//! fig11-replay — open-loop trace replay: overload behavior under bursty,
//! trace-clocked traffic.
//!
//! Every other bench drives the pool closed-loop: a rejected submit
//! retries after draining a response, so offered load self-throttles to
//! capacity and the admission/shedding machinery never actually fires.
//! This bench is the overload story. It first **calibrates** pool capacity
//! (closed-loop, requests/s on this runner), then generates seeded
//! open-loop traces at ~2× that rate (steady, bursty, diurnal — see
//! `trex::workload::synth`) and replays them on the trace clock: every
//! record submits exactly once at its arrival time, rejections shed at the
//! door, nothing retries.
//!
//! Two pool configurations face the same 2× overload:
//!
//! * **bounded (shed-at-door)**: small queue depth + in-flight bound + KV
//!   admission — the pool refuses what it cannot serve promptly;
//! * **unbounded (admit-everything)**: no backpressure — every request is
//!   admitted and queues grow without limit.
//!
//! Graceful degradation is the bounded column: goodput holds near
//! capacity, excess load is refused synchronously (shed rate ≈ the
//! overload fraction), and the p95 latency of *admitted* work stays
//! bounded. The unbounded column shows the alternative: the same goodput,
//! but tail latency grows with the backlog — every admitted request waits
//! behind the whole queue.
//!
//! `--test` (CI smoke): small trace; asserts the bounded pool sheds at the
//! door (not after admission), keeps conservation (lifecycle ledger), and
//! holds admitted-work p95 well under the unbounded pool's.

use std::sync::Arc;
use std::time::{Duration, Instant};
use trex::bench_util::{banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, PoolConfig, Request, Server, ServerHandle,
};
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::runtime::ArtifactSet;
use trex::workload::{
    replay, synth_trace, ArrivalShape, ReplayConfig, ReplayStats, SynthSpec, Trace,
};

const MAX_SEQ: usize = 32;
const D: usize = 64;

fn start_pool(bounded: bool) -> ServerHandle {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let kv = if bounded {
        Some(Arc::new(KvManager::new(
            &hw,
            &pm,
            KvArenaConfig::for_pool(&hw, &pm, KvQuant::Fp16, None),
        )))
    } else {
        None
    };
    let pool = PoolConfig {
        workers: 2,
        queue_depth: if bounded { 8 } else { 0 },
        max_inflight: if bounded { 32 } else { 0 },
        kv,
        lifecycle_ledger: true,
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::from_micros(200) },
        ..PoolConfig::default()
    };
    Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("fig11", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    )
}

/// Touch every batch class + the decode path so the pool's first
/// simulations (and decode plan compilations) are out of the way before
/// anything is timed. Warmup ids stay clear of trace ids (which start at 0).
fn warmup(handle: &ServerHandle) {
    let specs: [(usize, usize); 4] = [(4, 2), (6, 0), (12, 0), (30, 0)];
    for (i, (len, generate)) in specs.iter().enumerate() {
        let mut req = Request::new(u64::MAX - i as u64, *len, vec![0.1; len * D]);
        if *generate > 0 {
            req = req.with_generate(*generate);
        }
        handle.submit(req).expect("warmup submit");
    }
    for _ in 0..specs.len() {
        handle.responses.recv_timeout(Duration::from_secs(60)).expect("warmup response");
    }
    let _ = handle.tokens.try_iter().count();
}

/// Closed-loop capacity estimate, requests/s on this runner — the anchor
/// that makes "2× overload" mean the same thing on a laptop and a loaded
/// CI box.
fn calibrate(trace: &Trace, n: usize) -> f64 {
    let handle = start_pool(false);
    warmup(&handle);
    let t0 = Instant::now();
    for rec in trace.records.iter().take(n) {
        let mut req = Request::new(rec.id, rec.prompt_len, vec![0.1; rec.prompt_len * D]);
        if rec.gen_len > 0 {
            req = req.with_generate(rec.gen_len);
        }
        handle.submit(req).expect("unbounded pool rejects nothing");
    }
    let served = n.min(trace.len());
    for _ in 0..served {
        handle.responses.recv_timeout(Duration::from_secs(60)).expect("calibration response");
    }
    let rps = served as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    handle.shutdown().expect("clean calibration shutdown");
    rps
}

struct RunOutcome {
    stats: ReplayStats,
    conserved: bool,
}

fn run_replay(trace: &Trace, bounded: bool) -> RunOutcome {
    let handle = start_pool(bounded);
    warmup(&handle);
    let stats = replay(&handle, trace, &ReplayConfig::new(D));
    let metrics = Arc::clone(&handle.metrics);
    handle.shutdown().expect("clean shutdown after replay");
    let conserved = metrics.ledger_audit().is_some_and(|a| a.conserved());
    RunOutcome { stats, conserved }
}

fn spec(seed: u64, mean_rps: f64, duration_us: u64, shape: ArrivalShape) -> SynthSpec {
    SynthSpec {
        shape,
        generate_share: 0.4,
        gen_tokens: 3,
        prefix_groups: 2,
        ..SynthSpec::steady(seed, mean_rps, duration_us, MAX_SEQ)
    }
}

fn row(name: &str, offered_rps: f64, r: &RunOutcome) -> Vec<String> {
    let s = &r.stats;
    vec![
        name.to_string(),
        format!("{:.0}", offered_rps),
        format!("{}", s.offered),
        format!("{}", s.admitted),
        format!("{}", s.shed_at_door),
        format!("{}", s.shed_after_admit),
        format!("{:.0}", s.goodput_rps),
        format!("{:.0}%", s.shed_rate() * 100.0),
        format!("{:.1}", s.latency_us_p50 / 1e3),
        format!("{:.1}", s.latency_us_p95 / 1e3),
        format!("{:.1}", s.latency_us_p99 / 1e3),
        if r.conserved { "yes" } else { "NO" }.to_string(),
    ]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    banner("fig11-replay: open-loop trace replay under 2x overload");

    // Calibrate on a throwaway steady trace (lengths/classes match what
    // the replays offer). The calibration count sizes the replay traces:
    // every run offers ~the same request count regardless of runner speed.
    let n_offered = if smoke { 240 } else { 900 };
    let cal_trace = synth_trace(&spec(0xCA11B, 4000.0, 10_000_000, ArrivalShape::Steady));
    let capacity_rps = calibrate(&cal_trace, if smoke { 60 } else { 150 });
    let overload_rps = 2.0 * capacity_rps;
    let duration_us = ((n_offered as f64 / overload_rps) * 1e6) as u64;
    println!(
        "calibrated capacity ~{capacity_rps:.0} req/s; offering 2x = {overload_rps:.0} req/s \
         for {:.0} ms ({n_offered} requests)\n",
        duration_us as f64 / 1e3
    );

    let steady = synth_trace(&spec(0xF116, overload_rps, duration_us, ArrivalShape::Steady));
    let bounded = run_replay(&steady, true);
    let unbounded = run_replay(&steady, false);

    let mut rows = vec![
        row("steady 2x · bounded", overload_rps, &bounded),
        row("steady 2x · unbounded", overload_rps, &unbounded),
    ];
    if !smoke {
        let burst = synth_trace(&spec(
            0xF117,
            overload_rps,
            duration_us,
            ArrivalShape::Burst {
                mult: 6.0,
                period_us: duration_us / 4,
                burst_us: duration_us / 16,
            },
        ));
        let diurnal = synth_trace(&spec(
            0xF118,
            overload_rps,
            duration_us,
            ArrivalShape::Diurnal { swing: 0.8, period_us: duration_us },
        ));
        rows.push(row("burst 6x/16 · bounded", overload_rps, &run_replay(&burst, true)));
        rows.push(row("diurnal ±80% · bounded", overload_rps, &run_replay(&diurnal, true)));
    }
    table(
        &[
            "trace · pool",
            "offered rps",
            "offered",
            "admitted",
            "door shed",
            "late shed",
            "goodput rps",
            "shed",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "conserved",
        ],
        &rows,
    );
    println!(
        "\nBoth pools face the same 2x-overload trace on an open loop (no\n\
         retries). The bounded pool refuses excess load synchronously at the\n\
         door, so admitted work keeps a bounded tail; the unbounded pool\n\
         admits everything and its p95 grows with the backlog. Goodput is\n\
         capacity-bound either way — backpressure buys latency, not\n\
         throughput."
    );

    // Acceptance (CI smoke): graceful degradation under 2x overload.
    let (b, u) = (&bounded.stats, &unbounded.stats);
    assert!(b.drained, "bounded pool must settle within the drain window");
    assert!(
        b.shed_at_door > 0,
        "2x overload must trip door shedding (admitted {}, offered {})",
        b.admitted,
        b.offered
    );
    assert_eq!(
        b.shed_after_admit, 0,
        "every admitted request must answer — shedding happens at the door"
    );
    assert!(bounded.conserved, "lifecycle ledger must balance after the drain");
    assert!(
        b.latency_us_p95 < u.latency_us_p95 * 0.5,
        "bounded-pool admitted work must keep a bounded tail: p95 {:.1} ms (bounded) vs \
         {:.1} ms (unbounded backlog)",
        b.latency_us_p95 / 1e3,
        u.latency_us_p95 / 1e3
    );
    println!(
        "\nfig11-replay OK: door shed {}/{} offered, p95 {:.1} ms (bounded) vs {:.1} ms \
         (unbounded)",
        b.shed_at_door,
        b.offered,
        b.latency_us_p95 / 1e3,
        u.latency_us_p95 / 1e3
    );
}
