//! Fig. 23.1.7 — performance summary: 60–450 MHz across 0.45–0.85 V,
//! 7.12–152.5 mW. Sweeps the operating points (including interpolated ones)
//! on a fixed workload and reports frequency, modeled average power, peak
//! power (the measurement anchor), latency and energy.
//!
//! This sweep is the *static* menu: every point is pinned for the whole
//! run. `fig12_slo` uses its endpoints (0.85 V fast, 0.45 V frugal) as
//! the static baselines the runtime DVFS governor is judged against —
//! the governor walks this same table dynamically, buying µJ/token in
//! load valleys without giving up the latency SLO the 0.85 V point sets.

use trex::bench_util::{banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::model::build_program;
use trex::sim::{simulate, SimOptions};

fn main() {
    let hw = HwConfig::default();
    let m = ModelConfig::nmt_rdrop();
    let prog = build_program(&m, 64, 2);

    banner("Fig 23.1.7: voltage/frequency sweep (NMT workload, batch-2)");
    let mut rows = Vec::new();
    let mut vdd = 0.45;
    while vdd <= 0.8501 {
        let p = hw.point_at_vdd(vdd);
        let s = simulate(
            &hw,
            &prog,
            &SimOptions { point: p, act_bits: m.act_bits, ..SimOptions::paper(&hw) },
        );
        rows.push(vec![
            format!("{:.2}", p.vdd),
            format!("{:.0}", p.freq_mhz),
            format!("{:.2}", p.peak_mw),
            format!("{:.2}", s.avg_power_mw()),
            format!("{:.1}", s.us_per_token()),
            format!("{:.2}", s.uj_per_token()),
        ]);
        vdd += 0.05;
    }
    table(
        &["Vdd (V)", "f (MHz)", "peak mW (meas.)", "avg mW (model)", "µs/token", "µJ/token"],
        &rows,
    );
    println!(
        "\nanchors: 0.45 V/60 MHz/7.12 mW and 0.85 V/450 MHz/152.5 mW are the\n\
         paper's measured corners; modeled average power sits below peak by the\n\
         chip's idle fraction (utilization < 100%)."
    );

    banner("energy-optimal point per workload");
    let mut rows = Vec::new();
    for name in trex::config::WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        let prog = build_program(&m, (m.mean_input_len as usize).clamp(1, 128), 1);
        let mut best = (f64::INFINITY, 0.0);
        for &p in &hw.points {
            let s = simulate(
                &hw,
                &prog,
                &SimOptions { point: p, act_bits: m.act_bits, ..SimOptions::paper(&hw) },
            );
            if s.uj_per_token() < best.0 {
                best = (s.uj_per_token(), p.vdd);
            }
        }
        rows.push(vec![name.to_string(), format!("{:.2} V", best.1), format!("{:.2}", best.0)]);
    }
    table(&["workload", "best Vdd", "µJ/token"], &rows);
}
