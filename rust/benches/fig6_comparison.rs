//! Fig. 23.1.6 — the measurement & comparison table.
//!
//! Per workload: parameter-size reduction, EMA reduction vs the dense
//! baseline, utilization improvement, µs/token and µJ/token at the fast
//! corner (0.85 V / 450 MHz) and the efficient corner (0.45 V / 60 MHz) —
//! then the prior-work comparison with the paper's EMA adders.
//!
//! Paper bands: params ↓15.9–25.5×, EMA ↓31–65.9×, util ×1.2–3.4,
//! 68–567 µs/token, 0.41–3.95 µJ/token.

use trex::baseline::{dense_program, prior_works};
use trex::bench_util::{banner, ratio, table};
use trex::compress::CompressionReport;
use trex::config::{HwConfig, ModelConfig, WORKLOADS};
use trex::model::build_program;
use trex::sim::{batch_class, simulate, SimOptions};

fn main() {
    let hw = HwConfig::default();
    banner("Fig 23.1.6 (a): per-workload measurement (simulated chip)");
    let mut rows = Vec::new();
    for name in WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        let rep = CompressionReport::analytic(&m);
        let seq = (m.mean_input_len as usize).clamp(1, m.max_seq);
        let class = batch_class(seq, hw.max_seq).unwrap();
        let b = class.batch();

        let fast = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
        let eco = SimOptions { point: hw.min_point(), ..fast };
        let trex_fast = simulate(&hw, &build_program(&m, seq, b), &fast);
        let trex_eco = simulate(&hw, &build_program(&m, seq, b), &eco);
        let dense = simulate(&hw, &dense_program(&m, seq), &fast);
        // Features-off comparator for the utilization column.
        let base_util = simulate(
            &hw,
            &build_program(&m, seq, 1),
            &SimOptions { trf: false, ..fast },
        );

        let ema_gain =
            dense.ema_bytes() as f64 / (trex_fast.ema_bytes() as f64 / b as f64);
        let util_gain = trex_fast.utilization(&hw) / base_util.utilization(&hw);
        rows.push(vec![
            name.to_string(),
            ratio(rep.total_ratio()),
            ratio(ema_gain),
            ratio(util_gain),
            format!("{:.0}", trex_fast.us_per_token()),
            format!("{:.2}", trex_fast.uj_per_token()),
            format!("{:.0}", trex_eco.us_per_token()),
            format!("{:.2}", trex_eco.uj_per_token()),
        ]);
    }
    rows.push(vec![
        "paper".into(),
        "15.9-25.5x".into(),
        "31-65.9x".into(),
        "1.2-3.4x".into(),
        "68-567".into(),
        "0.41-3.95".into(),
        "-".into(),
        "-".into(),
    ]);
    table(
        &[
            "workload",
            "param ↓",
            "EMA ↓",
            "util ×",
            "µs/tok @.85V",
            "µJ/tok @.85V",
            "µs/tok @.45V",
            "µJ/tok @.45V",
        ],
        &rows,
    );

    banner("Fig 23.1.6 (b): comparison vs prior accelerators (EMA added at 3.7 pJ/b)");
    let m = ModelConfig::bert_large();
    let seq = 28usize;
    let b = batch_class(seq, hw.max_seq).unwrap().batch();
    let trex = simulate(
        &hw,
        &build_program(&m, seq, b),
        &SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) },
    );
    let trex_uj = trex.uj_per_token();
    let mut rows = vec![vec![
        "T-REX (this repro, BERT-Large)".to_string(),
        "16".into(),
        format!("{:.2}", trex_uj),
        "incl.".into(),
        "1.00x".into(),
    ]];
    for w in prior_works() {
        rows.push(vec![
            w.name.to_string(),
            format!("{}", w.tech_nm),
            format!("{:.2}", w.uj_per_token_with_ema()),
            if w.includes_ema { "incl.".into() } else { "added".into() },
            ratio(w.uj_per_token_with_ema() / trex_uj),
        ]);
    }
    table(
        &["accelerator", "node (nm)", "µJ/token (w/ EMA)", "EMA", "vs T-REX"],
        &rows,
    );
    println!(
        "\nshape check: with EMA included, T-REX wins against every prior work —\n\
         by the largest factors against CIM designs that excluded DRAM traffic.\n\
         Absolute µJ/token is power-anchored to Fig 23.1.7 (see EXPERIMENTS.md\n\
         for the paper-internal inconsistency analysis)."
    );
}
