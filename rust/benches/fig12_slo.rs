//! fig12-slo — runtime DVFS governor + SLO-aware admission vs the static
//! operating points of fig7: does closing the loop buy µJ/token at equal
//! SLO attainment?
//!
//! fig7's VDD/frequency sweep is an *offline* menu: pick 0.85 V and every
//! token pays 0.339 nJ/cycle whether the queue is deep or empty; pick
//! 0.45 V and tokens cost 0.119 nJ/cycle but take 7.5× longer, blowing any
//! latency target the moment load arrives. The governor walks that same
//! table at runtime: it watches the telemetry sampler's per-interval
//! decode-µs/token percentiles and per-chip queue depths, drops a chip one
//! operating point when the frequency-ratio projection says the SLO still
//! holds at the lower point, boosts on queue bursts or observed breaches,
//! and re-costs the chip's step-plan scope on every re-point (plans are
//! compiled per operating point — a stale plan is a correctness bug).
//!
//! Three runs over the same diurnal open-loop trace (paced valleys with
//! short bursts), two general chips each:
//!
//! * **static max**: both chips pinned at 0.85 V (fig7's fast point) —
//!   this run is also the probe that calibrates the SLO target
//!   (2.5× its observed worst interval p95);
//! * **static min**: both chips pinned at 0.45 V (fig7's frugal point);
//! * **governed**: chips start at 0.85 V, governor on with the calibrated
//!   SLO target.
//!
//! Attainment is the token-weighted fraction of telemetry intervals whose
//! decode p95 met the target. The claim: the governed fleet lands within a
//! point of the static-max attainment while spending ≥15% fewer µJ/token,
//! and the static-min fleet shows why the cheap point can't simply be
//! pinned — it breaches.
//!
//! `--test` (CI smoke): small trace; asserts the energy saving, the
//! attainment ordering, that re-points actually happened (and settled
//! below 0.85 V), that no step was ever priced against a stale plan, and
//! that the ledger + every chip arena drain clean.

use std::sync::Arc;
use std::time::Duration;
use trex::bench_util::{banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::control::{GovernorConfig, SloTarget};
use trex::coordinator::{BatcherConfig, Engine, EngineConfig, PoolConfig, Request, Server};
use trex::fleet::{ChipSpec, Fleet};
use trex::kv::KvQuant;
use trex::obs::{Snapshot, TelemetryConfig};
use trex::runtime::ArtifactSet;

const MAX_SEQ: usize = 32;
const D: usize = 64;
const PROMPT: usize = 6;
const GEN: usize = 8;

struct SloOutcome {
    tokens: u64,
    chip_uj: f64,
    snaps: Vec<Snapshot>,
    repoints: u64,
    stale_plan_hits: u64,
    final_vdds: Vec<f64>,
    door_sheds: u64,
}

impl SloOutcome {
    fn uj_per_token(&self) -> f64 {
        self.chip_uj / (self.tokens as f64).max(1.0)
    }

    /// Token-weighted fraction of non-empty telemetry intervals whose
    /// decode p95 met the target.
    fn attainment(&self, target_us: f64) -> f64 {
        let (mut total, mut ok) = (0u64, 0u64);
        for s in &self.snaps {
            if s.interval_tokens == 0 {
                continue;
            }
            total += s.interval_tokens;
            if s.interval_us_p95 <= target_us {
                ok += s.interval_tokens;
            }
        }
        if total == 0 {
            1.0
        } else {
            ok as f64 / total as f64
        }
    }

    /// Worst non-empty interval p95 — the probe statistic the SLO target
    /// is calibrated from.
    fn worst_p95(&self) -> f64 {
        self.snaps
            .iter()
            .filter(|s| s.interval_tokens > 0)
            .map(|s| s.interval_us_p95)
            .fold(0.0, f64::max)
    }
}

/// Diurnal arrival gaps, µs: long paced valleys with short gap-free bursts
/// (two "days" worth). Valleys keep queues shallow so the governor can
/// drop; bursts exercise the boost path.
fn diurnal_gaps(n: usize) -> Vec<u64> {
    let day = (n / 2).max(1);
    let burst = (day / 8).max(1);
    (0..n)
        .map(|i| {
            let phase = i % day;
            if phase < burst {
                0 // burst: back-to-back arrivals
            } else {
                350 // valley: paced
            }
        })
        .collect()
}

/// Run the diurnal trace against a two-chip general fleet at `vdd` and
/// account tokens, modeled energy, and telemetry intervals. `governor`
/// turns the control plane on (SLO target included); statics run the exact
/// PR-9 pool.
fn run(
    vdd: f64,
    governor: Option<GovernorConfig>,
    slo: Option<SloTarget>,
    gaps: &[u64],
) -> SloOutcome {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let fleet = Arc::new(
        Fleet::build(
            vec![ChipSpec::general("g0", vdd), ChipSpec::general("g1", vdd)],
            &hw,
            &pm,
            KvQuant::Fp16,
        )
        .expect("fleet build"),
    );
    let pool = PoolConfig {
        fleet: Some(Arc::clone(&fleet)),
        lifecycle_ledger: true,
        telemetry: Some(TelemetryConfig {
            interval: Duration::from_micros(1_500),
            capacity: 4096,
            ..TelemetryConfig::default()
        }),
        slo,
        governor,
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::from_micros(200) },
        ..PoolConfig::default()
    };
    let hw2 = hw.clone();
    let pm2 = pm.clone();
    let mut handle = Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("fig12s", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw2.clone(),
                    perf_model: pm2.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    );
    let metrics = Arc::clone(&handle.metrics);
    let (resp_rx, tok_rx) = handle.detach_streams();
    drop(tok_rx);
    let submitter = handle.submitter();

    for (i, gap) in gaps.iter().enumerate() {
        if *gap > 0 {
            std::thread::sleep(Duration::from_micros(*gap));
        }
        let mut req = Request::new(i as u64, PROMPT, vec![0.1; PROMPT * D]).with_generate(GEN);
        // Bounded backpressure retry; an SLO door shed is terminal for the
        // request (the trace is open-loop — shed traffic does not return).
        for _ in 0..200 {
            match submitter.try_submit(req) {
                Ok(()) => break,
                Err((r, e)) => {
                    if e.to_string().contains("slo breach") {
                        break;
                    }
                    req = r;
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
    }

    let report = handle.shutdown().expect("clean shutdown");
    assert!(
        metrics.ledger_audit().is_some_and(|a| a.conserved()),
        "lifecycle ledger must balance after the drain"
    );
    let (mut tokens, mut uj) = (0u64, 0.0f64);
    for resp in resp_rx.try_iter() {
        tokens += resp.tokens_generated as u64;
        uj += resp.chip_uj;
    }
    let mut stale = 0u64;
    let mut final_vdds = Vec::new();
    for chip in &fleet.chips {
        let residual = chip.kv.residual();
        assert!(
            residual.is_clean(),
            "chip '{}' holds KV residual after drain: {residual:?}",
            chip.spec.id
        );
        stale += chip.stale_plan_hits();
        final_vdds.push(chip.current_vdd());
    }
    let snaps = report.telemetry.as_ref().map(|t| t.snapshots()).unwrap_or_default();
    let repoints = report.control.as_ref().map(|c| c.repoints()).unwrap_or(0);
    let door_sheds = report.control.as_ref().map(|c| c.door_sheds()).unwrap_or(0);
    SloOutcome {
        tokens,
        chip_uj: uj,
        snaps,
        repoints,
        stale_plan_hits: stale,
        final_vdds,
        door_sheds,
    }
}

fn row(name: &str, r: &SloOutcome, target_us: f64) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{}", r.tokens),
        format!("{:.1}", r.chip_uj),
        format!("{:.3}", r.uj_per_token()),
        format!("{:.1}%", r.attainment(target_us) * 100.0),
        format!("{}", r.repoints),
        r.final_vdds.iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>().join("/"),
    ]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    banner("fig12-slo: runtime DVFS governor + SLO admission vs fig7's static points");

    let n = if smoke { 160 } else { 640 };
    let gaps = diurnal_gaps(n);
    println!(
        "{n} requests x ({PROMPT}-token prompt + {GEN} decode tokens), diurnal \
         open-loop trace (paced valleys, gap-free bursts), 2 general chips\n"
    );

    // Probe + baseline in one: the static max-VDD run calibrates the SLO
    // target at 2.5x its own worst interval p95.
    let max = run(0.85, None, None, &gaps);
    let target_us = max.worst_p95() * 2.5;
    assert!(target_us > 0.0, "probe run observed no decode intervals");
    println!("SLO target (decode p95): {target_us:.1} us/token (2.5x static-max probe)\n");

    let min = run(0.45, None, None, &gaps);
    let gov = run(
        0.85,
        Some(GovernorConfig { dwell_us: 3_000.0, ..GovernorConfig::default() }),
        Some(SloTarget::decode(target_us)),
        &gaps,
    );

    table(
        &["config (2 chips)", "tokens", "total uJ", "uJ/tok", "attainment", "re-points", "final V"],
        &[
            row("static 2xG@0.85V (probe)", &max, target_us),
            row("static 2xG@0.45V", &min, target_us),
            row("governed (start 0.85V)", &gov, target_us),
        ],
    );
    println!(
        "\nfig7 is the menu; the governor orders from it at runtime. Valleys let\n\
         it walk down to the cheapest point whose frequency-ratio projection\n\
         still clears the target; bursts walk it back up. Every re-point bumps\n\
         the chip's plan epoch, so each step is priced at the point it ran at\n\
         ({} governed door sheds).",
        gov.door_sheds
    );

    // Acceptance (CI smoke).
    let (max_uj, gov_uj) = (max.uj_per_token(), gov.uj_per_token());
    assert!(gov.tokens > 0, "governed fleet generated no tokens");
    assert_eq!(
        gov.stale_plan_hits, 0,
        "no step may be priced against a stale plan after a re-point"
    );
    assert_eq!(max.repoints, 0, "static runs must never re-point");
    assert_eq!(min.repoints, 0, "static runs must never re-point");
    assert!(
        gov.repoints >= 2,
        "governor should have walked down at least two points, saw {}",
        gov.repoints
    );
    assert!(
        gov.final_vdds.iter().all(|v| *v < 0.85 - 1e-9),
        "governed chips should settle below 0.85 V, saw {:?}",
        gov.final_vdds
    );
    assert!(
        gov_uj <= 0.85 * max_uj,
        "governor must save >=15% uJ/token vs static max: {gov_uj:.3} vs {max_uj:.3}"
    );
    assert!(
        gov.attainment(target_us) >= max.attainment(target_us) - 1e-9,
        "governed attainment must match the static-max baseline: {:.3} vs {:.3}",
        gov.attainment(target_us),
        max.attainment(target_us)
    );
    assert!(
        min.attainment(target_us) < gov.attainment(target_us),
        "the static-min point must breach where the governor does not: {:.3} vs {:.3}",
        min.attainment(target_us),
        gov.attainment(target_us)
    );
    println!(
        "\nfig12-slo OK: {:.3} -> {:.3} uJ/token ({:.0}% saved) at attainment \
         {:.1}% (static max {:.1}%, static min {:.1}%), {} re-points",
        max_uj,
        gov_uj,
        (1.0 - gov_uj / max_uj) * 100.0,
        gov.attainment(target_us) * 100.0,
        max.attainment(target_us) * 100.0,
        min.attainment(target_us) * 100.0,
        gov.repoints
    );
}
