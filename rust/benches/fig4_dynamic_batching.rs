//! Fig. 23.1.4 — dynamic batching across input lengths.
//!
//! Sweeps input length over the three dataflow classes and reports, for
//! batch-1 vs the class's full batch: utilization, per-input EMA, and
//! per-input latency. The paper's headline: utilization up to 3.31× and
//! EMA down via parameter reuse, most pronounced for short inputs
//! (BERT-Large-style NLU traffic).

use trex::bench_util::{banner, ratio, table};
use trex::config::{HwConfig, ModelConfig};
use trex::model::build_program;
use trex::sim::{batch_class, simulate, SimOptions};

fn main() {
    let hw = HwConfig::default();
    let m = ModelConfig::bert_large();
    let opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };

    banner("Fig 23.1.4: batching vs input length (BERT-Large)");
    let mut rows = Vec::new();
    for seq in [128usize, 96, 64, 48, 32, 24, 16, 8] {
        let class = batch_class(seq, hw.max_seq).unwrap();
        let b = class.batch();
        let solo = simulate(&hw, &build_program(&m, seq, 1), &opts);
        let batched = simulate(&hw, &build_program(&m, seq, b), &opts);
        let util_gain = batched.utilization(&hw) / solo.utilization(&hw);
        let ema_solo = solo.ema_bytes() as f64;
        let ema_batched = batched.ema_bytes() as f64 / b as f64;
        let lat_solo = solo.seconds() * 1e6;
        let lat_batched = batched.seconds() * 1e6 / b as f64;
        rows.push(vec![
            format!("{seq}"),
            class.name().to_string(),
            format!("{:.1}%", solo.utilization(&hw) * 100.0),
            format!("{:.1}%", batched.utilization(&hw) * 100.0),
            ratio(util_gain),
            ratio(ema_solo / ema_batched),
            ratio(lat_solo / lat_batched),
        ]);
    }
    table(
        &[
            "len",
            "class",
            "util b=1",
            "util batched",
            "util gain",
            "EMA gain/input",
            "latency gain/input",
        ],
        &rows,
    );
    println!(
        "\npaper: dynamic batching improves utilization by up to 3.31× and cuts EMA\n\
         by re-using parameters across the batch; gains appear exactly where\n\
         inputs underfill the 128-token plane. (Our idealized B1 starves harder\n\
         than silicon, so short-input gains can exceed the paper's ceiling —\n\
         see EXPERIMENTS.md.)"
    );

    banner("mean-length traffic per workload (trace-weighted)");
    let mut rows = Vec::new();
    for name in trex::config::WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        let seq = (m.mean_input_len as usize).clamp(1, m.max_seq);
        let class = batch_class(seq, hw.max_seq).unwrap();
        let solo = simulate(&hw, &build_program(&m, seq, 1), &opts);
        let batched = simulate(&hw, &build_program(&m, seq, class.batch()), &opts);
        rows.push(vec![
            name.to_string(),
            format!("{seq}"),
            class.name().to_string(),
            ratio(batched.utilization(&hw) / solo.utilization(&hw)),
        ]);
    }
    table(&["workload", "mean len", "class", "util gain"], &rows);
}
