//! Fig. 23.1.4 — dynamic batching across input lengths, plus the host-side
//! serving-pool scaling that batching feeds.
//!
//! Part 1 sweeps input length over the three dataflow classes and reports,
//! for batch-1 vs the class's full batch: utilization, per-input EMA, and
//! per-input latency. The paper's headline: utilization up to 3.31× and
//! EMA down via parameter reuse, most pronounced for short inputs
//! (BERT-Large-style NLU traffic).
//!
//! Part 2 drives the same mixed B1/B2/B4 offered load through the
//! coordinator's worker pool at 1 vs 4 workers (deterministic reference
//! backend, no artifacts needed) and reports host-side throughput scaling —
//! and verifies the per-request numerics are identical regardless of worker
//! count or batch composition.
//!
//! `--test` (CI smoke): one quick iteration of both parts.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};
use trex::bench_util::{banner, ratio, table};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, PoolConfig, Request, Server, TraceGenerator,
};
use trex::model::build_program;
use trex::runtime::ArtifactSet;
use trex::sim::{batch_class, simulate, SimOptions};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    chip_batching_sweep(smoke);
    pool_scaling(smoke);
}

fn chip_batching_sweep(smoke: bool) {
    let hw = HwConfig::default();
    let m = ModelConfig::bert_large();
    let opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };

    banner("Fig 23.1.4: batching vs input length (BERT-Large)");
    let seqs: &[usize] =
        if smoke { &[128, 32] } else { &[128, 96, 64, 48, 32, 24, 16, 8] };
    let mut rows = Vec::new();
    for &seq in seqs {
        let class = batch_class(seq, hw.max_seq).unwrap();
        let b = class.batch();
        let solo = simulate(&hw, &build_program(&m, seq, 1), &opts);
        let batched = simulate(&hw, &build_program(&m, seq, b), &opts);
        let util_gain = batched.utilization(&hw) / solo.utilization(&hw);
        let ema_solo = solo.ema_bytes() as f64;
        let ema_batched = batched.ema_bytes() as f64 / b as f64;
        let lat_solo = solo.seconds() * 1e6;
        let lat_batched = batched.seconds() * 1e6 / b as f64;
        rows.push(vec![
            format!("{seq}"),
            class.name().to_string(),
            format!("{:.1}%", solo.utilization(&hw) * 100.0),
            format!("{:.1}%", batched.utilization(&hw) * 100.0),
            ratio(util_gain),
            ratio(ema_solo / ema_batched),
            ratio(lat_solo / lat_batched),
        ]);
    }
    table(
        &[
            "len",
            "class",
            "util b=1",
            "util batched",
            "util gain",
            "EMA gain/input",
            "latency gain/input",
        ],
        &rows,
    );
    println!(
        "\npaper: dynamic batching improves utilization by up to 3.31× and cuts EMA\n\
         by re-using parameters across the batch; gains appear exactly where\n\
         inputs underfill the 128-token plane. (Our idealized B1 starves harder\n\
         than silicon, so short-input gains can exceed the paper's ceiling —\n\
         see EXPERIMENTS.md.)"
    );

    if smoke {
        return;
    }
    banner("mean-length traffic per workload (trace-weighted)");
    let mut rows = Vec::new();
    for name in trex::config::WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        let seq = (m.mean_input_len as usize).clamp(1, m.max_seq);
        let class = batch_class(seq, hw.max_seq).unwrap();
        let solo = simulate(&hw, &build_program(&m, seq, 1), &opts);
        let batched = simulate(&hw, &build_program(&m, seq, class.batch()), &opts);
        rows.push(vec![
            name.to_string(),
            format!("{seq}"),
            class.name().to_string(),
            ratio(batched.utilization(&hw) / solo.utilization(&hw)),
        ]);
    }
    table(&["workload", "mean len", "class", "util gain"], &rows);
}

/// Per-request output checksums keyed by id — the numerics identity check.
type Checksums = BTreeMap<u64, f64>;

/// Run `requests` through a pool of `workers`; returns (wall seconds,
/// responses/s, per-request checksums).
fn run_pool(workers: usize, requests: Vec<Request>, max_seq: usize) -> (f64, f64, Checksums) {
    let n = requests.len();
    let hw = HwConfig::default();
    let pm = ModelConfig::bert_large();
    let handle = Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("pool-bench", 128, max_seq)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: false,
                    kv_quant: trex::kv::KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        PoolConfig {
            workers,
            queue_depth: 0,    // offered load: measure capacity, don't shed
            max_inflight: 0,
            batcher: BatcherConfig { max_seq, max_wait: Duration::from_micros(200) },
            ..PoolConfig::default()
        },
    );
    let t0 = Instant::now();
    for req in requests {
        handle.submit(req).expect("unbounded pool rejects nothing");
    }
    let mut sums = Checksums::new();
    for _ in 0..n {
        let resp = handle
            .responses
            .recv_timeout(Duration::from_secs(60))
            .expect("pool must answer every request");
        let sum = resp.output.iter().map(|v| *v as f64).sum::<f64>();
        sums.insert(resp.id, sum);
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = handle.shutdown().expect("clean shutdown");
    assert_eq!(report.metrics.completed(), n as u64, "pool must serve all requests");
    (wall, n as f64 / wall, sums)
}

fn pool_scaling(smoke: bool) {
    banner("host-side serving pool: mixed B1/B2/B4 offered load");
    let max_seq = 32;
    let n = if smoke { 64 } else { 2000 };
    // Identical offered load for every pool size (same ids, same payloads).
    let trace: Vec<Request> = TraceGenerator::mixed(max_seq, 128, 0xF16_4).take(n);

    let worker_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let mut rows = Vec::new();
    let mut base_rps = 0.0;
    let mut base_sums: Option<Checksums> = None;
    for &w in worker_counts {
        let (wall, rps, sums) = run_pool(w, trace.clone(), max_seq);
        if let Some(base) = &base_sums {
            assert_eq!(
                base, &sums,
                "per-request numerics must be identical at any worker count"
            );
        } else {
            base_rps = rps;
            base_sums = Some(sums);
        }
        rows.push(vec![
            format!("{w}"),
            format!("{:.1} ms", wall * 1e3),
            format!("{rps:.0}"),
            ratio(rps / base_rps),
        ]);
    }
    table(&["workers", "wall", "req/s", "speedup"], &rows);
    println!(
        "\n{n} mixed-length requests, identical trace per pool size; per-request\n\
         outputs verified bit-identical across worker counts (row-wise reference\n\
         numerics are independent of batch composition and worker assignment)."
    );
}
