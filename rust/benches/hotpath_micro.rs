//! L3 hot-path microbenchmarks (our §Perf baseline): simulator throughput,
//! batcher decision latency, codec encode/decode bandwidth, JSON, matmul —
//! plus the decode-step **plan-vs-rebuild** comparison (BENCH_5.json): the
//! per-token harness cost of the compiled `StepPlan` path against the
//! rebuild-and-rewalk path it replaces, with heap-allocation counts from a
//! counting global allocator. `--test` runs the plan section only and
//! asserts the plan path is ≥ 5× faster with zero steady-state allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use trex::bench_util::{bench, banner, si, table};
use trex::compress::{DeltaCodec, NonUniformQuant, UniformQuant};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{BatcherConfig, DynamicBatcher, Request};
use trex::factorize::CscFixed;
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::model::{build_decode_step, build_program};
use trex::sim::{simulate, GbBudget, SimOptions, StepPlan, Stepper};
use trex::util::json::Json;
use trex::util::mat::Mat;
use trex::util::rng::Rng;

/// Counting allocator: every alloc/realloc bumps a counter, so the bench
/// can prove the plan hot path performs zero steady-state heap traffic.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// BENCH_5: steady-state decode costing — compiled plan vs rebuild-per-
/// token — on `s2t_small` at the four-up group width. Emits machine-
/// readable `BENCH_5.json`; in `--test` mode asserts the acceptance bars.
fn decode_step_plan_section(smoke: bool) {
    banner("decode step plan vs rebuild (BENCH_5)");
    let hw = HwConfig::default();
    let m = ModelConfig::s2t_small();
    let quant = KvQuant::Fp16;
    let group = 4usize;
    let kv = KvManager::new(&hw, &m, KvArenaConfig::for_pool(&hw, &m, quant, None));
    let plan = StepPlan::compile_budgeted(&hw, &m, group, quant);
    let depths: Vec<usize> = (32..96).collect();

    // The exact path: what every steady-state token cost the harness
    // before plans — rebuild the step program, re-derive the budget and
    // dequant charge, walk every op through a fresh Stepper.
    let rebuild = |past: usize| -> f64 {
        let gb = GbBudget::for_decode_quant(&hw, &m, past, group, quant);
        let mut opts = SimOptions {
            act_bits: m.act_bits,
            prefetch: gb.fits_with_prefetch(),
            gb: Some(gb),
            ..SimOptions::paper(&hw)
        };
        opts.kv_dequant_bytes_per_layer = kv.dequant_bytes_per_layer(group, past);
        simulate(&hw, &build_decode_step(&m, past, group), &opts).seconds() * 1e6
    };
    let opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
    let mut scratch = Stepper::new(&hw, opts);
    // Warm the scratch: ledger categories allocate on first touch only.
    scratch.reset();
    scratch.run_plan(&plan, depths[0]);
    let modeled = {
        let s = scratch.settle();
        s.seconds() * 1e6 / s.tokens.max(1) as f64
    };

    let iters = if smoke { 10 } else { 30 };
    let r_rebuild = bench("rebuild+simulate (64 depths)", 2, iters, || {
        for &p in &depths {
            std::hint::black_box(rebuild(p));
        }
    });
    let r_plan = bench("run_plan (64 depths)", 2, iters, || {
        for &p in &depths {
            scratch.reset();
            scratch.run_plan(&plan, p);
            std::hint::black_box(scratch.settle());
        }
    });

    // Allocation counts for one full sweep of each path (plan path first,
    // already warm — its steady state must be allocation-free).
    let before = alloc_count();
    for &p in &depths {
        scratch.reset();
        scratch.run_plan(&plan, p);
        std::hint::black_box(scratch.settle());
    }
    let plan_allocs = alloc_count() - before;
    let before = alloc_count();
    for &p in &depths {
        std::hint::black_box(rebuild(p));
    }
    let rebuild_allocs = alloc_count() - before;

    let n = depths.len() as f64;
    let us_rebuild = r_rebuild.mean_ns / n / 1e3;
    let us_plan = r_plan.mean_ns / n / 1e3;
    let speedup = us_rebuild / us_plan.max(1e-9);
    table(
        &["path", "harness µs/token", "allocs/sweep"],
        &[
            vec!["rebuild+simulate".into(), format!("{us_rebuild:.2}"), rebuild_allocs.to_string()],
            vec!["compiled plan".into(), format!("{us_plan:.3}"), plan_allocs.to_string()],
            vec!["speedup".into(), format!("{speedup:.1}×"), "-".into()],
        ],
    );
    println!(
        "\nmodeled decode: {modeled:.0} µs/token (s2t-small, 4-up, depth {}).\n\
         The plan path prices a steady-state token in O(phases) arithmetic\n\
         with zero heap allocations; the rebuild path reconstructs and\n\
         re-walks the whole op program per token.",
        depths[0]
    );

    let j = Json::obj(vec![
        ("bench", Json::str("decode_step_plan_vs_rebuild")),
        ("model", Json::str("s2t-small")),
        ("group", Json::num(group as f64)),
        ("depths_swept", Json::num(n)),
        ("harness_us_per_token_rebuild", Json::num(us_rebuild)),
        ("harness_us_per_token_plan", Json::num(us_plan)),
        ("speedup", Json::num(speedup)),
        ("modeled_us_per_token", Json::num(modeled)),
        ("plan_allocs_per_sweep", Json::num(plan_allocs as f64)),
        ("rebuild_allocs_per_sweep", Json::num(rebuild_allocs as f64)),
    ]);
    j.to_file("BENCH_5.json").expect("write BENCH_5.json");
    println!("wrote BENCH_5.json");

    // Cross-check: the plan prices the step identically to the rebuild.
    let past = 48usize;
    scratch.reset();
    scratch.run_plan(&plan, past);
    let s = scratch.settle();
    let gb = GbBudget::for_decode_quant(&hw, &m, past, group, quant);
    let mut xopts = SimOptions {
        act_bits: m.act_bits,
        prefetch: gb.fits_with_prefetch(),
        gb: Some(gb),
        ..SimOptions::paper(&hw)
    };
    xopts.kv_dequant_bytes_per_layer = kv.dequant_bytes_per_layer(group, past);
    let exact = simulate(&hw, &build_decode_step(&m, past, group), &xopts);
    assert_eq!(s.cycles, exact.cycles, "plan/exact cycle mismatch at depth {past}");
    assert_eq!(s.ema_bytes, exact.ema_bytes(), "plan/exact EMA mismatch at depth {past}");

    if smoke {
        assert!(
            speedup >= 5.0,
            "plan path must be ≥5× faster than rebuild-per-token: {speedup:.1}×"
        );
        assert_eq!(plan_allocs, 0, "plan path must be allocation-free in steady state");
        println!("[ci-smoke] BENCH_5 OK: {speedup:.1}× speedup, {plan_allocs} allocs/sweep");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        decode_step_plan_section(true);
        return;
    }
    let hw = HwConfig::default();
    banner("L3 hot-path microbenchmarks");
    let mut rows = Vec::new();

    // 1. simulator: ops/s on the biggest program.
    let m = ModelConfig::bert_large();
    let prog = build_program(&m, 128, 1);
    let opts = SimOptions::paper(&hw);
    let n_ops = prog.ops.len();
    let r = bench("simulate bert-large pass", 3, 30, || {
        std::hint::black_box(simulate(&hw, &prog, &opts));
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(n_ops as f64 / (r.mean_ns / 1e9), "ops/s"),
    ]);

    // 2. program build.
    let r = bench("build_program bert-large", 3, 30, || {
        std::hint::black_box(build_program(&m, 128, 1));
    });
    rows.push(vec![r.name.clone(), format!("{:.1} µs", r.mean_us()), "-".into()]);

    // 3. batcher decision latency.
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..4096)
        .map(|i| Request::new(i, rng.range(1, 128), Vec::new()))
        .collect();
    let r = bench("batcher push (4096 reqs)", 3, 50, || {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        for req in &reqs {
            std::hint::black_box(b.push(req.clone()).unwrap());
        }
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        format!("{:.0} ns/req", r.mean_ns / 4096.0),
    ]);

    // 4. codecs on a bert-large-shaped W_D slab.
    let mut rng = Rng::new(2);
    let rank = 640usize;
    let cols = 1024usize;
    let nnz = 84usize;
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for _ in 0..cols {
        let mut rs = rng.sample_distinct(rank, nnz);
        rs.sort_unstable();
        for r in rs {
            idx.push(r as u16);
            val.push(rng.normal_f32());
        }
    }
    let sp = CscFixed { rows: rank, cols, nnz_per_col: nnz, idx, val };
    let codec = DeltaCodec::new(5, rank).unwrap();
    let nz_bytes = sp.nnz() as f64;
    let r = bench("delta encode W_D (86k nz)", 3, 30, || {
        std::hint::black_box(codec.encode(&sp).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "idx/s"),
    ]);
    let enc = codec.encode(&sp).unwrap();
    let r = bench("delta decode W_D", 3, 30, || {
        std::hint::black_box(codec.decode(&enc, rank, cols, nnz).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "idx/s"),
    ]);

    let uq = UniformQuant::fit(&sp.val, 6).unwrap();
    let r = bench("uniform 6b encode (86k vals)", 3, 30, || {
        std::hint::black_box(uq.encode(&sp.val).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "val/s"),
    ]);

    let ws = Mat::randn(1024, 640, &mut rng);
    let q = NonUniformQuant::fit(&ws.data[..20000], 4, 20).unwrap();
    let r = bench("nonuniform 4b encode W_S (655k)", 2, 10, || {
        std::hint::black_box(q.encode(&ws).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.0} µs", r.mean_us()),
        si(ws.data.len() as f64 / (r.mean_ns / 1e9), "elem/s"),
    ]);

    // 5. reference matmul (functional-mode numerics).
    let a = Mat::randn(128, 1024, &mut rng);
    let b = Mat::randn(1024, 640, &mut rng);
    let flops = 2.0 * 128.0 * 1024.0 * 640.0;
    let r = bench("Mat::matmul 128x1024x640", 2, 10, || {
        std::hint::black_box(a.matmul(&b).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.0} µs", r.mean_us()),
        si(flops / (r.mean_ns / 1e9), "FLOP/s"),
    ]);

    table(&["benchmark", "mean", "throughput"], &rows);

    decode_step_plan_section(false);
}
