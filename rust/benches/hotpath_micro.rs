//! L3 hot-path microbenchmarks (our §Perf baseline): simulator throughput,
//! batcher decision latency, codec encode/decode bandwidth, JSON, matmul.
//! These are the quantities the performance pass optimizes — recorded
//! before/after in EXPERIMENTS.md §Perf.

use trex::bench_util::{bench, banner, si, table};
use trex::compress::{DeltaCodec, NonUniformQuant, UniformQuant};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{BatcherConfig, DynamicBatcher, Request};
use trex::factorize::CscFixed;
use trex::model::build_program;
use trex::sim::{simulate, SimOptions};
use trex::util::mat::Mat;
use trex::util::rng::Rng;

fn main() {
    let hw = HwConfig::default();
    banner("L3 hot-path microbenchmarks");
    let mut rows = Vec::new();

    // 1. simulator: ops/s on the biggest program.
    let m = ModelConfig::bert_large();
    let prog = build_program(&m, 128, 1);
    let opts = SimOptions::paper(&hw);
    let n_ops = prog.ops.len();
    let r = bench("simulate bert-large pass", 3, 30, || {
        std::hint::black_box(simulate(&hw, &prog, &opts));
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(n_ops as f64 / (r.mean_ns / 1e9), "ops/s"),
    ]);

    // 2. program build.
    let r = bench("build_program bert-large", 3, 30, || {
        std::hint::black_box(build_program(&m, 128, 1));
    });
    rows.push(vec![r.name.clone(), format!("{:.1} µs", r.mean_us()), "-".into()]);

    // 3. batcher decision latency.
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..4096)
        .map(|i| Request::new(i, rng.range(1, 128), Vec::new()))
        .collect();
    let r = bench("batcher push (4096 reqs)", 3, 50, || {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        for req in &reqs {
            std::hint::black_box(b.push(req.clone()).unwrap());
        }
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        format!("{:.0} ns/req", r.mean_ns / 4096.0),
    ]);

    // 4. codecs on a bert-large-shaped W_D slab.
    let mut rng = Rng::new(2);
    let rank = 640usize;
    let cols = 1024usize;
    let nnz = 84usize;
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for _ in 0..cols {
        let mut rs = rng.sample_distinct(rank, nnz);
        rs.sort_unstable();
        for r in rs {
            idx.push(r as u16);
            val.push(rng.normal_f32());
        }
    }
    let sp = CscFixed { rows: rank, cols, nnz_per_col: nnz, idx, val };
    let codec = DeltaCodec::new(5, rank).unwrap();
    let nz_bytes = sp.nnz() as f64;
    let r = bench("delta encode W_D (86k nz)", 3, 30, || {
        std::hint::black_box(codec.encode(&sp).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "idx/s"),
    ]);
    let enc = codec.encode(&sp).unwrap();
    let r = bench("delta decode W_D", 3, 30, || {
        std::hint::black_box(codec.decode(&enc, rank, cols, nnz).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "idx/s"),
    ]);

    let uq = UniformQuant::fit(&sp.val, 6).unwrap();
    let r = bench("uniform 6b encode (86k vals)", 3, 30, || {
        std::hint::black_box(uq.encode(&sp.val).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "val/s"),
    ]);

    let ws = Mat::randn(1024, 640, &mut rng);
    let q = NonUniformQuant::fit(&ws.data[..20000], 4, 20).unwrap();
    let r = bench("nonuniform 4b encode W_S (655k)", 2, 10, || {
        std::hint::black_box(q.encode(&ws).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.0} µs", r.mean_us()),
        si(ws.data.len() as f64 / (r.mean_ns / 1e9), "elem/s"),
    ]);

    // 5. reference matmul (functional-mode numerics).
    let a = Mat::randn(128, 1024, &mut rng);
    let b = Mat::randn(1024, 640, &mut rng);
    let flops = 2.0 * 128.0 * 1024.0 * 640.0;
    let r = bench("Mat::matmul 128x1024x640", 2, 10, || {
        std::hint::black_box(a.matmul(&b).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.0} µs", r.mean_us()),
        si(flops / (r.mean_ns / 1e9), "FLOP/s"),
    ]);

    table(&["benchmark", "mean", "throughput"], &rows);
}
