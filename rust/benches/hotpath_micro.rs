//! L3 hot-path microbenchmarks (our §Perf baseline): simulator throughput,
//! batcher decision latency, codec encode/decode bandwidth, JSON, matmul —
//! plus the decode-step **plan-vs-rebuild** comparison (BENCH_5.json): the
//! per-token harness cost of the compiled `StepPlan` path against the
//! rebuild-and-rewalk path it replaces, with heap-allocation counts from a
//! counting global allocator — and the **span-tracing overhead gate**: the
//! same pool served with the flight recorder off vs on. `--test` runs the
//! plan + tracing sections only and asserts the plan path is ≥ 5× faster
//! with zero steady-state allocations, the disabled-tracing record site
//! adds zero allocations, warm-ring recording is allocation-free, and
//! enabled tracing stays within 5% us/token of the untraced pool.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use trex::bench_util::{bench, banner, si, table};
use trex::compress::{DeltaCodec, NonUniformQuant, UniformQuant};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, DynamicBatcher, Engine, EngineConfig, PoolConfig, Request, Server,
};
use trex::factorize::CscFixed;
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::model::{build_decode_step, build_program};
use trex::obs::{FlightRecorder, SpanEvent, SpanKind, SpanWriter};
use trex::runtime::{artifacts, ArtifactSet};
use trex::sim::{simulate, GbBudget, SimOptions, StepPlan, Stepper};
use trex::util::json::Json;
use trex::util::mat::Mat;
use trex::util::rng::Rng;

/// Counting allocator: every alloc/realloc bumps a counter, so the bench
/// can prove the plan hot path performs zero steady-state heap traffic.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// BENCH_5: steady-state decode costing — compiled plan vs rebuild-per-
/// token — on `s2t_small` at the four-up group width. Emits machine-
/// readable `BENCH_5.json`; in `--test` mode asserts the acceptance bars.
fn decode_step_plan_section(smoke: bool) {
    banner("decode step plan vs rebuild (BENCH_5)");
    let hw = HwConfig::default();
    let m = ModelConfig::s2t_small();
    let quant = KvQuant::Fp16;
    let group = 4usize;
    let kv = KvManager::new(&hw, &m, KvArenaConfig::for_pool(&hw, &m, quant, None));
    let plan = StepPlan::compile_budgeted(&hw, &m, group, quant);
    let depths: Vec<usize> = (32..96).collect();

    // The exact path: what every steady-state token cost the harness
    // before plans — rebuild the step program, re-derive the budget and
    // dequant charge, walk every op through a fresh Stepper.
    let rebuild = |past: usize| -> f64 {
        let gb = GbBudget::for_decode_quant(&hw, &m, past, group, quant);
        let mut opts = SimOptions {
            act_bits: m.act_bits,
            prefetch: gb.fits_with_prefetch(),
            gb: Some(gb),
            ..SimOptions::paper(&hw)
        };
        opts.kv_dequant_bytes_per_layer = kv.dequant_bytes_per_layer(group, past);
        simulate(&hw, &build_decode_step(&m, past, group), &opts).seconds() * 1e6
    };
    let opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
    let mut scratch = Stepper::new(&hw, opts);
    // Warm the scratch: ledger categories allocate on first touch only.
    scratch.reset();
    scratch.run_plan(&plan, depths[0]);
    let modeled = {
        let s = scratch.settle();
        s.seconds() * 1e6 / s.tokens.max(1) as f64
    };

    let iters = if smoke { 10 } else { 30 };
    let r_rebuild = bench("rebuild+simulate (64 depths)", 2, iters, || {
        for &p in &depths {
            std::hint::black_box(rebuild(p));
        }
    });
    let r_plan = bench("run_plan (64 depths)", 2, iters, || {
        for &p in &depths {
            scratch.reset();
            scratch.run_plan(&plan, p);
            std::hint::black_box(scratch.settle());
        }
    });

    // Allocation counts for one full sweep of each path (plan path first,
    // already warm — its steady state must be allocation-free).
    let before = alloc_count();
    for &p in &depths {
        scratch.reset();
        scratch.run_plan(&plan, p);
        std::hint::black_box(scratch.settle());
    }
    let plan_allocs = alloc_count() - before;
    let before = alloc_count();
    for &p in &depths {
        std::hint::black_box(rebuild(p));
    }
    let rebuild_allocs = alloc_count() - before;

    let n = depths.len() as f64;
    let us_rebuild = r_rebuild.mean_ns / n / 1e3;
    let us_plan = r_plan.mean_ns / n / 1e3;
    let speedup = us_rebuild / us_plan.max(1e-9);
    table(
        &["path", "harness µs/token", "allocs/sweep"],
        &[
            vec!["rebuild+simulate".into(), format!("{us_rebuild:.2}"), rebuild_allocs.to_string()],
            vec!["compiled plan".into(), format!("{us_plan:.3}"), plan_allocs.to_string()],
            vec!["speedup".into(), format!("{speedup:.1}×"), "-".into()],
        ],
    );
    println!(
        "\nmodeled decode: {modeled:.0} µs/token (s2t-small, 4-up, depth {}).\n\
         The plan path prices a steady-state token in O(phases) arithmetic\n\
         with zero heap allocations; the rebuild path reconstructs and\n\
         re-walks the whole op program per token.",
        depths[0]
    );

    let j = Json::obj(vec![
        ("bench", Json::str("decode_step_plan_vs_rebuild")),
        ("model", Json::str("s2t-small")),
        ("group", Json::num(group as f64)),
        ("depths_swept", Json::num(n)),
        ("harness_us_per_token_rebuild", Json::num(us_rebuild)),
        ("harness_us_per_token_plan", Json::num(us_plan)),
        ("speedup", Json::num(speedup)),
        ("modeled_us_per_token", Json::num(modeled)),
        ("plan_allocs_per_sweep", Json::num(plan_allocs as f64)),
        ("rebuild_allocs_per_sweep", Json::num(rebuild_allocs as f64)),
    ]);
    j.to_file("BENCH_5.json").expect("write BENCH_5.json");
    println!("wrote BENCH_5.json");

    // Cross-check: the plan prices the step identically to the rebuild.
    let past = 48usize;
    scratch.reset();
    scratch.run_plan(&plan, past);
    let s = scratch.settle();
    let gb = GbBudget::for_decode_quant(&hw, &m, past, group, quant);
    let mut xopts = SimOptions {
        act_bits: m.act_bits,
        prefetch: gb.fits_with_prefetch(),
        gb: Some(gb),
        ..SimOptions::paper(&hw)
    };
    xopts.kv_dequant_bytes_per_layer = kv.dequant_bytes_per_layer(group, past);
    let exact = simulate(&hw, &build_decode_step(&m, past, group), &xopts);
    assert_eq!(s.cycles, exact.cycles, "plan/exact cycle mismatch at depth {past}");
    assert_eq!(s.ema_bytes, exact.ema_bytes(), "plan/exact EMA mismatch at depth {past}");

    if smoke {
        assert!(
            speedup >= 5.0,
            "plan path must be ≥5× faster than rebuild-per-token: {speedup:.1}×"
        );
        assert_eq!(plan_allocs, 0, "plan path must be allocation-free in steady state");
        println!("[ci-smoke] BENCH_5 OK: {speedup:.1}× speedup, {plan_allocs} allocs/sweep");
    }
}

/// One closed-loop serve run over the reference backend: N generate
/// requests on a single worker, returning client-observed µs per decoded
/// token. `recorder` present = span tracing on (the engine, door, and KV
/// arena all record); absent = the production default.
fn serve_us_per_token(recorder: Option<Arc<FlightRecorder>>) -> f64 {
    let d = artifacts::TINY_D_MODEL;
    let max_seq = artifacts::TINY_MAX_SEQ;
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let pool = PoolConfig {
        workers: 1,
        recorder,
        batcher: BatcherConfig { max_seq, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    };
    let (hw2, pm2) = (hw.clone(), pm.clone());
    let handle = Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference(artifacts::TINY_MODEL, d, max_seq)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw2.clone(),
                    perf_model: pm2.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    );
    let (n_req, len, n_gen) = (12usize, 8usize, 16usize);
    let t0 = Instant::now();
    for i in 0..n_req {
        let req = Request::new(i as u64, len, vec![0.1; len * d]).with_generate(n_gen);
        handle.submit(req).expect("submit");
    }
    let mut got = 0;
    while got < n_req {
        handle.responses.recv().expect("pool response");
        got += 1;
    }
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;
    let tokens = handle.tokens.try_iter().count().max(1);
    handle.shutdown().expect("shutdown");
    wall_us / tokens as f64
}

/// Span-tracing overhead gate: (1) the disabled record site — the
/// engine's exact `Option<SpanWriter>` branch shape — performs zero
/// allocations; (2) recording into a warm ring is allocation-free; (3)
/// end-to-end, serving with tracing enabled stays within 5% us/token of
/// the untraced pool (plus a small absolute slack for timer noise).
fn tracing_overhead_section(smoke: bool) {
    banner("span tracing overhead (flight recorder off vs on)");

    // (1) Disabled path: the branch every record site takes when
    // `PoolConfig::recorder` is None. Must not touch the heap.
    let obs: Option<SpanWriter> = None;
    let before = alloc_count();
    for i in 0..4096u64 {
        if let Some(w) = &obs {
            w.record(SpanEvent::marker(SpanKind::DecodeStep, i, 0.0));
        }
        std::hint::black_box(i);
    }
    let disabled_allocs = alloc_count() - before;

    // (2) Enabled path, warm ring: a record is a clock read + one short
    // lane mutex + a struct store into a preallocated slot.
    let rec = Arc::new(FlightRecorder::new(1, 1024));
    let w = SpanWriter::new(Arc::clone(&rec), 0);
    for i in 0..2048u64 {
        w.record(SpanEvent::marker(SpanKind::DecodeStep, i, w.now_us()));
    }
    let before = alloc_count();
    for i in 0..1024u64 {
        w.record(SpanEvent::marker(SpanKind::DecodeStep, i, w.now_us()));
    }
    let warm_ring_allocs = alloc_count() - before;

    // (3) End-to-end: same pool, same schedule, recorder off vs on. Best
    // of 3 damps scheduler noise; the serving step (numerics + pricing +
    // arena charge) dwarfs one struct store per token.
    let best = |on: bool| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let rec = on.then(|| Arc::new(FlightRecorder::for_pool(1, 16 * 1024)));
            best = best.min(serve_us_per_token(rec));
        }
        best
    };
    let us_off = best(false);
    let us_on = best(true);
    let overhead_pct = (us_on / us_off - 1.0) * 100.0;

    table(
        &["configuration", "µs/token", "allocs"],
        &[
            vec![
                "tracing disabled".to_string(),
                format!("{us_off:.2}"),
                disabled_allocs.to_string(),
            ],
            vec!["tracing enabled".to_string(), format!("{us_on:.2}"), "-".to_string()],
            vec![
                "overhead".to_string(),
                format!("{overhead_pct:+.1}%"),
                format!("warm ring: {warm_ring_allocs}"),
            ],
        ],
    );

    // Fold the gate's numbers into BENCH_5.json (written by the plan
    // section that runs just before this one).
    if let Ok(mut j) = Json::from_file("BENCH_5.json") {
        if let Json::Obj(m) = &mut j {
            m.insert("tracing_us_per_token_off".to_string(), Json::num(us_off));
            m.insert("tracing_us_per_token_on".to_string(), Json::num(us_on));
            m.insert("tracing_overhead_pct".to_string(), Json::num(overhead_pct));
            m.insert(
                "tracing_disabled_allocs".to_string(),
                Json::num(disabled_allocs as f64),
            );
            m.insert(
                "tracing_warm_ring_allocs".to_string(),
                Json::num(warm_ring_allocs as f64),
            );
        }
        j.to_file("BENCH_5.json").expect("rewrite BENCH_5.json");
    }

    if smoke {
        assert_eq!(
            disabled_allocs, 0,
            "disabled tracing must add zero steady-state allocations"
        );
        assert_eq!(warm_ring_allocs, 0, "warm-ring recording must be allocation-free");
        // 5% relative plus 2 µs/token absolute: the relative bar is the
        // contract; the absolute floor keeps a sub-40 µs/token tiny-model
        // run from failing on scheduler jitter alone.
        assert!(
            us_on <= us_off * 1.05 + 2.0,
            "tracing overhead over budget: {us_off:.2} -> {us_on:.2} us/token ({overhead_pct:+.1}%)"
        );
        println!(
            "[ci-smoke] tracing gate OK: {us_off:.2} -> {us_on:.2} us/token ({overhead_pct:+.1}%)"
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        decode_step_plan_section(true);
        tracing_overhead_section(true);
        return;
    }
    let hw = HwConfig::default();
    banner("L3 hot-path microbenchmarks");
    let mut rows = Vec::new();

    // 1. simulator: ops/s on the biggest program.
    let m = ModelConfig::bert_large();
    let prog = build_program(&m, 128, 1);
    let opts = SimOptions::paper(&hw);
    let n_ops = prog.ops.len();
    let r = bench("simulate bert-large pass", 3, 30, || {
        std::hint::black_box(simulate(&hw, &prog, &opts));
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(n_ops as f64 / (r.mean_ns / 1e9), "ops/s"),
    ]);

    // 2. program build.
    let r = bench("build_program bert-large", 3, 30, || {
        std::hint::black_box(build_program(&m, 128, 1));
    });
    rows.push(vec![r.name.clone(), format!("{:.1} µs", r.mean_us()), "-".into()]);

    // 3. batcher decision latency.
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..4096)
        .map(|i| Request::new(i, rng.range(1, 128), Vec::new()))
        .collect();
    let r = bench("batcher push (4096 reqs)", 3, 50, || {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        for req in &reqs {
            std::hint::black_box(b.push(req.clone()).unwrap());
        }
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        format!("{:.0} ns/req", r.mean_ns / 4096.0),
    ]);

    // 4. codecs on a bert-large-shaped W_D slab.
    let mut rng = Rng::new(2);
    let rank = 640usize;
    let cols = 1024usize;
    let nnz = 84usize;
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for _ in 0..cols {
        let mut rs = rng.sample_distinct(rank, nnz);
        rs.sort_unstable();
        for r in rs {
            idx.push(r as u16);
            val.push(rng.normal_f32());
        }
    }
    let sp = CscFixed { rows: rank, cols, nnz_per_col: nnz, idx, val };
    let codec = DeltaCodec::new(5, rank).unwrap();
    let nz_bytes = sp.nnz() as f64;
    let r = bench("delta encode W_D (86k nz)", 3, 30, || {
        std::hint::black_box(codec.encode(&sp).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "idx/s"),
    ]);
    let enc = codec.encode(&sp).unwrap();
    let r = bench("delta decode W_D", 3, 30, || {
        std::hint::black_box(codec.decode(&enc, rank, cols, nnz).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "idx/s"),
    ]);

    let uq = UniformQuant::fit(&sp.val, 6).unwrap();
    let r = bench("uniform 6b encode (86k vals)", 3, 30, || {
        std::hint::black_box(uq.encode(&sp.val).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.1} µs", r.mean_us()),
        si(nz_bytes / (r.mean_ns / 1e9), "val/s"),
    ]);

    let ws = Mat::randn(1024, 640, &mut rng);
    let q = NonUniformQuant::fit(&ws.data[..20000], 4, 20).unwrap();
    let r = bench("nonuniform 4b encode W_S (655k)", 2, 10, || {
        std::hint::black_box(q.encode(&ws).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.0} µs", r.mean_us()),
        si(ws.data.len() as f64 / (r.mean_ns / 1e9), "elem/s"),
    ]);

    // 5. reference matmul (functional-mode numerics).
    let a = Mat::randn(128, 1024, &mut rng);
    let b = Mat::randn(1024, 640, &mut rng);
    let flops = 2.0 * 128.0 * 1024.0 * 640.0;
    let r = bench("Mat::matmul 128x1024x640", 2, 10, || {
        std::hint::black_box(a.matmul(&b).unwrap());
    });
    rows.push(vec![
        r.name.clone(),
        format!("{:.0} µs", r.mean_us()),
        si(flops / (r.mean_ns / 1e9), "FLOP/s"),
    ]);

    table(&["benchmark", "mean", "throughput"], &rows);

    decode_step_plan_section(false);
    tracing_overhead_section(false);
}
