//! fig-decode — autoregressive decode: µs/token, µJ/token, and token-level
//! continuous batching through the serving pool.
//!
//! The paper's headline metrics (68–567 µs/token, 0.41–3.95 µJ/token) are
//! decode-side numbers. This bench reports them three ways:
//!
//! 1. **Per-step sweep** — one decode step (`build_decode_step`) across KV
//!    depths and batch widths for the encoder-decoder workloads: modeled
//!    µs/token, µJ/token and EMA/token, showing batching amortize the
//!    per-step W_D stream.
//! 2. **Full generation via the resumable `Stepper`** — prefill + T decode
//!    steps through ONE persistent executor state: end-to-end latency and
//!    the per-token mean the chip would sustain.
//! 3. **Serving-pool decode** — generate requests through the multi-worker
//!    pool (reference backend): host-side tokens/s plus the pool's
//!    `us_per_token` p50/p95, with token-level continuous batching live.
//!
//! `--test` (CI smoke): one quick configuration of each part.
//! `--kv-quant fp16|int8|int4` / `--kv-pages N` set the KV arena the pool
//! section decodes against (fig9_kv sweeps these systematically).
//! `--gen-len N` sets the full-generation sweep length — the sweep runs on
//! the compiled step-plan path, so its harness wall time grows linearly in
//! N instead of superlinearly (the rebuild-per-token path re-built and
//! re-walked a program whose attention grows with depth); one exact-path
//! column cross-checks the plan at the final depth.

use std::time::{Duration, Instant};
use trex::bench_util::{arg_value, banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, PoolConfig, Server, TraceGenerator,
};
use trex::kv::KvQuant;
use trex::model::{build_decode_step, build_program};
use trex::runtime::ArtifactSet;
use trex::sim::{simulate, GbBudget, SimOptions, StepPlan, Stepper};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let quant = KvQuant::parse(&arg_value("--kv-quant").unwrap_or_else(|| "fp16".to_string()))
        .expect("--kv-quant fp16|int8|int4");
    let pages: Option<usize> = arg_value("--kv-pages").map(|s| s.parse().expect("--kv-pages N"));
    let gen_len: Option<usize> = arg_value("--gen-len").map(|s| s.parse().expect("--gen-len N"));
    per_step_sweep(smoke);
    full_generation(smoke, quant, gen_len);
    pool_decode(smoke, quant, pages);
}

fn opts_for(hw: &HwConfig, m: &ModelConfig) -> SimOptions {
    SimOptions { act_bits: m.act_bits, ..SimOptions::paper(hw) }
}

fn per_step_sweep(smoke: bool) {
    let hw = HwConfig::default();
    banner("fig-decode: one autoregressive step (µs/token, µJ/token, EMA/token)");
    let models: &[&str] = if smoke { &["s2t-small"] } else { &["s2t-small", "nmt-rdrop"] };
    let pasts: &[usize] = if smoke { &[32] } else { &[8, 32, 64, 127] };
    let mut rows = Vec::new();
    for name in models {
        let m = ModelConfig::preset(name).unwrap();
        let opts = opts_for(&hw, &m);
        for &past in pasts {
            for batch in [1usize, 4] {
                let s = simulate(&hw, &build_decode_step(&m, past, batch), &opts);
                rows.push(vec![
                    name.to_string(),
                    format!("{past}"),
                    format!("{batch}"),
                    format!("{:.0}", s.us_per_token()),
                    format!("{:.2}", s.uj_per_token()),
                    format!("{:.0}", s.ema_bytes() as f64 / s.tokens as f64 / 1024.0),
                ]);
            }
        }
    }
    table(
        &["workload", "past_len", "batch", "µs/token", "µJ/token", "EMA KiB/token"],
        &rows,
    );
    println!(
        "\npaper: 68–567 µs/token and 0.41–3.95 µJ/token across decode workloads.\n\
         Per-step cost is dominated by the per-layer W_D stream, which batching\n\
         splits across streams — the decode-side form of the Fig. 23.1.4 claim."
    );
}

fn full_generation(smoke: bool, quant: KvQuant, gen_len: Option<usize>) {
    let hw = HwConfig::default();
    banner("fig-decode: full generation through one persistent Stepper (plan path)");
    let gen_tokens = gen_len.unwrap_or(if smoke { 8 } else { 64 }).max(1);
    let prompt = 32;
    let mut rows = Vec::new();
    for batch in [1usize, 4] {
        let m = ModelConfig::s2t_small();
        let opts = opts_for(&hw, &m);
        // The decode chain runs on the compiled plan: harness time per
        // token is O(phases), so the sweep's wall cost is linear in
        // --gen-len (the rebuild path re-built + re-walked every op per
        // token, superlinear once attention deepens).
        let plan = StepPlan::compile_fixed(&hw, &m, batch, &opts);
        let mut stepper = Stepper::new(&hw, opts);
        stepper.run_program(&build_program(&m, prompt, batch));
        let prefill_cycles = stepper.clock_cycles();
        // Time the decode loop only — the column demonstrates that the
        // plan path's harness cost is linear in --gen-len, so the O(ops)
        // prefill walk must not dilute it.
        let t_host = Instant::now();
        for t in 0..gen_tokens {
            stepper.run_plan(&plan, prompt + t);
        }
        let host_ms = t_host.elapsed().as_secs_f64() * 1e3;
        let stats = stepper.finish();
        let total_us = stats.seconds() * 1e6;
        let decode_cycles = (stats.cycles - prefill_cycles) as f64;
        let decode_us = decode_cycles / (stats.point.freq_mhz * 1e6) * 1e6;
        let decoded = (gen_tokens * batch) as f64;
        // Decode-only energy: a standalone prefill run replays the chain's
        // prefill exactly (same ops from a fresh state, idle linear in
        // cycles), so the subtraction isolates the decode phase.
        let prefill = simulate(&hw, &build_program(&m, prompt, batch), &opts);
        let decode_uj = stats.energy.total_uj() - prefill.energy.total_uj();
        // Exact-path cross-check column: one rebuilt step at the final
        // depth must price identically to the plan's replay of it.
        let last = prompt + gen_tokens - 1;
        let exact = simulate(&hw, &build_decode_step(&m, last, batch), &opts);
        let planned = {
            let mut s = Stepper::new(&hw, opts);
            s.run_plan(&plan, last);
            s.finish()
        };
        assert_eq!(planned.cycles, exact.cycles, "plan/exact mismatch at depth {last}");
        assert_eq!(planned.ema_bytes(), exact.ema_bytes(), "plan/exact EMA at depth {last}");
        rows.push(vec![
            format!("{batch}"),
            format!("{prompt}+{gen_tokens}"),
            format!("{total_us:.0}"),
            format!("{:.0}", decode_us / decoded),
            format!("{:.2}", decode_uj / decoded),
            format!("{:.1}%", stats.utilization(&hw) * 100.0),
            format!("{host_ms:.1}"),
            format!("{:.0}", exact.us_per_token()),
        ]);
    }
    table(
        &[
            "streams",
            "prompt+gen",
            "total µs",
            "decode µs/token",
            "decode µJ/token",
            "util",
            "host ms (plan)",
            "exact µs/tok @final",
        ],
        &rows,
    );
    let cap = GbBudget::max_decode_len_quant(&hw, &ModelConfig::s2t_small(), 4, quant);
    println!(
        "\nKV residency ({}): s2t-small keeps a {cap}-token prefix resident four-up\n\
         in the 4 MiB GB; admission caps generation there instead of rejecting.",
        quant.name()
    );
}

fn pool_decode(smoke: bool, quant: KvQuant, pages: Option<usize>) {
    banner("fig-decode: serving-pool decode (reference backend)");
    let max_seq = 32;
    let d_model = 128;
    let n = if smoke { 16 } else { 200 };
    let gen_tokens = if smoke { 4 } else { 16 };
    let workers: &[usize] = if smoke { &[2] } else { &[1, 4] };
    let mut rows = Vec::new();
    for &w in workers {
        let hw = HwConfig::default();
        let pm = ModelConfig::s2t_small();
        // Engine-side KV arena only (no pool admission bound): this bench's
        // client submits its whole trace up front and expects zero sheds —
        // fig9_kv exercises the pool-wide admission/eviction story.
        let handle = Server::start_pool(
            move |ctx| {
                let set = ArtifactSet::reference("pool-decode", d_model, max_seq)?;
                Engine::for_worker(
                    set,
                    EngineConfig {
                        hw: hw.clone(),
                        perf_model: pm.clone(),
                        self_test: false,
                        kv_quant: quant,
                        kv_pages: pages,
                    },
                    ctx,
                )
            },
            PoolConfig {
                workers: w,
                queue_depth: 0,
                max_inflight: 0,
                batcher: BatcherConfig { max_seq, max_wait: Duration::from_micros(200) },
                ..PoolConfig::default()
            },
        );
        let t0 = std::time::Instant::now();
        let reqs = TraceGenerator::mixed(max_seq, d_model, 0xDEC0)
            .with_generate(gen_tokens)
            .take(n);
        for r in reqs {
            handle.submit(r).expect("unbounded pool rejects nothing");
        }
        let mut got = 0;
        while got < n {
            handle
                .responses
                .recv_timeout(Duration::from_secs(60))
                .expect("pool must answer every request");
            got += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        let streamed = handle.tokens.try_iter().count();
        let report = handle.shutdown().expect("clean shutdown");
        assert_eq!(report.metrics.completed(), n as u64);
        assert_eq!(report.metrics.tokens_decoded(), streamed as u64);
        assert!(streamed > 0, "decode traffic must stream tokens");
        let j = report.json();
        let p50 = j.get("us_per_token_p50").unwrap().as_f64().unwrap();
        let p95 = j.get("us_per_token_p95").unwrap().as_f64().unwrap();
        let steps = j.get("decode_steps").unwrap().as_f64().unwrap();
        rows.push(vec![
            format!("{w}"),
            format!("{n}"),
            format!("{streamed}"),
            format!("{steps:.0}"),
            format!("{:.1}", streamed as f64 / steps.max(1.0)),
            format!("{:.0}", streamed as f64 / wall),
            format!("{p50:.0}"),
            format!("{p95:.0}"),
        ]);
    }
    table(
        &[
            "workers",
            "requests",
            "tokens",
            "decode steps",
            "tokens/step",
            "host tok/s",
            "µs/token p50",
            "µs/token p95",
        ],
        &rows,
    );
    println!(
        "\ntokens/step > 1 is continuous batching at work: streams at different\n\
         KV depths share steps, so the modeled µs/token falls toward the\n\
         batched column of the per-step sweep above."
    );
}
