//! Fig. 23.1.5 — two-direction-accessible register files (TRFs).
//!
//! Compares each workload with TRFs (cross-direction tile access hidden
//! behind compute) against conventional single-direction SRAM buffers
//! (transposing re-access + element-serial C-C stores stall the PEs).
//! Paper: TRFs improve utilization 12–20%.

use trex::bench_util::{banner, ratio, table};
use trex::config::{HwConfig, ModelConfig, WORKLOADS};
use trex::model::build_program;
use trex::sim::{simulate, SimOptions};

fn main() {
    let hw = HwConfig::default();
    banner("Fig 23.1.5: TRF vs single-direction SRAM buffers");
    let mut rows = Vec::new();
    for name in WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        let prog = build_program(&m, m.max_seq, 1);
        let on = simulate(
            &hw,
            &prog,
            &SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) },
        );
        let off = simulate(
            &hw,
            &prog,
            &SimOptions { trf: false, act_bits: m.act_bits, ..SimOptions::paper(&hw) },
        );
        rows.push(vec![
            name.to_string(),
            format!("{:.1}%", off.utilization(&hw) * 100.0),
            format!("{:.1}%", on.utilization(&hw) * 100.0),
            ratio(on.utilization(&hw) / off.utilization(&hw)),
            format!("{}", off.trf_stall_cycles),
            format!("{:.1}%", off.trf_stall_cycles as f64 / off.cycles as f64 * 100.0),
        ]);
    }
    rows.push(vec![
        "paper".into(),
        "-".into(),
        "-".into(),
        "1.12-1.20x".into(),
        "-".into(),
        "-".into(),
    ]);
    table(
        &["workload", "util (SRAM)", "util (TRF)", "gain", "stall cycles", "stall share"],
        &rows,
    );

    banner("stall anatomy: where single-direction buffers lose cycles");
    // One projection: X (C-C load), W_S (R-R), Y stored C-C for the SMM.
    let m = ModelConfig::bert_large();
    let mut rows = Vec::new();
    for (label, seq) in [("full plane (128 tokens)", 128usize), ("short input (32)", 32)] {
        let prog = build_program(&m, seq, 1);
        let off = simulate(
            &hw,
            &prog,
            &SimOptions { trf: false, ..SimOptions::paper(&hw) },
        );
        rows.push(vec![
            label.to_string(),
            format!("{}", off.cycles),
            format!("{}", off.trf_stall_cycles),
            format!("{:.1}%", off.trf_stall_cycles as f64 / off.cycles as f64 * 100.0),
        ]);
    }
    table(&["case", "total cycles", "buffer stalls", "share"], &rows);
}
