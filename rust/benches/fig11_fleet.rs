//! fig11-fleet — disaggregated heterogeneous fleet vs a homogeneous one
//! at equal chip count: does splitting roles across operating points buy
//! tokens/s/W?
//!
//! The paper's fig. 7 VDD/frequency sweep makes the two phases want
//! different chips: prefill is a throughput-bound batch pass (run it at
//! max VDD), while a decode step is one token of work whose energy scales
//! with the operating point (~2.9× fewer nJ/cycle at 0.45 V than at
//! 0.85 V). A disaggregated fleet prefills on max-VDD chips and decodes
//! on low-VDD chips, paying a priced KV migration (DRAM stall + EMA
//! energy at the source's operating point) to move each stream between
//! arenas — with shared prefix chains streaming **once per chain**, not
//! once per mate.
//!
//! Two four-chip fleets face the same closed-loop decode-heavy workload:
//!
//! * **split**: 2× prefill\@0.85 V + 2× decode\@0.45 V;
//! * **homogeneous**: 4× general\@0.85 V (same placement machinery, same
//!   migrations — only the decode operating point differs).
//!
//! Efficiency is tokens per total modeled µJ, which is tokens/s/W.
//!
//! `--test` (CI smoke): small run; asserts the split fleet beats the
//! homogeneous one on tokens/µJ, that migrations actually fired with
//! chains attaching warm for follower mates, that each shared chain is
//! charged exactly once (deterministic two-arena sub-check), and that
//! every chip's arena drains clean under the lifecycle ledger.

use std::sync::Arc;
use std::time::Duration;
use trex::bench_util::{banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{BatcherConfig, Engine, EngineConfig, PoolConfig, Request, Server};
use trex::fleet::{ChipRole, ChipSpec, Fleet};
use trex::kv::{prefix_id, KvArenaConfig, KvManager, KvQuant};
use trex::runtime::ArtifactSet;

const MAX_SEQ: usize = 32;
const D: usize = 64;
const PROMPT: usize = 8;
const GEN: usize = 6;
const GROUPS: usize = 6;

struct FleetOutcome {
    tokens: u64,
    chip_uj: f64,
    migrations: u64,
    chain_migrations: u64,
    migrated_bytes: u64,
}

impl FleetOutcome {
    /// Tokens per modeled µJ — dimensionally tokens/s/W.
    fn tokens_per_uj(&self) -> f64 {
        self.tokens as f64 / self.chip_uj.max(1e-9)
    }
}

/// Run `n` shared-prefix generate requests closed-loop against a fleet and
/// account tokens + modeled energy from the responses (migration charges
/// included — the split fleet must win *after* paying for its moves).
fn run_fleet(specs: Vec<ChipSpec>, n: usize) -> FleetOutcome {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let fleet =
        Arc::new(Fleet::build(specs, &hw, &pm, KvQuant::Fp16).expect("fleet build"));
    let pool = PoolConfig {
        fleet: Some(Arc::clone(&fleet)),
        lifecycle_ledger: true,
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::from_micros(200) },
        ..PoolConfig::default()
    };
    let hw2 = hw.clone();
    let pm2 = pm.clone();
    let handle = Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("fig11f", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw2.clone(),
                    perf_model: pm2.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    );
    let metrics = Arc::clone(&handle.metrics);

    let mut tokens = 0u64;
    let mut uj = 0.0f64;
    let mut got = 0usize;
    let mut account = |resp: &trex::coordinator::Response| {
        tokens += resp.tokens_generated as u64;
        uj += resp.chip_uj;
    };
    for i in 0..n {
        let mut req = Request::new(i as u64, PROMPT, vec![0.1; PROMPT * D])
            .with_generate(GEN)
            .with_prefix_group(prefix_id(&format!("fleet-g{}", i % GROUPS)));
        // Backpressure-aware closed loop: on rejection, drain a response
        // and retry — offered load self-throttles to fleet capacity, so
        // both fleets complete every token and the comparison is energy.
        loop {
            match handle.try_submit(req) {
                Ok(()) => break,
                Err((r, _)) => {
                    req = r;
                    if let Ok(resp) = handle.responses.recv_timeout(Duration::from_millis(50))
                    {
                        account(&resp);
                        got += 1;
                    }
                }
            }
        }
    }
    while got < n {
        let resp = handle.responses.recv_timeout(Duration::from_secs(60)).expect("drain");
        account(&resp);
        got += 1;
    }
    drop(account);
    let _ = handle.tokens.try_iter().count();
    handle.shutdown().expect("clean shutdown");
    assert!(
        metrics.ledger_audit().is_some_and(|a| a.conserved()),
        "lifecycle ledger must balance after the drain"
    );

    let (mut migrations, mut chain_migrations, mut migrated_bytes) = (0u64, 0u64, 0u64);
    for chip in &fleet.chips {
        let residual = chip.kv.residual();
        assert!(
            residual.is_clean(),
            "chip '{}' holds KV residual after drain: {residual:?}",
            chip.spec.id
        );
        let s = chip.kv.stats();
        migrations += s.migrations;
        chain_migrations += s.chain_migrations;
        migrated_bytes += s.migrated_bytes;
    }
    FleetOutcome { tokens, chip_uj: uj, migrations, chain_migrations, migrated_bytes }
}

/// Deterministic two-arena check of the pricing rule the fleet relies on:
/// a shared prefix chain streams to the target chip exactly once — the
/// first mate pays it, every follower attaches warm and pays only its
/// private KV.
fn assert_chain_migrates_once() {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let mk = || {
        KvManager::new(&hw, &pm, KvArenaConfig::for_pool(&hw, &pm, KvQuant::Fp16, Some(64)))
    };
    let (src, dst) = (mk(), mk());
    let g = prefix_id("sys-prompt");
    src.register(1, PROMPT, Some(g));
    src.register(2, PROMPT, Some(g));

    let m1 = src.migrate_out(1).expect("stream 1 held on source");
    assert!(m1.shared_bytes > 0, "shared prompt must ride the chain");
    let moved1 = dst.migrate_in(1, &m1);
    assert!(moved1 >= m1.shared_bytes, "first mate pays the chain");

    let m2 = src.migrate_out(2).expect("stream 2 held on source");
    let moved2 = dst.migrate_in(2, &m2);
    assert_eq!(moved2, m2.private_bytes, "follower mate pays no chain bytes");
    assert_eq!(dst.stats().migrations, 2);
    assert_eq!(dst.stats().chain_migrations, 1, "chain charged exactly once");

    dst.release(1);
    dst.release(2);
    assert!(src.residual().is_clean(), "{:?}", src.residual());
    assert!(dst.residual().is_clean(), "{:?}", dst.residual());
}

fn row(name: &str, r: &FleetOutcome) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{}", r.tokens),
        format!("{:.1}", r.chip_uj),
        format!("{:.3}", r.tokens_per_uj()),
        format!("{}", r.migrations),
        format!("{}", r.chain_migrations),
        format!("{:.1}", r.migrated_bytes as f64 / 1024.0),
    ]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    banner("fig11-fleet: split prefill/decode fleet vs homogeneous, equal chip count");

    let n = if smoke { 48 } else { 240 };
    println!(
        "{n} requests x ({PROMPT}-token shared prompt + {GEN} decode tokens), \
         {GROUPS} prefix groups, closed loop\n"
    );

    let split = run_fleet(
        vec![
            ChipSpec::with_role("p0", ChipRole::Prefill, 0.85),
            ChipSpec::with_role("p1", ChipRole::Prefill, 0.85),
            ChipSpec::with_role("d0", ChipRole::Decode, 0.45),
            ChipSpec::with_role("d1", ChipRole::Decode, 0.45),
        ],
        n,
    );
    let homog = run_fleet(
        vec![
            ChipSpec::general("g0", 0.85),
            ChipSpec::general("g1", 0.85),
            ChipSpec::general("g2", 0.85),
            ChipSpec::general("g3", 0.85),
        ],
        n,
    );

    table(
        &[
            "fleet (4 chips)",
            "tokens",
            "total uJ",
            "tok/uJ",
            "migrations",
            "chain moves",
            "moved KiB",
        ],
        &[
            row("split 2xP@0.85 + 2xD@0.45", &split),
            row("homogeneous 4xG@0.85", &homog),
        ],
    );
    println!(
        "\nSame placement machinery, same migrations — the split fleet's decode\n\
         steps run at 0.45 V, so every generated token costs ~2.9x fewer nJ per\n\
         cycle. tokens/uJ is tokens/s/W: role-splitting buys efficiency at equal\n\
         chip count, after paying the (chain-deduplicated) migration bill."
    );

    // Acceptance (CI smoke).
    assert_chain_migrates_once();
    assert!(split.tokens > 0, "split fleet generated no tokens");
    assert!(split.migrations > 0, "prefill->decode handoff must migrate streams");
    assert!(split.chain_migrations >= 1, "shared chains must migrate");
    assert!(
        split.chain_migrations < split.migrations,
        "follower mates must attach warm: {} chain moves vs {} migrations",
        split.chain_migrations,
        split.migrations
    );
    assert!(
        split.tokens_per_uj() > homog.tokens_per_uj(),
        "split fleet must beat homogeneous on tokens/s/W at equal chip count: \
         {:.3} vs {:.3} tok/uJ",
        split.tokens_per_uj(),
        homog.tokens_per_uj()
    );
    println!(
        "\nfig11-fleet OK: {:.3} tok/uJ (split) vs {:.3} tok/uJ (homogeneous), \
         {} migrations / {} chain moves",
        split.tokens_per_uj(),
        homog.tokens_per_uj(),
        split.migrations,
        split.chain_migrations
    );
}
