//! fig9-kv — the paged KV-cache manager: quantized residency, aggregate
//! arena pressure, and depth-bucketed decode grouping.
//!
//! Three sections:
//!
//! 1. **Residency table** — per-token KV bytes, the GB residency cap
//!    (`max_decode_len_quant`) and the derived arena size for each
//!    quantization mode: the cap roughly doubles fp16 → int8 → int4, minus
//!    the dequant scratch.
//! 2. **Arena pressure** — 8 concurrent decode streams over an arena sized
//!    to hold only *half* the fleet at full precision, stepped round-robin
//!    through one persistent `Stepper` with the `KvManager` charging
//!    swap-ins and dequant. Per-token EMA for fp16 vs int8 vs int4: fp16
//!    thrashes (every rejoin re-streams its whole KV), int4 stays resident
//!    and pays only the dequant overhead — the residency-relief-vs-dequant
//!    trade the ROADMAP asked to measure.
//! 3. **Grouping policies** — the serving pool decoding the same staggered
//!    trace under greedy vs depth-bucketed regrouping, with the new
//!    `pad_waste_tokens` metric making the bucketing win measurable.
//! 4. **Prefix sharing** — N streams over K≪N shared prompts, with and
//!    without `prefix_group` tags: the radix index keeps arena occupancy
//!    near the K-unique-prefix ideal while the no-share baseline grows
//!    O(N), warm-prefix rejoins skip the swap-in charge, and unaligned
//!    prefixes COW-fork their tail page.
//!
//! `--test` (CI smoke): quick configuration of each part, with the
//! deterministic section-2 and section-4 invariants asserted.
//! `--kv-quant MODE` restricts section 2; `--kv-pages N` overrides its
//! arena size.

use std::time::Duration;
use trex::bench_util::{arg_value, banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, DecodePolicy, Engine, EngineConfig, PoolConfig, Request, Server,
};
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::model::build_decode_step;
use trex::runtime::ArtifactSet;
use trex::sim::{GbBudget, SimOptions, Stepper};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let only: Option<KvQuant> =
        arg_value("--kv-quant").map(|s| KvQuant::parse(&s).expect("--kv-quant fp16|int8|int4"));
    let pages: Option<usize> = arg_value("--kv-pages").map(|s| s.parse().expect("--kv-pages N"));
    residency_table();
    arena_pressure(smoke, only, pages);
    grouping_policies(smoke);
    prefix_sharing(smoke);
}

fn residency_table() {
    let hw = HwConfig::default();
    banner("fig9-kv: quantized KV residency (per-token bytes, caps, arena)");
    let mut rows = Vec::new();
    for name in ["s2t-small", "nmt-rdrop", "tiny"] {
        let m = ModelConfig::preset(name).unwrap();
        for quant in KvQuant::ALL {
            let per_tok = GbBudget::kv_cache_bytes_quant(&m, 1, 4, quant);
            let cap1 = GbBudget::max_decode_len_quant(&hw, &m, 1, quant);
            let cap4 = GbBudget::max_decode_len_quant(&hw, &m, 4, quant);
            let arena = KvArenaConfig::for_pool(&hw, &m, quant, None);
            rows.push(vec![
                name.to_string(),
                quant.name().to_string(),
                format!("{per_tok}"),
                format!("{cap1}"),
                format!("{cap4}"),
                format!("{}", arena.capacity_pages),
            ]);
        }
    }
    table(&["workload", "kv", "B/token (4-up)", "cap b1", "cap b4", "arena pages"], &rows);
    println!(
        "\nThe resident prefix roughly doubles per halving of the storage\n\
         width — minus the dequant scratch int8/int4 add to the residents."
    );
}

fn arena_pressure(smoke: bool, only: Option<KvQuant>, pages_override: Option<usize>) {
    let hw = HwConfig::default();
    let m = ModelConfig::s2t_small();
    let streams = 8usize;
    let prefill = 16usize;
    let steps: usize = if smoke { 12 } else { 48 };
    banner("fig9-kv: aggregate arena pressure (8 streams, arena = half the fp16 fleet)");
    // Same page budget for every mode — the hardware doesn't grow with the
    // codec. Sized to hold half the fleet's *fp16* KV at final depth, so
    // full precision must thrash while int4 stays fully resident.
    let final_past = prefill + steps;
    let fleet_fp16 = GbBudget::kv_cache_bytes_quant(&m, final_past, streams, KvQuant::Fp16)
        + streams as u64 * GbBudget::cross_kv_bytes_quant(&m, 1, KvQuant::Fp16);
    let pages =
        pages_override.unwrap_or(((fleet_fp16 / 2) / hw.kv_page_bytes as u64) as usize).max(1);
    let mut rows = Vec::new();
    for quant in KvQuant::ALL {
        if let Some(q) = only {
            if q != quant {
                continue;
            }
        }
        let mut cfg = KvArenaConfig::for_pool(&hw, &m, quant, Some(pages));
        cfg.admit_oversub = 16.0; // admission is section 3's story
        let mgr = KvManager::new(&hw, &m, cfg);
        let opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
        let mut stepper = Stepper::new(&hw, opts);
        for id in 0..streams {
            mgr.register(id as u64, prefill, None);
        }
        let mut pasts = vec![prefill; streams];
        for _step in 0..steps {
            for g in 0..streams / 4 {
                let members: Vec<(u64, usize)> =
                    (0..4).map(|k| ((g * 4 + k) as u64, pasts[g * 4 + k])).collect();
                let charge = mgr.prepare_group(&members);
                let max_past = members.iter().map(|&(_, p)| p).max().unwrap();
                stepper.charge_kv_swap(charge.swap_in_bytes);
                stepper.set_kv_dequant_bytes_per_layer(mgr.dequant_bytes_per_layer(4, max_past));
                stepper.run_program(&build_decode_step(&m, max_past, 4));
                mgr.finish_group(&members);
                for k in 0..4 {
                    pasts[g * 4 + k] += 1;
                }
            }
        }
        let stats = stepper.finish();
        let kv = mgr.stats();
        let tokens = stats.tokens.max(1) as f64;
        rows.push(vec![
            quant.name().to_string(),
            format!("{pages}"),
            format!("{:.0}", stats.ema_bytes() as f64 / tokens / 1024.0),
            format!("{:.0}", stats.seconds() * 1e6 / tokens),
            format!("{:.2}", stats.energy.total_uj() / tokens),
            format!("{}", kv.swap_ins),
            format!("{}", kv.evictions),
            format!("{}", kv.peak_used_pages),
        ]);
        // Deterministic invariants (the CI smoke relies on these).
        if pages_override.is_none() {
            if quant == KvQuant::Fp16 {
                assert!(kv.swap_ins > 0, "fp16 must thrash the half-fleet arena: {kv:?}");
            }
            if quant == KvQuant::Int4 {
                assert_eq!(kv.swap_ins, 0, "int4 fleet fits resident: {kv:?}");
            }
            assert!(kv.peak_used_pages <= pages, "{kv:?} exceeds {pages} pages");
        }
    }
    table(
        &[
            "kv",
            "arena pages",
            "EMA KiB/token",
            "µs/token",
            "µJ/token",
            "swap-ins",
            "evictions",
            "peak pages",
        ],
        &rows,
    );
    println!(
        "\nfp16 pays swap-in EMA every time an evicted stream rejoins; int4\n\
         quarters the footprint, stays resident, and pays only the per-step\n\
         dequant — the residency-relief-vs-dequant trade, now measurable."
    );
}

fn grouping_policies(smoke: bool) {
    banner("fig9-kv: greedy vs depth-bucketed decode grouping (serving pool)");
    let max_seq = 32;
    let d = 64;
    let n = if smoke { 6u64 } else { 16 };
    let gen_tokens = if smoke { 12 } else { 48 };
    let mut rows = Vec::new();
    for (label, policy) in [
        ("greedy", DecodePolicy::Greedy),
        ("bucketed:8", DecodePolicy::DepthBucketed { bucket: 8 }),
    ] {
        let hw = HwConfig::default();
        let pm = ModelConfig::tiny();
        let handle = Server::start_pool(
            move |ctx| {
                let set = ArtifactSet::reference("fig9-group", d, max_seq)?;
                Engine::for_worker(
                    set,
                    EngineConfig {
                        hw: hw.clone(),
                        perf_model: pm.clone(),
                        self_test: false,
                        kv_quant: KvQuant::Fp16,
                        kv_pages: None,
                    },
                    ctx,
                )
            },
            PoolConfig {
                workers: 1, // deterministic alternation; staggered joins
                queue_depth: 0,
                max_inflight: 0,
                decode: policy,
                batcher: BatcherConfig { max_seq, max_wait: Duration::from_millis(0) },
                ..PoolConfig::default()
            },
        );
        // Staggered prefill lengths spread the streams' KV depths, so the
        // greedy regrouper forms mixed-depth groups and pads.
        for i in 0..n {
            let len = 2 + (i as usize % 4) * 2; // 2/4/6/8 → all B4-class
            let req = Request::new(i, len, vec![0.1; len * d]).with_generate(gen_tokens);
            handle.submit(req).expect("unbounded pool rejects nothing");
        }
        for _ in 0..n {
            handle
                .responses
                .recv_timeout(Duration::from_secs(60))
                .expect("pool must answer every request");
        }
        let report = handle.shutdown().expect("clean shutdown");
        assert_eq!(report.metrics.completed(), n);
        let j = report.json();
        let steps = j.get("decode_steps").unwrap().as_f64().unwrap().max(1.0);
        let tokens = j.get("tokens_decoded").unwrap().as_f64().unwrap();
        let pad = j.get("pad_waste_tokens").unwrap().as_f64().unwrap();
        let p50 = j.get("us_per_token_p50").unwrap().as_f64().unwrap();
        rows.push(vec![
            label.to_string(),
            format!("{steps:.0}"),
            format!("{:.2}", tokens / steps),
            format!("{pad:.0}"),
            format!("{:.2}", pad / steps),
            format!("{p50:.0}"),
        ]);
    }
    table(
        &["policy", "decode steps", "tokens/step", "pad waste", "pad/step", "µs/token p50"],
        &rows,
    );
    println!(
        "\nPad waste is the token-slots a step burns padding shallow streams\n\
         to its deepest member (∝ max−min past_len); depth-bucketed grouping\n\
         bounds it at bucket−1 per stream at some cost in group occupancy."
    );
}

fn prefix_sharing(smoke: bool) {
    use trex::kv::prefix_id;
    let hw = HwConfig::default();
    let m = ModelConfig::tiny();
    let k_groups = 4usize;
    let decode = if smoke { 4usize } else { 16 };
    banner("fig9-kv: prefix sharing (N streams over K=4 shared prompts)");
    // One probe manager just for the geometry (per-token bytes, page size):
    // a page-aligned prefill shares cleanly; an unaligned one must COW-fork.
    let probe = KvManager::new(&hw, &m, KvArenaConfig::for_pool(&hw, &m, KvQuant::Fp16, Some(4)));
    let ptb = probe.per_token_bytes();
    let pb = probe.config().page_bytes;
    let prefill = (1..=64)
        .find(|&p| (p as u64 * ptb) % pb == 0)
        .expect("some prefill under 64 tokens lands on a page line");
    let prefix_pages = ((prefill as u64 * ptb).div_ceil(pb)) as usize;

    let mut rows = Vec::new();
    let ns: &[usize] = if smoke { &[8, 16] } else { &[8, 16, 32, 64] };
    for &n in ns {
        let mut shared_peak = 0usize;
        let mut baseline_peak = 0usize;
        let mut hits = 0u64;
        let mut shared_gauge = 0usize;
        for share in [true, false] {
            // Generous arena: this section measures occupancy, not eviction.
            let mut cfg = KvArenaConfig::for_pool(&hw, &m, KvQuant::Fp16, Some(1 << 20));
            cfg.admit_oversub = 1e9;
            let mgr = KvManager::new(&hw, &m, cfg);
            for id in 0..n as u64 {
                let prefix = if share {
                    Some(prefix_id(&format!("sys-{}", id % k_groups as u64)))
                } else {
                    None
                };
                mgr.register(id, prefill, prefix);
            }
            let mut pasts = vec![prefill; n];
            for _ in 0..decode {
                for g in 0..n / 4 {
                    let members: Vec<(u64, usize)> =
                        (0..4).map(|k| ((g * 4 + k) as u64, pasts[g * 4 + k])).collect();
                    mgr.prepare_group(&members);
                    mgr.finish_group(&members);
                    for k in 0..4 {
                        pasts[g * 4 + k] += 1;
                    }
                }
            }
            let kv = mgr.stats();
            if share {
                shared_peak = kv.peak_used_pages;
                hits = kv.prefix_hits;
                shared_gauge = mgr.shared_pages();
                // Page-aligned prefixes never need the tail duplicated.
                assert_eq!(kv.cow_forks, 0, "aligned prefix must not fork: {kv:?}");
                // Warm-prefix latecomer: its prefill is already resident in
                // the chain, so registration + first step charge no swap-in.
                let swaps_before = kv.swap_ins;
                let late = n as u64 + 1;
                mgr.register(late, prefill, Some(prefix_id("sys-0")));
                let charge = mgr.prepare_group(&[(late, prefill)]);
                assert_eq!(charge.swap_in_bytes, 0, "warm prefix charged a swap-in");
                mgr.finish_group(&[(late, prefill)]);
                assert_eq!(mgr.stats().swap_ins, swaps_before, "warm prefix swap-in counted");
                mgr.release(late);
            } else {
                baseline_peak = kv.peak_used_pages;
            }
            for id in 0..n as u64 {
                mgr.release(id);
            }
            let residual = mgr.residual();
            assert!(residual.is_clean(), "leaked after drain: {residual:?}");
        }
        // K-unique-prefix ideal: K prefix chains + every stream's private
        // decode tail (the arena floors a live stream at one page).
        let priv_pages = ((decode as u64 * ptb).div_ceil(pb) as usize).max(1);
        let ideal = k_groups * prefix_pages + n * priv_pages;
        assert!(
            shared_peak as f64 <= 1.5 * ideal as f64,
            "shared arena {shared_peak} pages exceeds 1.5x the {ideal}-page ideal (n={n})"
        );
        assert!(
            baseline_peak >= n * prefix_pages,
            "no-share baseline {baseline_peak} pages is not O(N) in the prefix (n={n})"
        );
        assert!(shared_peak < baseline_peak, "sharing must beat the baseline (n={n})");
        rows.push(vec![
            format!("{n}"),
            format!("{ideal}"),
            format!("{shared_peak}"),
            format!("{baseline_peak}"),
            format!("{shared_gauge}"),
            format!("{hits}"),
            format!("{:.2}x", baseline_peak as f64 / shared_peak as f64),
        ]);
    }
    table(
        &[
            "streams",
            "ideal pages",
            "shared peak",
            "no-share peak",
            "shared gauge",
            "prefix hits",
            "saving",
        ],
        &rows,
    );

    // COW check: an unaligned prefix (partial tail page) forks exactly once
    // per stream that decodes past it, and never before.
    if let Some(unaligned) = (1..prefill).find(|&p| (p as u64 * ptb) % pb != 0) {
        let cfg = KvArenaConfig::for_pool(&hw, &m, KvQuant::Fp16, Some(1 << 20));
        let mgr = KvManager::new(&hw, &m, cfg);
        for id in 0..2u64 {
            mgr.register(id, unaligned, Some(prefix_id("cow")));
        }
        let at_depth = [(0u64, unaligned), (1u64, unaligned)];
        mgr.prepare_group(&at_depth);
        mgr.finish_group(&at_depth);
        assert_eq!(mgr.stats().cow_forks, 0, "no fork while inside the prefix");
        let past_it = [(0u64, unaligned + 1), (1u64, unaligned + 1)];
        mgr.prepare_group(&past_it);
        mgr.finish_group(&past_it);
        assert_eq!(mgr.stats().cow_forks, 2, "each stream forks the partial tail page once");
        mgr.release(0);
        mgr.release(1);
        assert!(mgr.residual().is_clean());
        println!(
            "\nCOW: prefill {unaligned} straddles a page; both streams forked the\n\
             partial tail exactly once on decoding past it."
        );
    }
    println!(
        "\nArena occupancy grows with unique prompt tokens, not stream count:\n\
         K chains back every mate's prefill while each stream pays only its\n\
         own decode tail (plus the COW'd tail page when the prefix is not\n\
         page-aligned)."
    );
}
