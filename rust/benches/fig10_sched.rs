//! fig10-sched — chunked prefill interleaving + decode coalescing/priority
//! through the serving pool.
//!
//! T-REX's dynamic batching keeps the PE array utilized by reshaping what
//! runs each pass. The serving-plane analogue is the scheduler: without it,
//! one long monolithic prefill monopolizes a worker while parked decode
//! streams stall behind it (head-of-line blocking), and streams that enter
//! decode at staggered times step *solo* — each paying the full per-step
//! W_D stream the paper's batching exists to amortize.
//!
//! The bench drives one worker with a mixed load — staggered generate
//! requests plus long B1 prefill-only blockers — under two scheduler
//! configurations:
//!
//! * **baseline (seed)**: monolithic prefill, zero coalescing window,
//!   FIFO decode — the pre-scheduler behavior;
//! * **chunk+coalesce+priority**: `prefill_chunk` phases per chunk,
//!   a decode coalescing window, near-done-first priority.
//!
//! With coalescing, early streams wait for mates and step 4-up, so the
//! modeled µs/token p95 drops toward the batched column of fig8's sweep;
//! with chunking, decode steps interleave between the blockers' chunks
//! (`interleave_ratio` > 0) instead of stalling a full pass.
//!
//! `--test` (CI smoke): small load, asserts decode `us_per_token_p95`
//! improves with the scheduler on vs off, and that chunked prefills
//! actually interleaved.

use std::time::{Duration, Instant};
use trex::bench_util::{banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{BatcherConfig, Engine, EngineConfig, PoolConfig, Request, Server};
use trex::kv::KvQuant;
use trex::runtime::ArtifactSet;
use trex::util::rng::Rng;

const MAX_SEQ: usize = 32;
const D: usize = 128;

struct SchedResult {
    p50: f64,
    p95: f64,
    decode_steps: f64,
    tokens: f64,
    interleave: f64,
    chunks: f64,
    coalesce_us: f64,
    wall_ms: f64,
}

struct Load {
    n_gen: usize,
    gen_tokens: usize,
    n_block: usize,
    stagger: Duration,
}

fn run_config(
    prefill_chunk: usize,
    decode_max_wait: Duration,
    decode_priority: bool,
    load: &Load,
) -> SchedResult {
    let hw = HwConfig::default();
    let pm = ModelConfig::s2t_small();
    let handle = Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("fig10", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        PoolConfig {
            workers: 1,
            queue_depth: 0,
            max_inflight: 0,
            prefill_chunk,
            decode_max_wait,
            decode_priority,
            batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
            ..PoolConfig::default()
        },
    );
    let mut rng = Rng::new(0xF1610);
    let mut id = 0u64;
    // Warm the pool first (worker engine construction + the B4 prefill
    // simulation) so the staggered submission below measures scheduling,
    // not startup.
    {
        let payload: Vec<f32> = (0..6 * D).map(|_| rng.normal_f32() * 0.5).collect();
        handle.submit(Request::new(u64::MAX, 6, payload)).expect("warmup");
        handle.responses.recv_timeout(Duration::from_secs(60)).expect("warmup response");
    }
    let t0 = Instant::now();
    // Staggered generate streams (B4-class prompts): without coalescing,
    // the first stream solo-steps through most of its budget before the
    // next even arrives. No sleep after the last one — the blockers must
    // land while its decode group is in flight.
    for i in 0..load.n_gen {
        let len = 6;
        let payload: Vec<f32> = (0..len * D).map(|_| rng.normal_f32() * 0.5).collect();
        handle
            .submit(Request::new(id, len, payload).with_generate(load.gen_tokens))
            .expect("unbounded pool rejects nothing");
        id += 1;
        if i + 1 < load.n_gen {
            std::thread::sleep(load.stagger);
        }
    }
    // Sync on the first streamed token so the blockers provably land while
    // decode is in flight (in the coalescing config the first step only
    // runs once the group forms).
    handle.tokens.recv_timeout(Duration::from_secs(30)).expect("decode must stream tokens");
    // Long B1 prefills land while decoding is in flight: chunked, they
    // yield between chunks (decode steps interleave); monolithic, each
    // blocks the worker for a whole pass.
    for _ in 0..load.n_block {
        let len = 30;
        let payload: Vec<f32> = (0..len * D).map(|_| rng.normal_f32() * 0.5).collect();
        handle.submit(Request::new(id, len, payload)).expect("unbounded pool rejects nothing");
        id += 1;
        std::thread::sleep(load.stagger / 4);
    }
    let total = load.n_gen + load.n_block;
    let mut got = 0;
    while got < total {
        handle
            .responses
            .recv_timeout(Duration::from_secs(60))
            .expect("pool must answer every request");
        got += 1;
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let report = handle.shutdown().expect("clean shutdown");
    assert_eq!(report.metrics.completed(), total as u64 + 1, "trace + warmup all answered");
    let j = report.json();
    let f = |k: &str| j.get(k).unwrap().as_f64().unwrap();
    SchedResult {
        p50: f("us_per_token_p50"),
        p95: f("us_per_token_p95"),
        decode_steps: f("decode_steps"),
        tokens: f("tokens_decoded"),
        interleave: f("interleave_ratio"),
        chunks: f("prefill_chunks"),
        coalesce_us: f("coalesce_wait_us_mean"),
        wall_ms,
    }
}

fn row(name: &str, r: &SchedResult) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{:.0}", r.tokens),
        format!("{:.0}", r.decode_steps),
        format!("{:.2}", r.tokens / r.decode_steps.max(1.0)),
        format!("{:.0}", r.p50),
        format!("{:.0}", r.p95),
        format!("{:.0}", r.chunks),
        format!("{:.0}%", r.interleave * 100.0),
        format!("{:.0}", r.coalesce_us),
        format!("{:.1}", r.wall_ms),
    ]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    banner("fig10-sched: chunked prefill + decode coalescing/priority scheduler");
    // 2 ms staggers: wide enough that the baseline's first stream really
    // does solo-step before its mates arrive, even on a loaded runner.
    let load = if smoke {
        Load { n_gen: 4, gen_tokens: 24, n_block: 3, stagger: Duration::from_millis(2) }
    } else {
        Load { n_gen: 4, gen_tokens: 32, n_block: 4, stagger: Duration::from_millis(2) }
    };
    let window = Duration::from_millis(25);
    let chunk = 2;

    let base = run_config(0, Duration::ZERO, false, &load);
    let full = run_config(chunk, window, true, &load);
    let mut rows = Vec::new();
    rows.push(row("baseline (seed)", &base));
    if !smoke {
        let chunk_only = run_config(chunk, Duration::ZERO, false, &load);
        let coalesce_only = run_config(0, window, false, &load);
        rows.push(row("chunk only", &chunk_only));
        rows.push(row("coalesce only", &coalesce_only));
    }
    rows.push(row("chunk+coalesce+priority", &full));
    table(
        &[
            "config",
            "tokens",
            "decode steps",
            "tokens/step",
            "µs/token p50",
            "µs/token p95",
            "chunks",
            "interleaved",
            "coalesce µs",
            "wall ms",
        ],
        &rows,
    );
    println!(
        "\nCoalescing lets staggered streams wait for batch-mates, so steps run\n\
         fuller (tokens/step ↑) and the per-token share of the step's weight\n\
         stream drops — µs/token p95 falls toward fig8's batched column.\n\
         Chunked prefill parks long passes between phase chunks so decode\n\
         steps interleave mid-prefill (interleaved > 0%) instead of queueing\n\
         behind a monolithic pass."
    );

    // Acceptance (CI smoke): same tokens served, better decode tail.
    assert_eq!(full.tokens, base.tokens, "both configs must decode the same load");
    assert!(
        full.p95 < base.p95 * 0.8,
        "scheduler must cut decode µs/token p95: {:.0} (on) vs {:.0} (off)",
        full.p95,
        base.p95
    );
    assert!(full.chunks > 0.0, "chunked prefill must execute chunks");
    assert!(full.interleave > 0.0, "decode steps must interleave with parked prefills");
    assert_eq!(base.chunks, 0.0, "baseline runs monolithic prefills");
    println!("\nfig10-sched OK: p95 {:.0} µs/token → {:.0} µs/token", base.p95, full.p95);
}
