//! Fig. 23.1.3 — factorizing training + compression.
//!
//! Regenerates the three claims:
//!   (1) factorization reduces EMA 8.5–10.7× across the four workloads,
//!   (2) compression (4b non-uniform W_S + 5b delta indices + 6b uniform
//!       values) adds another 2.1–2.9×,
//!   (3) the sequential order (X·W_S)·W_D needs 1–2.14× fewer MACs than X·W,
//! plus the delta-encoding/reorder ablation on a real factorized group.

use trex::bench_util::{banner, ratio, table};
use trex::compress::{reorder_gain, CompressionReport, DeltaCodec};
use trex::config::{ModelConfig, WORKLOADS};
use trex::factorize::{factorize_joint, mac_counts, FactorizeOptions};
use trex::util::mat::Mat;
use trex::util::rng::Rng;

fn main() {
    banner("Fig 23.1.3 (a): EMA / parameter reductions per workload");
    let mut rows = Vec::new();
    for name in WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        let r = CompressionReport::analytic(&m);
        rows.push(vec![
            name.to_string(),
            format!("{:.1} MB", r.baseline_bytes as f64 / 1e6),
            ratio(r.factorization_ratio()),
            ratio(r.compression_ratio()),
            ratio(r.total_ratio()),
            ratio(r.mac_ratio()),
        ]);
    }
    rows.push(vec![
        "paper".into(),
        "-".into(),
        "8.5-10.7x".into(),
        "2.1-2.9x".into(),
        "15.9-25.5x".into(),
        "1-2.14x".into(),
    ]);
    table(
        &["workload", "dense 16b", "factorize", "compress", "total", "MAC vs X·W"],
        &rows,
    );

    banner("Fig 23.1.3 (b): computing-order MAC comparison (BERT-Large FFN-up)");
    let m = ModelConfig::bert_large();
    let (seq, fused, dense) = mac_counts(128, m.d_model, m.d_ff, m.rank, m.nnz_per_col);
    table(
        &["order", "MACs", "vs dense"],
        &[
            vec!["X·W (dense)".into(), format!("{dense}"), "1.00x".into()],
            vec!["X·(W_S·W_D)".into(), format!("{fused}"), ratio(dense as f64 / fused as f64)],
            vec!["(X·W_S)·W_D".into(), format!("{seq}"), ratio(dense as f64 / seq as f64)],
        ],
    );

    banner("Fig 23.1.3 (c): delta-encoding ablation on a factorized group");
    // Factorize a real group, then measure index bits under each reorder.
    let mut rng = Rng::new(0xF16_3);
    let (d_in, d_out, rank, nnz) = (96usize, 80usize, 32usize, 6usize);
    let ws_true = Mat::randn(d_in, rank, &mut rng);
    let teachers: Vec<Mat> = (0..3)
        .map(|_| {
            let mut wd = Mat::zeros(rank, d_out);
            for c in 0..d_out {
                // Community structure: columns prefer one half of the rank
                // space — the correlation reordering exploits.
                let half = (c % 2) * (rank / 2);
                for r in rng.sample_distinct(rank / 2, nnz) {
                    *wd.at_mut(half + r, c) = rng.normal_f32();
                }
            }
            ws_true.matmul(&wd).unwrap()
        })
        .collect();
    let f = factorize_joint(
        &teachers,
        FactorizeOptions { rank, nnz_per_col: nnz, iters: 10, lambda: 1e-4, seed: 5 },
    )
    .unwrap();
    let mut rows = Vec::new();
    for (l, wd) in f.wds.iter().enumerate() {
        let gains = reorder_gain(wd, 5).unwrap();
        let codec = DeltaCodec::new(5, rank).unwrap();
        let _ = codec;
        rows.push(vec![
            format!("W_D layer {l}"),
            format!("{:.2}", gains[0].1),
            format!("{:.2}", gains[1].1),
            format!("{:.2}", gains[2].1),
            "8.00".into(),
        ]);
    }
    table(
        &["matrix", "b/idx identity", "b/idx popularity", "b/idx co-occur", "b/idx absolute"],
        &rows,
    );
    println!("\npaper: rearrangement lets 5b deltas replace 8b indices; co-occurrence\nordering approaches the nominal 5.0 b/idx.");
}
