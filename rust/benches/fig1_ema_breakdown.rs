//! Fig. 23.1.1 — "EMA accounts for up to 81% of total energy usage".
//!
//! Reproduces the paper's motivating analysis: take prior accelerators'
//! published core-only energy/token, add the LPDDR3 EMA cost at the paper's
//! own constants (3.7 pJ/b, 6.4 GB/s), and report the EMA share. Then show
//! the same breakdown for T-REX (simulated), where the factorization +
//! compression collapse the EMA term.

use trex::baseline::{dense_program, prior_works};
use trex::bench_util::{banner, table};
use trex::config::{HwConfig, ModelConfig};
use trex::model::build_program;
use trex::sim::{simulate, SimOptions};

fn main() {
    banner("Fig 23.1.1 (a): EMA share of prior transformer accelerators");
    let mut rows = Vec::new();
    let mut max_share = 0.0f64;
    for w in prior_works() {
        let total = w.uj_per_token_with_ema();
        let ema = total - w.uj_per_token;
        let share = ema / total;
        if !w.includes_ema {
            max_share = max_share.max(share);
        }
        rows.push(vec![
            w.name.to_string(),
            w.reference.to_string(),
            format!("{:.2}", w.uj_per_token),
            format!("{:.2}", ema),
            format!("{:.2}", total),
            format!("{:.0}%", share * 100.0),
        ]);
    }
    table(
        &["accelerator", "ref", "core µJ/tok", "EMA µJ/tok", "total", "EMA share"],
        &rows,
    );
    println!(
        "\nmax EMA share across core-only works: {:.0}%  (paper: up to 81%)",
        max_share * 100.0
    );

    banner("Fig 23.1.1 (b): the same chip, dense model vs T-REX (simulated)");
    let hw = HwConfig::default();
    let opts = SimOptions::paper(&hw);
    let mut rows = Vec::new();
    for name in ["bert-large", "vit-base"] {
        let m = ModelConfig::preset(name).unwrap();
        let dense = simulate(&hw, &dense_program(&m, 128), &opts);
        let trex = simulate(&hw, &build_program(&m, 128, 1), &opts);
        for (label, s) in [("dense", &dense), ("t-rex", &trex)] {
            rows.push(vec![
                format!("{name} ({label})"),
                format!("{:.1}", s.energy.total_uj() / s.tokens as f64),
                format!("{:.1}", s.energy.ema_pj * 1e-6 / s.tokens as f64),
                format!("{:.0}%", s.energy.ema_share() * 100.0),
            ]);
        }
    }
    table(&["config", "µJ/token", "EMA µJ/token", "EMA share"], &rows);
    println!("\nT-REX's EMA share collapses versus the dense baseline — the paper's thesis.");
}
