//! Control-plane integration over the deterministic reference backend:
//! governor-off fleet runs stay bit-identical to the pre-control-plane
//! pool (no re-points, no control section in the report, reproducible
//! modeled pricing), governor-on runs re-point chips at runtime without
//! ever pricing a step against a stale plan, and the SLO door sheds
//! generate traffic while the decode-p95 target is breached.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;
use trex::config::{HwConfig, ModelConfig};
use trex::control::{GovernorConfig, SloTarget};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, PoolConfig, Request, Server, ServerHandle,
};
use trex::fleet::{ChipSpec, Fleet};
use trex::kv::KvQuant;
use trex::obs::{FlightRecorder, SpanKind, TelemetryConfig};
use trex::runtime::ArtifactSet;

const MAX_SEQ: usize = 32;
const D: usize = 64;

fn start(pool: PoolConfig) -> ServerHandle {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("ctl", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    )
}

fn two_chip_fleet(vdd: f64) -> Arc<Fleet> {
    Arc::new(
        Fleet::build(
            vec![ChipSpec::general("g0", vdd), ChipSpec::general("g1", vdd)],
            &HwConfig::default(),
            &ModelConfig::tiny(),
            KvQuant::Fp16,
        )
        .expect("fleet build"),
    )
}

/// One serialized pass over a single-chip fleet: submit → await each
/// response, so decode grouping (and therefore modeled pricing) is a pure
/// function of the engine, not of thread timing.
fn serialized_pricing(fleet: &Arc<Fleet>) -> BTreeMap<u64, (f64, f64, usize)> {
    let handle = start(PoolConfig {
        fleet: Some(Arc::clone(fleet)),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    });
    let mut out = BTreeMap::new();
    for id in 0..6u64 {
        let req = Request::new(id, 6, vec![0.1; 6 * D]).with_generate(4);
        handle.submit(req).unwrap();
        let resp = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
        out.insert(resp.id, (resp.chip_us, resp.chip_uj, resp.tokens_generated));
    }
    handle.shutdown().unwrap();
    out
}

/// Governor off == the pre-control-plane pool: two identical runs price
/// identically (bit-identical modeled µs/µJ), no chip ever re-points, and
/// the report carries no control section at all.
#[test]
fn governor_off_static_fleet_is_bit_identical_and_never_repoints() {
    let one_chip = || {
        Arc::new(
            Fleet::build(
                vec![ChipSpec::general("g0", 0.65)],
                &HwConfig::default(),
                &ModelConfig::tiny(),
                KvQuant::Fp16,
            )
            .unwrap(),
        )
    };
    let fleet_a = one_chip();
    let fleet_b = one_chip();
    let a = serialized_pricing(&fleet_a);
    let b = serialized_pricing(&fleet_b);
    assert_eq!(a.len(), 6);
    for (id, (us_a, uj_a, tok_a)) in &a {
        let (us_b, uj_b, tok_b) = &b[id];
        assert_eq!(tok_a, tok_b, "request {id} decoded a different token count");
        assert_eq!(
            us_a.to_bits(),
            us_b.to_bits(),
            "request {id} modeled chip_us differs across identical static runs"
        );
        assert_eq!(
            uj_a.to_bits(),
            uj_b.to_bits(),
            "request {id} modeled chip_uj differs across identical static runs"
        );
    }
    for f in [&fleet_a, &fleet_b] {
        for chip in &f.chips {
            assert_eq!(chip.op_epoch(), 0, "static run re-pointed chip '{}'", chip.spec.id);
            assert_eq!(chip.stale_plan_hits(), 0);
            assert!((chip.current_vdd() - 0.65).abs() < 1e-12);
        }
    }

    // And the report JSON has no control section when nothing configured it.
    let handle = start(PoolConfig {
        fleet: Some(two_chip_fleet(0.85)),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    });
    handle.submit(Request::new(0, 6, vec![0.1; 6 * D]).with_generate(2)).unwrap();
    let _ = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    let report = handle.shutdown().unwrap();
    let doc = report.json();
    let obj = doc.as_obj().expect("report is a JSON object");
    assert!(
        !obj.contains_key("control"),
        "governor-off report must not grow a control section"
    );
}

/// Governor on: a paced trace against a 0.85 V fleet walks chips down the
/// fig7 table. Every re-point bumps the chip's plan epoch, the engine
/// re-costs before the next priced step (zero stale-plan hits), the
/// re-points land as `dvfs_repoint` span markers, and the report grows a
/// control section with the per-chip VDD.
#[test]
fn governor_repoints_recost_plans_and_emit_spans() {
    let fleet = two_chip_fleet(0.85);
    let recorder = Arc::new(FlightRecorder::for_pool(2, 4096));
    let handle = start(PoolConfig {
        fleet: Some(Arc::clone(&fleet)),
        lifecycle_ledger: true,
        recorder: Some(Arc::clone(&recorder)),
        telemetry: Some(TelemetryConfig {
            interval: Duration::from_micros(500),
            capacity: 4096,
            ..TelemetryConfig::default()
        }),
        governor: Some(GovernorConfig { dwell_us: 500.0, ..GovernorConfig::default() }),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::from_micros(200) },
        ..PoolConfig::default()
    });
    let metrics = Arc::clone(&handle.metrics);
    // Paced valley: queues stay shallow, so the governor drops.
    for id in 0..40u64 {
        std::thread::sleep(Duration::from_micros(800));
        let req = Request::new(id, 6, vec![0.1; 6 * D]).with_generate(4);
        handle.submit(req).unwrap();
    }
    let mut got = 0;
    while got < 40 {
        handle.responses.recv_timeout(Duration::from_secs(30)).expect("drain");
        got += 1;
    }
    let report = handle.shutdown().unwrap();
    assert!(metrics.ledger_audit().is_some_and(|a| a.conserved()));

    let control = report.control.as_ref().expect("governed run carries control state");
    assert!(control.repoints() >= 1, "governor never re-pointed on a shallow valley");
    for chip in &fleet.chips {
        assert!(
            chip.op_epoch() >= 1,
            "chip '{}' never re-pointed (epoch 0)",
            chip.spec.id
        );
        assert!(
            chip.current_vdd() < 0.85 - 1e-9,
            "chip '{}' should have dropped below its 0.85 V start, is at {}",
            chip.spec.id,
            chip.current_vdd()
        );
        assert_eq!(
            chip.stale_plan_hits(),
            0,
            "chip '{}' priced a step against a stale plan after a re-point",
            chip.spec.id
        );
        assert!(chip.kv.residual().is_clean());
    }

    // Each re-point is a span marker carrying the VDD transition.
    let events = recorder.snapshot();
    let repoints: Vec<_> =
        events.iter().filter(|e| e.kind == SpanKind::DvfsRepoint).collect();
    assert_eq!(
        repoints.len() as u64,
        control.repoints(),
        "every governor decision must land in the flight recorder"
    );
    for ev in &repoints {
        assert!((ev.group as usize) < fleet.n_chips());
        assert!(
            (ev.chip_us - ev.chip_uj).abs() > 1e-9,
            "a re-point marker must record an actual VDD transition"
        );
    }

    // The report grows a control section with the per-chip operating state.
    let doc = report.json();
    let ctl = doc.get("control").expect("governed report carries a control section");
    assert!(ctl.get("dvfs_repoints").and_then(|j| j.as_f64()).unwrap_or(0.0) >= 1.0);
    let chips = ctl.get("chip_vdd").expect("chip_vdd field").as_arr().expect("chip_vdd array");
    assert_eq!(chips.len(), fleet.n_chips());
}

/// SLO admission: an impossible decode-p95 target latches the door shut
/// for generate traffic after the first sampled interval — chat requests
/// shed with an SLO-attributed error while embed traffic still passes.
#[test]
fn slo_gate_sheds_generate_traffic_on_breach() {
    let handle = start(PoolConfig {
        workers: 1,
        telemetry: Some(TelemetryConfig {
            interval: Duration::from_micros(500),
            capacity: 4096,
            ..TelemetryConfig::default()
        }),
        slo: Some(SloTarget::decode(0.001)),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    });
    // First request lands before any interval has sampled a breach.
    handle.submit(Request::new(0, 6, vec![0.1; 6 * D]).with_generate(4)).unwrap();
    let _ = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    // Give the sampler a few intervals to observe the breach and latch.
    std::thread::sleep(Duration::from_millis(20));
    let ctl = handle.control().expect("slo config creates control state").clone();
    assert!(ctl.shedding(), "an impossible target must latch the gate");

    let shed = handle
        .try_submit(Request::new(1, 6, vec![0.1; 6 * D]).with_generate(4))
        .expect_err("generate traffic must shed while the gate is latched");
    assert!(
        shed.1.to_string().contains("slo breach"),
        "shed error must attribute the SLO: {}",
        shed.1
    );
    assert!(ctl.door_sheds() >= 1);

    // Embed traffic (no decode) is not governed by the decode-p95 gate.
    handle.try_submit(Request::new(2, 6, vec![0.1; 6 * D])).expect("embed must pass");
    let resp = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(resp.id, 2);
    let report = handle.shutdown().unwrap();
    let doc = report.json();
    let ctl_json = doc.get("control").expect("slo report carries a control section");
    assert!(ctl_json.get("slo_door_sheds").and_then(|j| j.as_f64()).unwrap_or(0.0) >= 1.0);
}
