//! Worker-pool integration over the deterministic reference backend —
//! runs everywhere (no AOT artifacts, no PJRT): concurrency, deadline
//! flushing, backpressure, drain-on-shutdown, shared-sim-cache semantics,
//! and token-level continuous batching on the decode path.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, FormedBatch, PassKey, PoolConfig, PrefillProgress,
    Request, Server, ServerHandle, SimCache, TokenEvent, TraceGenerator,
};
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::runtime::ArtifactSet;
use trex::sim::{BatchClass, GbBudget};

const MAX_SEQ: usize = 32;
const D: usize = 64;

fn start(pool: PoolConfig) -> ServerHandle {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("tiny", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    )
}

fn pool(workers: usize, max_wait: Duration) -> PoolConfig {
    PoolConfig {
        workers,
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait },
        ..PoolConfig::default()
    }
}

#[test]
fn pool_serves_mixed_load_and_merges_metrics() {
    let n = 120;
    let handle = start(pool(4, Duration::from_millis(1)));
    let mut gen = TraceGenerator::mixed(MAX_SEQ, D, 0xA11);
    for _ in 0..n {
        handle.submit(gen.next()).unwrap();
    }
    let mut got = 0;
    while got < n {
        let r = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.output.iter().all(|v| v.is_finite()));
        assert!(r.queue_us >= 0.0, "queue time clamps at zero");
        assert!(r.worker < 4);
        got += 1;
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), n);
    // Per-worker metrics partition the pooled view exactly.
    assert_eq!(report.workers.len(), 4);
    let sum: u64 = report.workers.iter().map(|w| w.completed()).sum();
    assert_eq!(sum, n);
    let j = report.json();
    assert_eq!(j.get("completed").unwrap().as_f64().unwrap(), n as f64);
    assert!(j.get("e2e_latency_us_p95").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(j.get("workers").unwrap().as_arr().unwrap().len(), 4);
}

#[test]
fn deadline_flush_under_concurrent_submit() {
    // Three B4-class requests from three threads: never a full batch of 4,
    // so only the deadline can flush them — while submits keep arriving.
    let handle = start(pool(2, Duration::from_millis(5)));
    let mut threads = Vec::new();
    for i in 0..3u64 {
        let sub = handle.submitter();
        threads.push(std::thread::spawn(move || {
            sub.submit(Request::new(i, 4, vec![0.25; 4 * D])).unwrap();
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    for _ in 0..3 {
        let r = handle.responses.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.output.len(), 4 * D);
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), 3);
}

#[test]
fn backpressure_rejects_when_saturated() {
    // max_inflight = 3 and a batcher that can hold requests for 10 s: the
    // first three admissions sit in the batcher (B4 needs four mates), so
    // the fourth submit must be rejected — deterministically.
    let cfg = PoolConfig {
        workers: 2,
        max_inflight: 3,
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::from_secs(10) },
        ..PoolConfig::default()
    };
    let handle = start(cfg);
    for i in 0..3u64 {
        handle.submit(Request::new(i, 4, vec![0.1; 4 * D])).unwrap();
    }
    // Give the ingest thread time to drain the channel into the batcher —
    // the requests are admitted (inflight) either way.
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(handle.inflight(), 3);
    let err = handle.submit(Request::new(9, 4, vec![0.1; 4 * D])).unwrap_err();
    assert!(err.to_string().contains("overloaded"), "got: {err}");

    // try_submit hands the request back for retry.
    let (req, _) = handle.try_submit(Request::new(10, 4, vec![0.1; 4 * D])).unwrap_err();
    assert_eq!(req.id, 10);

    // Unservable lengths fail the caller synchronously too — they must
    // never vanish inside the ingest thread with no response coming.
    assert!(handle.submit(Request::new(11, 0, vec![])).is_err());
    assert!(handle.submit(Request::new(12, MAX_SEQ + 1, vec![0.0; (MAX_SEQ + 1) * D])).is_err());
    assert_eq!(handle.inflight(), 3, "rejected requests are not admitted");

    // Rejections are counted; admitted requests still complete on shutdown.
    assert_eq!(handle.metrics.rejected(), 4);
    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), 3);
}

#[test]
fn shutdown_drains_all_inflight_batches_across_workers() {
    // Park requests of every class in the batcher (long deadline, partial
    // batches) and shut down immediately: the drain must flush them through
    // the worker pool — nothing admitted is ever dropped.
    let handle = start(pool(3, Duration::from_secs(10)));
    let mut id = 0u64;
    let mut expected = 0u64;
    for len in [4usize, 20, 30, 10, 4] {
        // 4→B4, 20→B2, 30→B1, 10→B2, 4→B4: B1 flushes at once, the rest
        // (two B4, plus one leftover B2 after the pair forms) sit pending.
        handle.submit(Request::new(id, len, vec![0.5; len * D])).unwrap();
        id += 1;
        expected += 1;
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), expected);
    assert_eq!(report.metrics.rejected(), 0);
}

#[test]
fn sim_cache_simulates_each_class_slot_exactly_once() {
    // 40 same-length requests → 10 full B4 batches (each formed on its 4th
    // push — the long deadline keeps partial flushes out), all hitting one
    // (class, slot) key. The shared cache must simulate once and serve 9
    // hits, no matter how the 4 workers interleave.
    let n = 40u64;
    let handle = start(pool(4, Duration::from_secs(60)));
    for i in 0..n {
        handle.submit(Request::new(i, 6, vec![0.3; 6 * D])).unwrap();
    }
    let mut got = 0;
    while got < n {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
        got += 1;
    }
    let stats = handle.cache_stats();
    assert_eq!(stats.entries, 1, "one (class, slot) key");
    assert_eq!(stats.misses, 1, "simulated exactly once across the pool");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.cache.hits + report.cache.misses, 10, "one lookup per batch");
    assert_eq!(report.cache.misses, 1);
}

/// Pool whose engines simulate performance for `perf` on hardware `hw`
/// (decode caps derive from both).
fn start_with(pool: PoolConfig, hw: HwConfig, perf: ModelConfig) -> ServerHandle {
    Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("tiny", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: perf.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    )
}

#[test]
fn decode_streams_tokens_with_continuous_batching() {
    // Acceptance: N generate requests stream tokens back with monotone
    // per-token timestamps, and decode batches mix requests at different
    // past_len. One worker + one deadline-flushed partial B4 batch makes
    // the grouping deterministic: three streams prefilled at lens 4/6/8
    // decode together from step one, each at its own KV depth.
    let n_tokens = 5usize;
    let lens = [4usize, 6, 8];
    let handle = start(pool(1, Duration::from_millis(5)));
    for (i, len) in lens.iter().enumerate() {
        let req = Request::new(i as u64, *len, vec![0.2; len * D]).with_generate(n_tokens);
        handle.submit(req).unwrap();
    }
    let mut finals = BTreeMap::new();
    for _ in 0..lens.len() {
        let r = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
        finals.insert(r.id, r);
    }
    // Every token precedes its final response, so the channel holds all.
    let events: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    assert_eq!(events.len(), lens.len() * n_tokens);

    for (i, len) in lens.iter().enumerate() {
        let id = i as u64;
        let r = &finals[&id];
        assert_eq!(r.tokens_generated, n_tokens, "req {id}");
        assert_eq!(r.prefill_len, *len);
        assert_eq!(r.output.len(), len * D, "final response carries prefill output");
        let mine: Vec<&TokenEvent> = events.iter().filter(|e| e.id == id).collect();
        assert_eq!(mine.len(), n_tokens);
        for (j, ev) in mine.iter().enumerate() {
            assert_eq!(ev.index, j, "tokens arrive in order");
            assert_eq!(ev.past_len, len + j, "KV depth grows one per step");
            assert!(ev.us_per_token > 0.0);
            if j > 0 {
                assert!(
                    ev.emitted >= mine[j - 1].emitted,
                    "req {id}: token {j} timestamp must be monotone"
                );
            }
        }
    }
    // Continuous batching observable: some step served streams at
    // different KV depths simultaneously.
    let mixed = events.iter().any(|e| {
        e.group_past_lens.len() > 1
            && e.group_past_lens.iter().any(|&p| p != e.group_past_lens[0])
    });
    assert!(mixed, "decode groups must mix past_len values: {events:#?}");

    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), lens.len() as u64);
    assert_eq!(report.metrics.tokens_decoded(), (lens.len() * n_tokens) as u64);
    let j = report.json();
    assert!(j.get("us_per_token_p50").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("us_per_token_p95").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(j.get("tokens_decoded").unwrap().as_f64().unwrap(), 15.0);
}

#[test]
fn decode_joins_streams_from_separate_prefills() {
    // Streams from different prefill batches must merge into shared decode
    // groups (token-level continuous batching across admissions). A zero
    // deadline flushes each of the five B4 requests as its own prefill
    // batch; with one worker alternating prefill/decode, each new stream
    // lands in the between-steps pool mid-generation and the FIFO regroup
    // mixes it into the earlier streams' steps.
    let n_tokens = 60usize;
    let handle = start(pool(1, Duration::from_millis(0)));
    for i in 0..5u64 {
        handle.submit(Request::new(i, 4, vec![0.1; 4 * D]).with_generate(n_tokens)).unwrap();
    }
    for _ in 0..5 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let events: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    assert_eq!(events.len(), 5 * n_tokens);
    // The late (5th) stream must share at least one step with others.
    let joined = events.iter().any(|e| e.id == 4 && e.group_past_lens.len() > 1);
    assert!(joined, "late stream must join the in-flight generation");
    handle.shutdown().unwrap();
}

#[test]
fn decode_groups_respect_class_width() {
    // A stream's decode budget is cap-clamped at its CLASS's batch width, so
    // the regrouper must never batch it wider: B1 streams decode solo even
    // when B4 streams are waiting alongside them.
    let n_tokens = 12usize;
    let handle = start(pool(1, Duration::from_millis(2)));
    // len 20 on the 32-token plane → B1 (flushes immediately).
    handle.submit(Request::new(0, 20, vec![0.4; 20 * D]).with_generate(n_tokens)).unwrap();
    // Four len-4 B4 requests → one full batch.
    for i in 1..=4u64 {
        handle.submit(Request::new(i, 4, vec![0.1; 4 * D]).with_generate(n_tokens)).unwrap();
    }
    for _ in 0..5 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let events: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    assert_eq!(events.len(), 5 * n_tokens);
    for e in events.iter().filter(|e| e.id == 0) {
        assert_eq!(e.group_past_lens.len(), 1, "B1 stream must decode solo: {e:?}");
    }
    // The B4 streams do share steps.
    assert!(events.iter().any(|e| e.id != 0 && e.group_past_lens.len() > 1));
    handle.shutdown().unwrap();
}

#[test]
fn decode_cap_clamps_generation_instead_of_rejecting() {
    // A GB too small for the asked-for KV depth must CAP generation (serve
    // what stays resident), not reject the request. Caps follow the KV
    // arena's precision (fp16 here — the engine default).
    let mut hw = HwConfig::default();
    hw.gb_bytes = 64 << 10;
    let perf = ModelConfig::tiny();
    let cap = GbBudget::max_decode_len_quant(&hw, &perf, 4, KvQuant::Fp16); // len 4 → B4
    assert!(cap > 4 && cap < 1000, "cap {cap} must bind below the ask");
    let handle = start_with(pool(2, Duration::from_millis(1)), hw, perf);
    handle.submit(Request::new(0, 4, vec![0.5; 4 * D]).with_generate(1000)).unwrap();
    let r = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r.tokens_generated, cap - 4, "generation clamps at cap - prefill");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), 1);
    assert_eq!(report.metrics.tokens_decoded(), (cap - 4) as u64);
}

#[test]
fn plain_and_generate_requests_share_prefill_sim_entries() {
    // A generate request's prefill pass must hit the same cache entry a
    // plain request of the same class/slot created — prefill results are
    // reused as decode prefixes (PassKey carries past_len = 0).
    let handle = start(pool(2, Duration::from_secs(60)));
    for i in 0..4u64 {
        handle.submit(Request::new(i, 6, vec![0.3; 6 * D])).unwrap();
    }
    for _ in 0..4 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let prefill_only = handle.cache_stats();
    assert_eq!(prefill_only.entries, 1);
    for i in 4..8u64 {
        handle.submit(Request::new(i, 6, vec![0.3; 6 * D]).with_generate(3)).unwrap();
    }
    for _ in 0..4 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let stats = handle.cache_stats();
    // New entries are decode steps only; the prefill key was reused.
    assert_eq!(prefill_only.misses, 1);
    assert!(stats.misses >= 2, "decode steps add entries");
    assert!(
        stats.entries < 1 + 4 * 3,
        "decode keys are (group, depth), shared across streams: {stats:?}"
    );
    handle.shutdown().unwrap();
}

/// Pool over a shared, explicitly-sized KV manager: admission consults it
/// and every worker's engine charges residency against it.
fn start_kv(workers: usize, kv: Arc<KvManager>, max_wait: Duration) -> ServerHandle {
    let cfg = PoolConfig {
        workers,
        kv: Some(kv),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait },
        ..PoolConfig::default()
    };
    start(cfg)
}

#[test]
fn kv_admission_bounds_concurrent_generate_streams() {
    // A 4-page (8 KiB) arena at oversub 1.0: one 200-token generate stream
    // projects past half the arena, so the second and third submits must be
    // refused at the door with a kv-arena error — admission bounds
    // aggregate decode state, not just per-class caps.
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let mut cfg = KvArenaConfig::for_pool(&hw, &pm, KvQuant::Fp16, Some(4));
    cfg.admit_oversub = 1.0;
    let kv = Arc::new(KvManager::new(&hw, &pm, cfg));
    let handle = start_kv(1, Arc::clone(&kv), Duration::from_millis(1));
    let mut accepted = 0;
    let mut kv_rejected = 0;
    for i in 0..3u64 {
        // len 4 → B4; a long generation keeps the first stream live while
        // the later submits arrive.
        match handle.submit(Request::new(i, 4, vec![0.2; 4 * D]).with_generate(200)) {
            Ok(()) => accepted += 1,
            Err(e) => {
                assert!(e.to_string().contains("kv arena"), "got: {e}");
                kv_rejected += 1;
            }
        }
    }
    assert_eq!(accepted, 1, "arena projection admits exactly one stream");
    assert_eq!(kv_rejected, 2);
    for _ in 0..accepted {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    assert_eq!(kv.stats().admit_rejected, 2);
    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), accepted);
    assert_eq!(report.metrics.rejected(), kv_rejected);
    // Completed streams released their reservations and pages.
    assert_eq!(kv.live_streams(), 0);
    assert_eq!(kv.used_pages(), 0);
    let j = report.json();
    assert_eq!(j.get("kv_arena").unwrap().get("admit_rejected").unwrap().as_f64().unwrap(), 2.0);
}

#[test]
fn kv_arena_evicts_and_charges_swap_in_across_concurrent_streams() {
    // Acceptance: aggregate residency enforced across concurrent streams —
    // 8 generate streams whose combined KV outgrows a 64-page arena. Parked
    // streams are never free: the LRU must evict them, rejoins must charge
    // swap-in EMA, and occupancy must never exceed the arena.
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let pages = 64usize;
    let mut cfg = KvArenaConfig::for_pool(&hw, &pm, KvQuant::Fp16, Some(pages));
    cfg.admit_oversub = 8.0; // admit the whole fleet; let residency churn
    let kv = Arc::new(KvManager::new(&hw, &pm, cfg));
    let n = 8u64;
    let gen = 40usize;
    let handle = start_kv(1, Arc::clone(&kv), Duration::from_millis(0));
    for i in 0..n {
        handle.submit(Request::new(i, 4, vec![0.1; 4 * D]).with_generate(gen)).unwrap();
    }
    for _ in 0..n {
        let r = handle.responses.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.tokens_generated, gen);
    }
    let report = handle.shutdown().unwrap();
    let stats = kv.stats();
    // 8 streams at final depth 44 need ~88 pages > 64: eviction must have
    // triggered, and at least one evicted stream rejoined a step.
    assert!(stats.evictions > 0, "{stats:?}");
    assert!(stats.swap_ins > 0 && stats.swap_in_bytes > 0, "{stats:?}");
    assert_eq!(stats.forced_overcommit, 0, "groups of 4 fit the arena: {stats:?}");
    assert!(
        stats.peak_used_pages <= pages,
        "residency cap violated: {} > {pages}",
        stats.peak_used_pages
    );
    // The charges surfaced in the pooled metrics (and the swap bytes ride
    // the final responses' EMA shares — never free).
    assert_eq!(report.metrics.kv_swap_bytes(), stats.swap_in_bytes);
    let j = report.json();
    assert!(j.get("kv_swap_ins").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(kv.live_streams(), 0, "all streams released on completion");
}

#[test]
fn prefix_shared_pool_outputs_match_private_and_use_fewer_pages() {
    // Tentpole acceptance: prefix sharing is accounting-only. The same
    // generate workload run with and without a shared `prefix_group` tag
    // must produce byte-identical per-request outputs (COW forks never
    // touch numerics), while the shared run's arena peak stays well below
    // the no-share run's O(N) footprint.
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let n = 8u64;
    let gen = 6usize;
    // 6 tokens of fp16 KV straddle a page on the tiny geometry, so every
    // stream decoding past the prefix COW-forks the partial tail page.
    let len = 6usize;
    let run = |share: bool| {
        let cfg = KvArenaConfig::for_pool(&hw, &pm, KvQuant::Fp16, Some(256));
        let kv = Arc::new(KvManager::new(&hw, &pm, cfg));
        let handle = start_kv(1, Arc::clone(&kv), Duration::from_millis(1));
        for i in 0..n {
            let mut req = Request::new(i, len, vec![0.3; len * D]).with_generate(gen);
            if share {
                req = req.with_prefix_group(trex::kv::prefix_id("shared-sys-prompt"));
            }
            handle.submit(req).unwrap();
        }
        let mut out = BTreeMap::new();
        for _ in 0..n {
            let r = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
            out.insert(r.id, (r.output, r.tokens_generated));
        }
        handle.shutdown().unwrap();
        let residual = kv.residual();
        assert!(residual.is_clean(), "leak after drain: {residual:?}");
        (out, kv.stats())
    };
    let (shared_out, shared_kv) = run(true);
    let (private_out, private_kv) = run(false);
    assert_eq!(shared_out, private_out, "sharing must not change any stream's results");
    assert!(
        shared_kv.peak_used_pages < private_kv.peak_used_pages,
        "shared peak {} must undercut the no-share {} pages",
        shared_kv.peak_used_pages,
        private_kv.peak_used_pages
    );
    assert_eq!(shared_kv.prefix_hits, n - 1, "every mate after the first hits the chain");
    assert!(shared_kv.cow_forks > 0, "unaligned prefix must fork on decode: {shared_kv:?}");
    assert_eq!(private_kv.prefix_hits, 0);
    assert_eq!(private_kv.cow_forks, 0);
}

/// Pool with the scheduler knobs set (1 worker unless stated — the
/// single-worker pop sequence is what makes these tests deterministic).
fn sched_pool(
    batcher_wait: Duration,
    prefill_chunk: usize,
    decode_max_wait: Duration,
    decode_priority: bool,
) -> PoolConfig {
    PoolConfig {
        workers: 1,
        prefill_chunk,
        decode_max_wait,
        decode_priority,
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: batcher_wait },
        ..PoolConfig::default()
    }
}

#[test]
fn decode_coalescing_window_actually_waits() {
    // Two B4 streams can never fill a 4-wide group, so every step must
    // wait out the coalescing window — consecutive tokens of a stream are
    // separated by at least (most of) the window.
    let window = Duration::from_millis(200);
    let handle = start(sched_pool(Duration::from_millis(5), 0, window, false));
    for i in 0..2u64 {
        handle.submit(Request::new(i, 4, vec![0.2; 4 * D]).with_generate(2)).unwrap();
    }
    for _ in 0..2 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let events: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    assert_eq!(events.len(), 4);
    // The window coalesced the pair: every step served both streams.
    for e in &events {
        assert_eq!(e.group_past_lens.len(), 2, "streams must share steps: {e:?}");
    }
    for id in 0..2u64 {
        let mine: Vec<&TokenEvent> = events.iter().filter(|e| e.id == id).collect();
        assert_eq!(mine.len(), 2);
        let gap = mine[1].emitted.duration_since(mine[0].emitted);
        assert!(
            gap >= Duration::from_millis(140),
            "req {id}: steps only {gap:?} apart — the window did not hold"
        );
    }
    let report = handle.shutdown().unwrap();
    let j = report.json();
    assert!(
        j.get("coalesce_wait_us_mean").unwrap().as_f64().unwrap() >= 100_000.0,
        "coalescing wait must be measured"
    );
}

#[test]
fn full_width_decode_groups_skip_the_coalescing_window() {
    // Four B4 streams fill the group: despite a huge window, steps
    // dispatch immediately — the window only holds *partial* groups.
    let window = Duration::from_millis(200);
    let handle = start(sched_pool(Duration::from_millis(5), 0, window, false));
    // Warm up first (engine construction + prefill simulation) so the
    // wall-clock bound below measures scheduling, not startup.
    handle.submit(Request::new(99, 4, vec![0.2; 4 * D])).unwrap();
    handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    for i in 0..4u64 {
        handle.submit(Request::new(i, 4, vec![0.2; 4 * D]).with_generate(2)).unwrap();
    }
    let t0 = Instant::now();
    for _ in 0..4 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_millis(150),
        "full groups must not wait the 200ms window per step: {elapsed:?}"
    );
    let events: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    assert_eq!(events.len(), 8);
    assert!(events.iter().all(|e| e.group_past_lens.len() == 4), "steps ran full");
    handle.shutdown().unwrap();
}

#[test]
fn decode_priority_drains_near_done_streams_first() {
    // Stream A (24 tokens) decodes solo (B1); stream B (3 tokens) joins
    // mid-generation. With near-done-first priority, B drains completely
    // before A steps again — its response arrives while A still decodes.
    let handle = start(sched_pool(Duration::from_millis(1), 0, Duration::ZERO, true));
    handle.submit(Request::new(0, 20, vec![0.4; 20 * D]).with_generate(24)).unwrap();
    // Wait for A's first token so it is decoding when B arrives.
    let first = handle.tokens.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(first.id, 0);
    handle.submit(Request::new(1, 24, vec![0.4; 24 * D]).with_generate(3)).unwrap();
    let r1 = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r1.id, 1, "near-done stream must finish first");
    let r0 = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(r0.id, 0);
    // The discriminator vs FIFO (which would alternate A,B,A,B…): once B
    // leads the pool (3 remaining vs A's ≥ 8), every pop picks B until it
    // drains — no A token lands between B's first and last.
    let events: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    let b_first = events.iter().filter(|e| e.id == 1).map(|e| e.emitted).min().unwrap();
    let b_last = events.iter().filter(|e| e.id == 1).map(|e| e.emitted).max().unwrap();
    let a_between = events
        .iter()
        .filter(|e| e.id == 0 && e.emitted > b_first && e.emitted < b_last)
        .count();
    assert_eq!(a_between, 0, "B must drain consecutively, ahead of the deeper stream");
    let a_after = events.iter().filter(|e| e.id == 0 && e.emitted >= b_last).count();
    assert!(a_after >= 2, "A must still be decoding after B drained (saw {a_after})");
    handle.shutdown().unwrap();
}

#[test]
fn chunked_prefill_interleaves_decode_with_a_long_prefill() {
    // One worker, chunk = 1 phase: while the long B1 request prefills,
    // decode steps of stream A must land BETWEEN its chunk completions —
    // the head-of-line blocking a monolithic prefill would cause is gone.
    let hw = HwConfig::default();
    let perf = ModelConfig::s2t_small(); // 20 phases → many chunks
    let pool = sched_pool(Duration::from_millis(1), 1, Duration::ZERO, false);
    let handle = start_with(pool, hw, perf);
    handle.submit(Request::new(0, 4, vec![0.2; 4 * D]).with_generate(40)).unwrap();
    // A is decoding (its own prefill chunks are done once a token streams).
    let first = handle.tokens.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(first.id, 0);
    let marks_before = handle.metrics.chunk_marks().len();
    handle.submit(Request::new(1, 30, vec![0.3; 30 * D])).unwrap();
    let rb = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(rb.id, 1, "encode-only blocker finishes while A still decodes");
    assert_eq!(rb.output.len(), 30 * D);
    let ra = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(ra.id, 0);
    assert_eq!(ra.tokens_generated, 40);

    let marks = handle.metrics.chunk_marks();
    assert!(marks.len() > marks_before + 2, "the blocker must have run as many chunks");
    let b_marks = &marks[marks_before..];
    let (b_first, b_last) = (*b_marks.first().unwrap(), *b_marks.last().unwrap());
    let events: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    let between = events
        .iter()
        .filter(|e| e.id == 0 && e.emitted > b_first && e.emitted < b_last)
        .count();
    assert!(
        between > 0,
        "decode tokens must land between the blocker's chunk completions \
         ({} chunks over {:?})",
        b_marks.len(),
        b_last.duration_since(b_first)
    );
    assert!(
        handle.metrics.interleaved_decode_steps() > 0,
        "interleaved steps must be counted"
    );
    let report = handle.shutdown().unwrap();
    let j = report.json();
    assert!(j.get("prefill_chunks").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("interleave_ratio").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn shed_mid_prefill_releases_kv_reservations() {
    // A generate request with a corrupt payload passes length admission,
    // reserves KV, registers at its first chunk, then fails at the final
    // chunk's plane assembly — mid-prefill. The shed path must release the
    // arena pages AND the admission reservation, and free the in-flight
    // slot.
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let kv = Arc::new(KvManager::new(
        &hw,
        &pm,
        KvArenaConfig::for_pool(&hw, &pm, KvQuant::Fp16, Some(64)),
    ));
    let cfg = PoolConfig {
        kv: Some(Arc::clone(&kv)),
        ..sched_pool(Duration::from_millis(1), 2, Duration::ZERO, false)
    };
    let handle = start(cfg);
    // len 4 but only 3 rows of payload: invalid shape, valid length.
    handle.submit(Request::new(7, 4, vec![0.1; 3 * D]).with_generate(5)).unwrap();
    let mut sheds = 0;
    for _ in 0..500 {
        sheds = handle.metrics.execute_errors();
        if sheds > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(sheds, 1, "the corrupt batch must shed");
    assert!(
        handle.metrics.prefill_chunks() >= 1,
        "the shed happened mid-prefill, after at least one parked chunk"
    );
    assert_eq!(kv.live_streams(), 0, "shed must release the stream's registration");
    assert_eq!(kv.used_pages(), 0, "shed must free the arena pages");
    assert_eq!(handle.inflight(), 0, "shed must free the in-flight slot");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.metrics.completed(), 0);
}

#[test]
fn chunked_prefill_outcome_matches_monolithic_execute() {
    // Acceptance: the chunked path's final per-request stats are
    // bit-identical to Engine::execute — same simulation, different
    // schedule (the sim-level twin is chunked_phase_ranges_match_monolithic
    // in sim::exec).
    let hw = HwConfig::default();
    let pm = ModelConfig::s2t_small();
    let mk_engine = || {
        let set = ArtifactSet::reference("tiny", D, MAX_SEQ).unwrap();
        Engine::new(
            set,
            EngineConfig {
                hw: hw.clone(),
                perf_model: pm.clone(),
                self_test: false,
                kv_quant: KvQuant::Fp16,
                kv_pages: None,
            },
        )
        .unwrap()
    };
    let reqs =
        vec![Request::new(0, 10, vec![0.3; 10 * D]), Request::new(1, 12, vec![-0.2; 12 * D])];
    let batch = |reqs: &[Request]| FormedBatch { class: BatchClass::B2, requests: reqs.to_vec() };

    let mut mono = mk_engine();
    let mono_out = mono.execute(batch(&reqs)).unwrap();

    let mut chunked = mk_engine();
    let mut st = chunked.begin_prefill(batch(&reqs), 3).unwrap();
    let done = loop {
        match chunked.prefill_chunk(st).unwrap() {
            PrefillProgress::Parked(next) => st = *next,
            PrefillProgress::Done(outcome) => break outcome,
        }
    };
    assert_eq!(done.responses.len(), 2);
    assert_eq!(done.responses.len(), mono_out.responses.len());
    for (a, b) in done.responses.iter().zip(mono_out.responses.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output, b.output, "req {}: numerics identical", a.id);
        assert_eq!(a.chip_us, b.chip_us, "req {}: chunked sim bit-identical", a.id);
        assert_eq!(a.chip_uj, b.chip_uj, "req {}", a.id);
        assert_eq!(a.ema_bytes, b.ema_bytes, "req {}", a.id);
        assert_eq!(a.utilization, b.utilization, "req {}", a.id);
        assert_eq!(a.class, b.class);
    }
}

#[test]
fn identical_numerics_any_worker_count() {
    // The same trace through 1-worker and 4-worker pools must produce
    // byte-identical per-request outputs (row-wise reference numerics are
    // independent of batching and worker assignment).
    let trace: Vec<Request> = TraceGenerator::mixed(MAX_SEQ, D, 0xBEEF).take(60);
    let run = |workers: usize| -> std::collections::BTreeMap<u64, Vec<f32>> {
        let handle = start(pool(workers, Duration::from_millis(1)));
        for r in trace.clone() {
            handle.submit(r).unwrap();
        }
        let mut out = std::collections::BTreeMap::new();
        for _ in 0..trace.len() {
            let resp = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
            out.insert(resp.id, resp.output);
        }
        handle.shutdown().unwrap();
        out
    };
    assert_eq!(run(1), run(4));
}

#[test]
fn chunked_cold_key_race_simulates_exactly_once() {
    // Satellite acceptance (PR 4 race closed): two engines sharing one
    // SimCache begin the SAME cold prefill key. The first claims the
    // chunked simulation; the second becomes a follower that steps no
    // simulation at all and rides the owner's published value.
    let hw = HwConfig::default();
    let pm = ModelConfig::s2t_small();
    let cache = Arc::new(SimCache::new());
    let mk = |cache: &Arc<SimCache>| {
        let set = ArtifactSet::reference("tiny", D, MAX_SEQ).unwrap();
        Engine::with_cache(
            set,
            EngineConfig {
                hw: hw.clone(),
                perf_model: pm.clone(),
                self_test: false,
                kv_quant: KvQuant::Fp16,
                kv_pages: None,
            },
            Arc::clone(cache),
        )
        .unwrap()
    };
    let mut a = mk(&cache);
    let mut b = mk(&cache);
    let reqs = |base: u64| {
        vec![
            Request::new(base, 10, vec![0.1; 10 * D]),
            Request::new(base + 1, 12, vec![0.2; 12 * D]),
        ]
    };
    let batch = |requests: Vec<Request>| FormedBatch { class: BatchClass::B2, requests };
    let mut sa = a.begin_prefill(batch(reqs(0)), 2).unwrap();
    let sb = b.begin_prefill(batch(reqs(10)), 2).unwrap();
    assert!(sa.owns_simulation(), "first racer owns the chunked simulation");
    assert!(!sb.owns_simulation(), "second racer follows instead of re-simulating");
    assert_eq!(cache.in_flight_chunked(), 1);
    // Drive the owner to completion; its final chunk publishes the pass.
    let oa = loop {
        match a.prefill_chunk(sa).unwrap() {
            PrefillProgress::Parked(next) => sa = *next,
            PrefillProgress::Done(outcome) => break outcome,
        }
    };
    assert_eq!(cache.in_flight_chunked(), 0, "publish releases the claim");
    // The follower completes in ONE chunk (nothing to re-step).
    let ob = match b.prefill_chunk(sb).unwrap() {
        PrefillProgress::Done(outcome) => outcome,
        PrefillProgress::Parked(_) => panic!("follower must complete directly"),
    };
    assert_eq!(cache.stats().misses, 1, "exactly one simulation for the racing key");
    // Both batches carry the same modeled pass.
    assert_eq!(oa.responses[0].chip_us, ob.responses[0].chip_us);
    assert_eq!(oa.responses[0].utilization, ob.responses[0].utilization);

    // A dropped OWNER (an external driver discarding a parked state)
    // abandons its claim in Drop — the key stays claimable and later
    // prefills are never demoted to stalling followers.
    let st = a.begin_prefill(batch(reqs(20)), 2).unwrap();
    assert!(!st.owns_simulation(), "key already cached: no claim to hold");
    drop(st);
    let cold = PassKey::prefill(BatchClass::B1, 32);
    assert!(cache.peek(cold).is_none(), "B1 slot is a fresh key");
    let st = a
        .begin_prefill(
            FormedBatch {
                class: BatchClass::B1,
                requests: vec![Request::new(30, 20, vec![0.1; 20 * D])],
            },
            2,
        )
        .unwrap();
    assert!(st.owns_simulation());
    assert_eq!(cache.in_flight_chunked(), 1);
    drop(st);
    assert_eq!(cache.in_flight_chunked(), 0, "Drop releases an owned claim");
}

#[test]
fn steady_state_decode_routes_through_step_plans() {
    // Tentpole acceptance at the pool level: generate traffic's decode
    // steps split into exact first steps plus plan-priced steady-state
    // steps, and the per-token numbers stay identical either way (the
    // us_per_token stream is what clients see).
    let gen = 6usize;
    let n = 4u64;
    let handle = start(pool(2, Duration::from_millis(1)));
    for i in 0..n {
        handle.submit(Request::new(i, 6, vec![0.3; 6 * D]).with_generate(gen)).unwrap();
    }
    for _ in 0..n {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let tokens: Vec<TokenEvent> = handle.tokens.try_iter().collect();
    assert_eq!(tokens.len(), (n as usize) * gen);
    let report = handle.shutdown().unwrap();
    let steps = report.metrics.decode_plan_steps();
    assert!(steps > 0, "steady-state steps must take the plan path");
    let j = report.json();
    let total = j.get("decode_steps").unwrap().as_f64().unwrap();
    let planned = j.get("decode_plan_steps").unwrap().as_f64().unwrap();
    assert!(planned < total, "first steps keep the exact path");
    // Every stream's deeper steps (all past the first) were plan-priced;
    // steps at the same group width and padded depth (the group's MAX —
    // what the simulation keys on) must report identical modeled per-token
    // cost regardless of which path priced them.
    let mut by_key: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for ev in &tokens {
        let max_past = *ev.group_past_lens.iter().max().expect("non-empty group");
        let key = (ev.group_past_lens.len(), max_past);
        let us = by_key.entry(key).or_insert(ev.us_per_token);
        assert!(
            (*us - ev.us_per_token).abs() < 1e-9,
            "same (group, max depth) must price identically: {key:?}"
        );
    }
}
