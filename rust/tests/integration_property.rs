//! Generative property tests across module boundaries (proptest is not
//! vendored; a seeded SplitMix64 harness drives the same style of sweep).
//! Focus: invariants that only hold when several modules agree.

use trex::compress::{DeltaCodec, NonUniformQuant, UniformQuant};
use trex::config::{HwConfig, ModelConfig};
use trex::factorize::{factorize_joint, CscFixed, FactorizeOptions};
use trex::model::build_program;
use trex::sim::{batch_class, simulate, GbBudget, SimOptions};
use trex::util::mat::Mat;
use trex::util::rng::Rng;

fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> CscFixed {
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for _ in 0..cols {
        let mut rs = rng.sample_distinct(rows, nnz);
        rs.sort_unstable();
        for r in rs {
            idx.push(r as u16);
            val.push(rng.normal_f32());
        }
    }
    CscFixed { rows, cols, nnz_per_col: nnz, idx, val }
}

#[test]
fn full_compression_pipeline_bounded_error() {
    // factorize → quantize W_S (4b) → quantize W_D values (6b) → delta-code
    // indices → decode everything → reconstruct. End-to-end error must stay
    // bounded by the sum of the quantizers' worst cases.
    let mut rng = Rng::new(0xA11);
    for trial in 0..5 {
        let (d_in, d_out, rank, nnz) = (
            rng.range(24, 48),
            rng.range(16, 40),
            rng.range(8, 16),
            rng.range(2, 6),
        );
        let ws_true = Mat::randn(d_in, rank, &mut rng);
        let teachers: Vec<Mat> = (0..2)
            .map(|_| {
                let sp = random_sparse(&mut rng, rank, d_out, nnz);
                ws_true.matmul(&sp.to_dense()).unwrap()
            })
            .collect();
        let f = factorize_joint(
            &teachers,
            FactorizeOptions { rank, nnz_per_col: nnz, iters: 10, lambda: 1e-4, seed: trial },
        )
        .unwrap();

        let q = NonUniformQuant::fit(&f.ws.data, 4, 20).unwrap();
        let ws_q = q.decode(&q.encode(&f.ws).unwrap(), d_in, rank).unwrap();

        for (wd, teacher) in f.wds.iter().zip(&teachers) {
            let uq = UniformQuant::fit(&wd.val, 6).unwrap();
            let val_q = uq.decode(&uq.encode(&wd.val).unwrap(), wd.val.len()).unwrap();
            let codec = DeltaCodec::new(5, rank).unwrap();
            let enc = codec.encode(wd).unwrap();
            let idx = codec.decode(&enc, rank, d_out, nnz).unwrap();
            assert_eq!(idx, wd.idx, "index plane must roundtrip losslessly");
            let wd_q = CscFixed { val: val_q, ..wd.clone() };
            let recon = ws_q.matmul(&wd_q.to_dense()).unwrap();
            // Reconstruction vs the teacher: ALS fit error + both
            // quantizers' noise, loosely bounded.
            let err = teacher.rel_err(&recon);
            let fit_only = teacher.rel_err(&f.ws.matmul(&wd.to_dense()).unwrap());
            assert!(err < fit_only + 0.35, "trial {trial}: pipeline {err} vs fit {fit_only}");
        }
    }
}

#[test]
fn utilization_monotone_in_batch() {
    // For any short length, utilization never decreases with the batch size
    // admitted by the class system.
    let hw = HwConfig::default();
    let m = ModelConfig::nmt_rdrop();
    let mut rng = Rng::new(42);
    let opts = SimOptions::paper(&hw);
    for _ in 0..10 {
        let seq = rng.range(1, 32);
        let u1 = simulate(&hw, &build_program(&m, seq, 1), &opts).utilization(&hw);
        let u2 = simulate(&hw, &build_program(&m, seq, 2), &opts).utilization(&hw);
        let u4 = simulate(&hw, &build_program(&m, seq, 4), &opts).utilization(&hw);
        assert!(u2 >= u1 * 0.99, "seq {seq}: u2 {u2} < u1 {u1}");
        assert!(u4 >= u2 * 0.99, "seq {seq}: u4 {u4} < u2 {u2}");
    }
}

#[test]
fn ema_strictly_increases_with_layers() {
    // Adding layers can only add weight traffic.
    let hw = HwConfig::default();
    let opts = SimOptions::paper(&hw);
    let mut m = ModelConfig::tiny();
    let mut prev = 0;
    for layers in [1usize, 2, 4, 8] {
        m.enc_layers = layers;
        let s = simulate(&hw, &build_program(&m, 16, 1), &opts);
        assert!(s.ema_bytes() > prev);
        prev = s.ema_bytes();
    }
}

#[test]
fn latency_monotone_in_voltage() {
    let hw = HwConfig::default();
    let m = ModelConfig::s2t_small();
    let prog = build_program(&m, 64, 2);
    let mut prev = f64::INFINITY;
    let mut vdd = 0.45;
    while vdd <= 0.86 {
        let s = simulate(
            &hw,
            &prog,
            &SimOptions { point: hw.point_at_vdd(vdd), ..SimOptions::paper(&hw) },
        );
        assert!(s.seconds() <= prev * 1.0001, "latency not monotone at {vdd}");
        prev = s.seconds();
        vdd += 0.02;
    }
}

#[test]
fn gb_budget_consistent_with_class_system() {
    // Any admissible (len → class) configuration must fit the GB at least
    // single-buffered for every workload.
    let hw = HwConfig::default();
    let mut rng = Rng::new(7);
    for name in trex::config::WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        for _ in 0..20 {
            let len = rng.range(1, m.max_seq);
            let class = batch_class(len, hw.max_seq).unwrap();
            let b = GbBudget::for_config(&hw, &m, class.max_len(hw.max_seq), class.batch());
            assert!(b.fits_single(), "{name} len {len}: {:?}", b);
        }
    }
}

#[test]
fn trf_never_hurts() {
    let hw = HwConfig::default();
    let mut rng = Rng::new(9);
    for _ in 0..10 {
        let m = ModelConfig::preset(
            trex::config::WORKLOADS[rng.below(4)],
        )
        .unwrap();
        let batch = [1usize, 2, 4][rng.below(3)];
        let seq = rng.range(1, hw.max_seq / batch);
        let prog = build_program(&m, seq, batch);
        let on = simulate(&hw, &prog, &SimOptions::paper(&hw));
        let off = simulate(&hw, &prog, &SimOptions { trf: false, ..SimOptions::paper(&hw) });
        assert!(on.cycles <= off.cycles, "{}: trf slower at seq {seq}", m.name);
    }
}
