//! Step-plan parity: the compiled decode plan (`Stepper::run_plan`) must
//! price a step **bit-identically** to building the op program and walking
//! it (`build_decode_step` + `Stepper::run_program`) — cycles, busy/stall
//! tallies, every EMA category, and the f64 energy breakdown, across KV
//! depths, group widths, quantization modes, both architectures, and the
//! spill/dequant/single-buffer GB regimes.

use trex::compress::EmaCategory;
use trex::config::{HwConfig, ModelConfig};
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::model::{build_decode_step, build_program};
use trex::sim::{simulate, GbBudget, RunStats, SimOptions, StepPlan, Stepper};

fn assert_bit_identical(a: &RunStats, b: &RunStats, ctx: &str) {
    assert_eq!(a.cycles, b.cycles, "{ctx}: cycles");
    assert_eq!(a.dmm_busy, b.dmm_busy, "{ctx}: dmm_busy");
    assert_eq!(a.smm_busy, b.smm_busy, "{ctx}: smm_busy");
    assert_eq!(a.afu_busy, b.afu_busy, "{ctx}: afu_busy");
    assert_eq!(a.dma_stall_cycles, b.dma_stall_cycles, "{ctx}: dma_stall");
    assert_eq!(a.trf_stall_cycles, b.trf_stall_cycles, "{ctx}: trf_stall");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.inputs, b.inputs, "{ctx}: inputs");
    for cat in EmaCategory::ALL {
        assert_eq!(a.ema.get(cat), b.ema.get(cat), "{ctx}: ema {}", cat.name());
    }
    // f64 energy must match *bitwise* — both paths execute the same float
    // operations in the same order.
    assert_eq!(a.energy, b.energy, "{ctx}: energy breakdown");
}

/// The engine's exact per-depth option derivation for one decode step.
fn engine_opts(
    hw: &HwConfig,
    m: &ModelConfig,
    kv: &KvManager,
    past: usize,
    batch: usize,
    quant: KvQuant,
) -> SimOptions {
    let gb = GbBudget::for_decode_quant(hw, m, past, batch, quant);
    let mut opts = SimOptions {
        act_bits: m.act_bits,
        prefetch: gb.fits_with_prefetch(),
        gb: Some(gb),
        ..SimOptions::paper(hw)
    };
    opts.kv_dequant_bytes_per_layer = kv.dequant_bytes_per_layer(batch, past);
    opts
}

#[test]
fn plan_matches_exact_stepper_across_depths_batches_and_quants() {
    // The headline parity sweep: past_len × batch × quant × architecture,
    // budgeted (engine-semantics) plans against the exact rebuild path.
    let hw = HwConfig::default();
    for name in ["s2t-small", "nmt-rdrop", "tiny", "bert-large"] {
        let m = ModelConfig::preset(name).unwrap();
        for batch in [1usize, 2, 4] {
            for quant in KvQuant::ALL {
                let plan = StepPlan::compile_budgeted(&hw, &m, batch, quant);
                let kv =
                    KvManager::new(&hw, &m, KvArenaConfig::for_pool(&hw, &m, quant, None));
                for past in [0usize, 1, 4, 16, 100] {
                    let opts = engine_opts(&hw, &m, &kv, past, batch, quant);
                    let exact = simulate(&hw, &build_decode_step(&m, past, batch), &opts);
                    let mut stepper = Stepper::new(&hw, opts);
                    stepper.run_plan(&plan, past);
                    let planned = stepper.finish();
                    let ctx = format!("{name} b{batch} {} past {past}", quant.name());
                    assert_bit_identical(&planned, &exact, &ctx);
                }
            }
        }
    }
}

#[test]
fn plan_parity_holds_under_tight_gb_spill_and_dequant() {
    // Shrunken GB: the sweep must traverse prefetch-on, single-buffered
    // and spilling regimes (and charge dequant under the reduced modes) —
    // with bit identity holding in all of them.
    let mut hw = HwConfig::default();
    hw.gb_bytes = 96 << 10;
    let m = ModelConfig::s2t_small();
    let (mut saw_spill, mut saw_single, mut saw_dequant) = (false, false, false);
    for quant in KvQuant::ALL {
        let kv = KvManager::new(&hw, &m, KvArenaConfig::for_pool(&hw, &m, quant, None));
        for batch in [1usize, 4] {
            let plan = StepPlan::compile_budgeted(&hw, &m, batch, quant);
            for past in [4usize, 64, 200] {
                let opts = engine_opts(&hw, &m, &kv, past, batch, quant);
                let exact = simulate(&hw, &build_decode_step(&m, past, batch), &opts);
                let mut stepper = Stepper::new(&hw, opts);
                stepper.run_plan(&plan, past);
                let planned = stepper.finish();
                let ctx = format!("tight-gb b{batch} {} past {past}", quant.name());
                assert_bit_identical(&planned, &exact, &ctx);
                saw_spill |= exact.ema.get(EmaCategory::ActivationSpill) > 0;
                saw_dequant |= exact.ema.get(EmaCategory::KvDequant) > 0;
                saw_single |= !opts.prefetch;
            }
        }
    }
    assert!(saw_spill, "sweep must exercise the spill regime");
    assert!(saw_single, "sweep must exercise the single-buffered regime");
    assert!(saw_dequant, "sweep must exercise the dequant charge");
}

#[test]
fn plan_chain_matches_program_chain_through_one_stepper() {
    // A full generation — prefill then T decode steps through ONE
    // persistent stepper — must finish bit-identical whether the decode
    // steps are rebuilt programs or plan replays (frontier, EMA and energy
    // all carry across the boundary between the two forms).
    let hw = HwConfig::default();
    let (prompt, gen) = (24usize, 12usize);
    for name in ["s2t-small", "tiny"] {
        let m = ModelConfig::preset(name).unwrap();
        for batch in [1usize, 4] {
            let opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
            let plan = StepPlan::compile_fixed(&hw, &m, batch, &opts);
            let mut exact = Stepper::new(&hw, opts);
            exact.run_program(&build_program(&m, prompt, batch));
            for t in 0..gen {
                exact.run_program(&build_decode_step(&m, prompt + t, batch));
            }
            let exact = exact.finish();
            let mut planned = Stepper::new(&hw, opts);
            planned.run_program(&build_program(&m, prompt, batch));
            for t in 0..gen {
                planned.run_plan(&plan, prompt + t);
            }
            let planned = planned.finish();
            assert_bit_identical(&planned, &exact, &format!("{name} b{batch} chain"));
            assert_eq!(exact.tokens, (prompt * batch + gen * batch) as u64);
        }
    }
}

#[test]
fn scratch_stepper_reset_reuse_is_bit_identical_to_fresh() {
    // The engine's hot path reuses ONE stepper (reset + run_plan + settle)
    // across steps; every step must read exactly what a fresh stepper
    // would. Revisited depths exercise the reset of every accumulator.
    let hw = HwConfig::default();
    let m = ModelConfig::s2t_small();
    let opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
    let plan = StepPlan::compile_budgeted(&hw, &m, 4, KvQuant::Int8);
    let mut scratch = Stepper::new(&hw, opts);
    for past in [8usize, 9, 33, 9, 8, 100, 8] {
        scratch.reset();
        scratch.run_plan(&plan, past);
        let s = scratch.settle();
        let fresh = {
            let mut st = Stepper::new(&hw, opts);
            st.run_plan(&plan, past);
            st.finish()
        };
        assert_eq!(s.cycles, fresh.cycles, "past {past}: cycles");
        assert_eq!(s.energy, fresh.energy, "past {past}: energy");
        assert_eq!(s.ema_bytes, fresh.ema_bytes(), "past {past}: ema");
        assert_eq!(s.tokens, fresh.tokens, "past {past}: tokens");
        assert_eq!(s.dmm_busy, fresh.dmm_busy, "past {past}: dmm busy");
        assert_eq!(s.smm_busy, fresh.smm_busy, "past {past}: smm busy");
        assert!(s.utilization(&hw) == fresh.utilization(&hw), "past {past}: utilization");
        assert!(s.seconds() == fresh.seconds(), "past {past}: seconds");
    }
}
