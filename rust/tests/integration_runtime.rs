//! Runtime + coordinator integration over the real AOT artifacts.
//! Skips politely if `make artifacts` hasn't been run (the manifest is the
//! stamp) or the crate was built without the `pjrt` feature. PJRT
//! executables are created inside each test's thread.
//! (Pool behavior over the always-available reference backend is covered in
//! `integration_pool.rs`.)

use std::path::PathBuf;
use std::time::Duration;
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, Request, Server, TraceGenerator,
};
use trex::kv::KvQuant;
use trex::runtime::{ArtifactSet, PjrtRuntime};

fn art_dir() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let p = PathBuf::from("../artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

#[test]
fn artifacts_load_and_self_test() {
    let Some(dir) = art_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let set = ArtifactSet::load(&rt, &dir).unwrap();
    assert_eq!(set.model_name, "tiny");
    assert_eq!(set.entries.len(), 3);
    set.self_test().unwrap();
}

#[test]
fn executable_rejects_bad_shapes() {
    let Some(dir) = art_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let set = ArtifactSet::load(&rt, &dir).unwrap();
    let e = set.entries.values().next().unwrap();
    assert!(e.exe.run_f32(&[0.0; 7], 1, 7).is_err() || e.tokens * e.d_model == 7);
}

#[test]
fn engine_executes_batches_and_strips_padding() {
    let Some(dir) = art_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let set = ArtifactSet::load(&rt, &dir).unwrap();
    let d = set.d_model;
    let mut engine = Engine::new(
        set,
        EngineConfig {
            hw: HwConfig::default(),
            perf_model: ModelConfig::tiny(),
            self_test: false,
            kv_quant: KvQuant::Fp16,
            kv_pages: None,
        },
    )
    .unwrap();

    // Four 5-token requests → class B4 (slot 8 on the 32-token tiny plane).
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request::new(i, 5, vec![0.1 * (i as f32 + 1.0); 5 * d]))
        .collect();
    let mut batcher = trex::coordinator::DynamicBatcher::new(BatcherConfig {
        max_seq: 32,
        max_wait: Duration::from_millis(1),
    });
    let mut formed = None;
    for r in reqs {
        if let Some(b) = batcher.push(r).unwrap() {
            formed = Some(b);
        }
    }
    let batch = formed.expect("4 B4 requests form a batch");
    let responses = engine.execute(batch).unwrap().responses;
    assert_eq!(responses.len(), 4);
    for r in &responses {
        assert_eq!(r.output.len(), 5 * d, "padding must be stripped");
        assert!(r.output.iter().all(|v| v.is_finite()));
        assert!(r.chip_us > 0.0 && r.chip_uj > 0.0 && r.ema_bytes > 0);
        assert!(r.queue_us >= 0.0, "queue time is clamped at zero");
    }
    // Distinct inputs ⇒ distinct outputs.
    assert_ne!(responses[0].output, responses[1].output);
}

#[test]
fn server_end_to_end_trace() {
    let Some(dir) = art_dir() else { return };
    let hw = HwConfig::default();
    let perf = ModelConfig::bert_large();
    let handle = Server::start(
        move |_ctx| {
            let rt = PjrtRuntime::cpu()?;
            let set = ArtifactSet::load(&rt, &dir)?;
            Engine::new(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: perf.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
            )
        },
        BatcherConfig { max_seq: 32, max_wait: Duration::from_millis(1) },
    );
    let mut gen = TraceGenerator::for_model(&ModelConfig::bert_large(), 32, 64, 3);
    let n = 24;
    for _ in 0..n {
        handle.submit(gen.next()).unwrap();
    }
    let mut got = 0;
    while got < n {
        let r = handle.responses.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(r.output.iter().all(|v| v.is_finite()));
        got += 1;
    }
    let report = handle.shutdown().unwrap();
    let j = report.json();
    assert_eq!(j.get("completed").unwrap().as_f64().unwrap(), n as f64);
    assert!(j.get("utilization_mean").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn engine_rejects_oversized_request() {
    let Some(dir) = art_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let set = ArtifactSet::load(&rt, &dir).unwrap();
    let d = set.d_model;
    let mut engine = Engine::new(
        set,
        EngineConfig {
            hw: HwConfig::default(),
            perf_model: ModelConfig::tiny(),
            self_test: false,
            kv_quant: KvQuant::Fp16,
            kv_pages: None,
        },
    )
    .unwrap();
    // A 20-token request shoved into a B4 batch (slot 8) must error.
    let batch = trex::coordinator::batcher::FormedBatch {
        class: trex::sim::BatchClass::B4,
        requests: vec![Request::new(0, 20, vec![0.0; 20 * d])],
    };
    assert!(engine.execute(batch).is_err());
}
