//! Cross-module integration: model programs × simulator × ledger coherence,
//! plus failure injection on configs and generative sweeps.

use trex::baseline::dense_program;
use trex::compress::CompressionReport;
use trex::config::{HwConfig, ModelConfig, WORKLOADS};
use trex::model::build_program;
use trex::sim::{batch_class, simulate, simulate_workload, SimOptions};
use trex::util::rng::Rng;

#[test]
fn all_workloads_all_classes_simulate() {
    let hw = HwConfig::default();
    for name in WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        for (seq, batch) in [(128, 1), (64, 2), (32, 4), (100, 1), (17, 4)] {
            let prog = build_program(&m, seq, batch);
            let s = simulate(&hw, &prog, &SimOptions::paper(&hw));
            assert!(s.cycles > 0, "{name} {seq}x{batch}");
            let u = s.utilization(&hw);
            assert!(u > 0.0 && u <= 1.0, "{name} {seq}x{batch}: util {u}");
            assert!(s.avg_power_mw() <= s.point.peak_mw * 1.05, "{name}: power");
        }
    }
}

#[test]
fn generative_sweep_invariants() {
    // Random (seq, batch, vdd, trf, prefetch) points: physical invariants
    // must hold everywhere.
    let hw = HwConfig::default();
    let m = ModelConfig::s2t_small();
    let mut rng = Rng::new(2024);
    for _ in 0..40 {
        let batch = [1, 2, 4][rng.below(3)];
        let seq = rng.range(1, hw.max_seq / batch);
        let opts = SimOptions {
            point: hw.point_at_vdd(rng.f64_range(0.4, 0.9)),
            trf: rng.below(2) == 0,
            prefetch: rng.below(2) == 0,
            act_bits: 8,
            ..SimOptions::paper(&hw)
        };
        let prog = build_program(&m, seq, batch);
        let s = simulate(&hw, &prog, &opts);
        assert!(s.cycles > 0);
        assert!(s.utilization(&hw) <= 1.0);
        assert!(s.energy.total_pj() > 0.0);
        assert!(s.energy.ema_share() >= 0.0 && s.energy.ema_share() <= 1.0);
        // Energy must be at least the EMA floor (bytes are precision-exact).
        let ema_pj = s.ema_bytes() as f64 * 8.0 * hw.dram_pj_per_bit;
        assert!((s.energy.ema_pj - ema_pj).abs() < 1.0);
    }
}

#[test]
fn program_weight_bytes_equal_simulated_ledger() {
    // The program builder's byte accounting and the executor's ledger must
    // agree exactly — no EMA bytes invented or dropped.
    let hw = HwConfig::default();
    for name in ["tiny", "nmt-rdrop"] {
        let m = ModelConfig::preset(name).unwrap();
        let prog = build_program(&m, m.max_seq.min(64), 2);
        let s = simulate(&hw, &prog, &SimOptions::paper(&hw));
        let from_prog: u64 = prog.ops.iter().map(|o| o.dma_bytes()).sum();
        assert_eq!(s.ema_bytes(), from_prog, "{name}");
    }
}

#[test]
fn fig6_shape_trex_beats_dense_on_every_workload() {
    let hw = HwConfig::default();
    let opts = SimOptions::paper(&hw);
    for name in WORKLOADS {
        let m = ModelConfig::preset(name).unwrap();
        let seq = (m.mean_input_len as usize).clamp(1, m.max_seq);
        let batch = batch_class(seq, hw.max_seq).unwrap().batch();
        let trex = simulate(&hw, &build_program(&m, seq, batch), &opts);
        let dense = simulate(&hw, &dense_program(&m, seq), &opts);
        // Per-input EMA reduction (the paper's 31–65.9×) > 10× everywhere.
        let ema_gain = dense.ema_bytes() as f64
            / (trex.ema_bytes() as f64 / trex.inputs as f64);
        assert!(ema_gain > 10.0, "{name}: EMA gain {ema_gain:.1}");
        // And faster per input.
        let t_trex = trex.seconds() / trex.inputs as f64;
        assert!(t_trex < dense.seconds(), "{name}: latency");
    }
}

#[test]
fn static_report_tracks_dynamic_ledger() {
    // CompressionReport (analytic bytes) vs what the simulator streams.
    let m = ModelConfig::vit_base();
    let hw = HwConfig::default();
    let rep = CompressionReport::analytic(&m);
    let s = simulate_workload(&hw, &m, m.max_seq, 1);
    let dynamic_wd = s.ema.get(trex::compress::EmaCategory::WdValues)
        + s.ema.get(trex::compress::EmaCategory::WdIndices)
        + s.ema.get(trex::compress::EmaCategory::Metadata);
    let statically = rep.compressed_bytes - rep.ws_compressed_bytes;
    let rel = (dynamic_wd as f64 - statically as f64).abs() / statically as f64;
    assert!(rel < 0.02, "dynamic {dynamic_wd} vs static {statically}");
}

#[test]
fn config_failure_injection() {
    // Corrupt JSON configs must produce typed errors, not panics.
    use trex::util::json::Json;
    let hw = HwConfig::default();
    let mut j = hw.to_json();
    // Remove a required field.
    if let Json::Obj(m) = &mut j {
        m.remove("dram_gbps");
    }
    assert!(HwConfig::from_json(&j).is_err());
    // Model with broken invariants.
    let m = ModelConfig::tiny();
    let mut mj = m.to_json();
    if let Json::Obj(o) = &mut mj {
        o.insert("rank".into(), Json::Num(0.0));
    }
    let parsed = ModelConfig::from_json(&mj).unwrap();
    assert!(parsed.validate(128).is_err());
    // Garbage text.
    assert!(Json::parse("{not json").is_err());
}

#[test]
fn batch_class_boundaries_match_hw() {
    let hw = HwConfig::default();
    assert_eq!(batch_class(65, hw.max_seq).unwrap().batch(), 1);
    assert_eq!(batch_class(64, hw.max_seq).unwrap().batch(), 2);
    assert_eq!(batch_class(33, hw.max_seq).unwrap().batch(), 2);
    assert_eq!(batch_class(32, hw.max_seq).unwrap().batch(), 4);
    assert!(batch_class(129, hw.max_seq).is_err());
}
