//! Workload-plane integration over the deterministic reference backend:
//! trace parsing round-trips, open-loop replay against a live pool with
//! lifecycle-ledger conservation, KV residual cleanliness after drain, and
//! short end-to-end fuzzer runs (the CI job runs the long ones).
//!
//! Parser *unit* coverage (every malformed-field variant, line numbers)
//! lives in `src/workload/trace_file.rs`; this file covers the seams the
//! units can't: a parsed trace driving a real pool, and the replay/ledger
//! counters agreeing with each other.

use std::sync::Arc;
use std::time::Duration;
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, PoolConfig, Server, ServerHandle,
};
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::runtime::ArtifactSet;
use trex::workload::{
    replay, run_fuzz, synth_trace, FuzzConfig, ReplayConfig, SynthSpec, Trace, TraceErrorKind,
};

const MAX_SEQ: usize = 32;
const D: usize = 64;

fn start(pool: PoolConfig) -> ServerHandle {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("tiny", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    )
}

fn ledgered_pool(queue_depth: usize, max_inflight: usize) -> (PoolConfig, Arc<KvManager>) {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let kv = Arc::new(KvManager::new(
        &hw,
        &pm,
        KvArenaConfig::for_pool(&hw, &pm, KvQuant::Fp16, None),
    ));
    let pool = PoolConfig {
        workers: 2,
        queue_depth,
        max_inflight,
        kv: Some(Arc::clone(&kv)),
        lifecycle_ledger: true,
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::from_micros(200) },
        ..PoolConfig::default()
    };
    (pool, kv)
}

#[test]
fn parsed_trace_replays_with_conservation_and_clean_kv() {
    // A hand-written trace (comments, blank lines, prefix groups, mixed
    // encode/generate) goes file-text -> Trace -> live pool.
    let text = "\
# id arrival_us class prompt_len gen_len [prefix_group]
0 0    interactive 6  2 g0
1 150  interactive 6  2 g0

2 300  batch       24 0
3 450  interactive 4  3
4 600  batch       30 0
5 700  interactive 8  1 g0
";
    let trace = Trace::parse(text).expect("well-formed trace");
    assert_eq!(trace.len(), 6);
    assert_eq!(trace.span_us(), 700);

    let (pool, kv) = ledgered_pool(0, 0);
    let handle = start(pool);
    let stats = replay(&handle, &trace, &ReplayConfig::new(D));
    let metrics = Arc::clone(&handle.metrics);
    handle.shutdown().unwrap();

    // Unbounded pool under trivial load: everything admits and completes.
    assert_eq!(stats.offered, 6);
    assert_eq!(stats.admitted, 6);
    assert_eq!(stats.shed_at_door, 0);
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.shed_after_admit, 0);
    assert!(stats.drained);
    assert!(stats.tokens_streamed >= 2 + 2 + 3 + 1, "every generate token streams");
    assert!(stats.latency_us_p95 > 0.0);

    // The ledger saw the same story, and the arena holds nothing.
    let audit = metrics.ledger_audit().expect("ledger was enabled");
    assert!(audit.conserved(), "violations: {:?}", audit.violations);
    assert_eq!(audit.completed, 6);
    assert_eq!(audit.shed, 0);
    assert!(kv.residual().is_clean(), "residual: {:?}", kv.residual());
}

#[test]
fn open_loop_replay_sheds_at_the_door_and_still_conserves() {
    // A tightly bounded pool offered a dense synthetic burst must refuse
    // some of it synchronously — and the refusals must show up as door
    // sheds in both the replay stats and the ledger, with zero residual.
    let spec = SynthSpec {
        generate_share: 0.5,
        gen_tokens: 2,
        ..SynthSpec::steady(0x51ED, 4000.0, 40_000, MAX_SEQ)
    };
    let trace = synth_trace(&spec);
    assert!(trace.len() > 40, "dense trace expected, got {}", trace.len());

    let (pool, kv) = ledgered_pool(1, 2);
    let handle = start(pool);
    let stats = replay(&handle, &trace, &ReplayConfig::new(D));
    let metrics = Arc::clone(&handle.metrics);
    handle.shutdown().unwrap();

    assert_eq!(stats.admitted + stats.shed_at_door, stats.offered);
    assert!(
        stats.shed_at_door > 0,
        "a 2-in-flight pool cannot absorb a 4k rps burst: {stats:?}"
    );
    assert_eq!(stats.completed, stats.admitted, "admitted work all answers");
    assert!(stats.drained);

    let audit = metrics.ledger_audit().expect("ledger was enabled");
    assert!(audit.conserved(), "violations: {:?}", audit.violations);
    assert_eq!(audit.completed as usize, stats.admitted);
    assert!(kv.residual().is_clean(), "residual: {:?}", kv.residual());
}

#[test]
fn replay_speed_compresses_the_trace_clock() {
    // 400 ms of trace clock at 20x replays in ~20 ms of wall (plus service
    // and drain) — the cheap way to overload from a calibrated trace.
    let spec = SynthSpec {
        generate_share: 0.0,
        ..SynthSpec::steady(0x5BEE, 150.0, 400_000, MAX_SEQ)
    };
    let trace = synth_trace(&spec);
    let (pool, _kv) = ledgered_pool(0, 0);
    let handle = start(pool);
    let stats = replay(&handle, &trace, &ReplayConfig::new(D).at_speed(20.0));
    handle.shutdown().unwrap();
    assert_eq!(stats.completed, stats.offered);
    assert!(
        stats.wall_seconds < 0.2,
        "20x speed must beat the 0.4 s trace span by a wide margin, took {:.3} s",
        stats.wall_seconds
    );
}

#[test]
fn trace_round_trips_through_text() {
    let spec = SynthSpec {
        generate_share: 0.5,
        prefix_groups: 3,
        ..SynthSpec::steady(0xD0C, 2000.0, 20_000, MAX_SEQ)
    };
    let trace = synth_trace(&spec);
    let reparsed = Trace::parse(&trace.to_text()).expect("synth output reparses");
    assert_eq!(reparsed.records, trace.records);
}

#[test]
fn parse_errors_carry_line_numbers_end_to_end() {
    // The replay path surfaces parse failures before any pool spins up;
    // line numbers are what makes a 50k-line trace debuggable.
    let text = "0 0 interactive 4 0\n1 100 interactive nope 0\n";
    let err = Trace::parse(text).unwrap_err();
    assert_eq!(err.line, 2);
    assert!(matches!(err.kind, TraceErrorKind::Malformed { .. }));
    let msg = err.to_string();
    assert!(msg.contains("line 2"), "got: {msg}");

    let non_monotone = "0 500 interactive 4 0\n1 100 interactive 4 0\n";
    let err = Trace::parse(non_monotone).unwrap_err();
    assert_eq!(err.line, 2);
    assert!(matches!(err.kind, TraceErrorKind::NonMonotoneArrival { .. }));
}

#[test]
fn fuzzer_holds_invariants_across_seeds() {
    // A broader sweep than the unit smoke: 6 scenarios end-to-end. The CI
    // fuzz job runs 200 with a run-unique seed; this pins determinism and
    // the invariant plumbing into `cargo test`.
    let summary = run_fuzz(&FuzzConfig { seed: 0x7E57ED, iters: 6, ..FuzzConfig::default() });
    assert_eq!(summary.iters_run, 6);
    assert!(
        summary.ok(),
        "fuzz violation:\n{}",
        summary.failure.map(|f| f.render()).unwrap_or_default()
    );
}

#[test]
fn fuzz_failure_render_names_the_seed() {
    // The CI contract: a failure must print the exact reproduce command.
    use trex::workload::FuzzFailure;
    let f = FuzzFailure {
        seed: 0xBAD5EED,
        iteration: 3,
        violations: vec!["request 7: double terminal".to_string()],
        scenario: "workers=1".to_string(),
        snippet: "7 0 interactive 4 0".to_string(),
    };
    let r = f.render();
    assert!(r.contains(&format!("--seed {}", 0xBAD5EEDu64)), "got: {r}");
    assert!(r.contains("--iters 1"), "got: {r}");
    assert!(r.contains("double terminal"), "got: {r}");
}
