//! Cross-language codec contract: python (`compile/compress.py`) encodes,
//! Rust decodes — byte streams must be identical in both directions.
//! The fixture is produced by `make artifacts` (aot.py); tests skip politely
//! when artifacts haven't been built.

use trex::compress::{DeltaCodec, EncodedIndices, NonUniformQuant, UniformQuant};
use trex::factorize::CscFixed;
use trex::util::json::Json;
use trex::util::mat::Mat;

fn fixture() -> Option<Json> {
    let path = std::path::Path::new("../artifacts/codec_fixture.json");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts` to build the codec fixture");
        return None;
    }
    Some(Json::from_file(path).expect("fixture parses"))
}

fn hex_decode(s: &str) -> Vec<u8> {
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
        .collect()
}

#[test]
fn nonuniform_python_encoding_matches_rust() {
    let Some(fx) = fixture() else { return };
    let nu = fx.get("nonuniform").unwrap();
    let lut: Vec<f32> = nu.get("lut").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as f32).collect();
    let rows = nu.get("rows").unwrap().as_usize().unwrap();
    let cols = nu.get("cols").unwrap().as_usize().unwrap();
    let values: Vec<f32> = nu.get("values").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as f32).collect();
    let expected = hex_decode(nu.get("encoded_hex").unwrap().as_str().unwrap());

    let q = NonUniformQuant { lut, bits: 4 };
    let w = Mat::from_vec(rows, cols, values).unwrap();
    // Rust encode == python encode, byte for byte.
    let got = q.encode(&w).unwrap();
    assert_eq!(got, expected, "rust-encoded bytes differ from python");
    // And rust decode of the python bytes == quantize-dequantize.
    let dec = q.decode(&expected, rows, cols).unwrap();
    assert_eq!(dec, q.apply(&w));
}

#[test]
fn uniform_python_encoding_matches_rust() {
    let Some(fx) = fixture() else { return };
    let u = fx.get("uniform").unwrap();
    let offset = u.get("offset").unwrap().as_f64().unwrap() as f32;
    let scale = u.get("scale").unwrap().as_f64().unwrap() as f32;
    let values: Vec<f32> = u.get("values").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap() as f32).collect();
    let expected = hex_decode(u.get("encoded_hex").unwrap().as_str().unwrap());

    let q = UniformQuant { offset, scale, bits: 6 };
    let got = q.encode(&values).unwrap();
    assert_eq!(got, expected, "rust-encoded bytes differ from python");
    let dec = q.decode(&expected, values.len()).unwrap();
    for (orig, d) in values.iter().zip(&dec) {
        assert!((orig - d).abs() <= q.max_abs_err() * 1.001);
    }
}

#[test]
fn delta_python_encoding_matches_rust() {
    let Some(fx) = fixture() else { return };
    let d = fx.get("delta").unwrap();
    let rows = d.get("rows").unwrap().as_usize().unwrap();
    let cols = d.get("cols").unwrap().as_usize().unwrap();
    let nnz = d.get("nnz_per_col").unwrap().as_usize().unwrap();
    let idx: Vec<u16> = d.get("indices").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_usize().unwrap() as u16).collect();
    let expected = hex_decode(d.get("encoded_hex").unwrap().as_str().unwrap());
    let n_escapes = d.get("n_escapes").unwrap().as_usize().unwrap();

    let sp = CscFixed { rows, cols, nnz_per_col: nnz, idx: idx.clone(), val: vec![0.0; idx.len()] };
    sp.check_invariants().unwrap();
    let codec = DeltaCodec::new(5, rows).unwrap();
    let enc = codec.encode(&sp).unwrap();
    assert_eq!(enc.bytes, expected, "rust-encoded bytes differ from python");
    assert_eq!(enc.n_escapes, n_escapes);
    // Decode the python bytes back to the exact index plane.
    let enc2 = EncodedIndices { bytes: expected, n_indices: idx.len(), n_escapes, codec };
    let back = codec.decode(&enc2, rows, cols, nnz).unwrap();
    assert_eq!(back, idx);
}
