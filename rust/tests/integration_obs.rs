//! Observability integration over the deterministic reference backend:
//! the flight recorder's lifecycle spans tile a generate request's true
//! end-to-end latency, the Chrome trace export round-trips through the
//! inspect parser, the time-series sampler records snapshots, and anomaly
//! dumps (ledger violations, fuzz failures) restate their violations in
//! their final lines.

use std::sync::Arc;
use std::time::Duration;
use trex::config::{HwConfig, ModelConfig};
use trex::coordinator::{
    BatcherConfig, Engine, EngineConfig, PoolConfig, Request, Server, ServerHandle,
};
use trex::kv::KvQuant;
use trex::obs::{
    chrome_trace, dump_anomaly, parse_trace, spans_jsonl, FlightRecorder, SpanKind, SpanWriter,
    TelemetryConfig,
};
use trex::runtime::ArtifactSet;
use trex::util::json::Json;
use trex::workload::FuzzFailure;

const MAX_SEQ: usize = 32;
const D: usize = 64;

fn start(pool: PoolConfig) -> ServerHandle {
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference("tiny", D, MAX_SEQ)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: false,
                    kv_quant: KvQuant::Fp16,
                    kv_pages: None,
                },
                ctx,
            )
        },
        pool,
    )
}

/// The acceptance criterion: one generate request's lifecycle spans
/// (queue → prefill → every decode step → complete) are present, ordered,
/// tile exactly (each starts where the previous ended), and sum to the
/// reported end-to-end latency.
#[test]
fn lifecycle_spans_tile_and_sum_to_e2e_latency() {
    let recorder = Arc::new(FlightRecorder::for_pool(1, 4096));
    let handle = start(PoolConfig {
        workers: 1,
        recorder: Some(Arc::clone(&recorder)),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    });
    let n_gen = 8;
    let req = Request::new(7, 6, vec![0.1; 6 * D]).with_generate(n_gen);
    handle.submit(req).unwrap();
    let resp = handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(resp.id, 7);
    assert_eq!(resp.tokens_generated, n_gen);
    handle.shutdown().unwrap();

    let events = recorder.snapshot();
    let life: Vec<_> =
        events.iter().filter(|e| e.id == 7 && e.kind.is_lifecycle()).copied().collect();

    // Present and ordered: queue, prefill, one span per decode token, then
    // the zero-duration completion marker.
    assert_eq!(life.len(), 2 + n_gen + 1, "queue + prefill + {n_gen} steps + complete");
    assert_eq!(life[0].kind, SpanKind::Queue);
    assert_eq!(life[1].kind, SpanKind::Prefill);
    for ev in &life[2..2 + n_gen] {
        assert_eq!(ev.kind, SpanKind::DecodeStep);
    }
    let last = life.last().unwrap();
    assert_eq!(last.kind, SpanKind::Complete);
    assert_eq!(last.t_start_us, last.t_end_us, "complete is a marker");

    // Tiling: each lifecycle span starts exactly where the previous ended
    // (the cursors are copied, not re-measured — the diff is 0.0).
    for w in life.windows(2) {
        assert!(
            (w[1].t_start_us - w[0].t_end_us).abs() < 1e-6,
            "span gap: {:?} ends {} but {:?} starts {}",
            w[0].kind,
            w[0].t_end_us,
            w[1].kind,
            w[1].t_start_us
        );
    }

    // Sum == reported e2e, within clock-read skew: the span endpoints and
    // the response latency are measured by adjacent-but-distinct clock
    // reads, so allow a scheduler-hiccup-sized absolute slack.
    let span_sum: f64 = life.iter().map(|e| e.t_end_us - e.t_start_us).sum();
    let e2e = resp.e2e_us();
    assert!(
        (span_sum - e2e).abs() <= 500.0 + 0.05 * e2e,
        "lifecycle spans sum to {span_sum:.1}µs but e2e is {e2e:.1}µs"
    );

    // Decode spans carry the per-token attribution the summary feeds on.
    for ev in &life[2..2 + n_gen] {
        assert!(ev.chip_us > 0.0, "decode span carries chip time");
        assert!(ev.chip_uj > 0.0, "decode span carries energy");
    }
}

/// The Chrome trace_event export is valid JSON with both views (workers =
/// pid 1, per-request streams = pid 2) and round-trips through the
/// inspect parser: every exported duration event in the worker view comes
/// back as a span.
#[test]
fn chrome_trace_round_trips_through_inspect_parser() {
    let recorder = Arc::new(FlightRecorder::for_pool(1, 4096));
    let handle = start(PoolConfig {
        workers: 1,
        recorder: Some(Arc::clone(&recorder)),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    });
    for i in 0..3u64 {
        handle.submit(Request::new(i, 4, vec![0.1; 4 * D]).with_generate(4)).unwrap();
    }
    for _ in 0..3 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    handle.shutdown().unwrap();

    let events = recorder.snapshot();
    assert!(!events.is_empty());
    let trace = chrome_trace(&events, 1);
    let text = trace.to_string();
    let parsed = Json::parse(&text).expect("chrome trace is valid JSON");
    let arr = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let pids: Vec<f64> = arr
        .iter()
        .filter_map(|e| e.opt("pid").and_then(|p| p.as_f64().ok()))
        .collect();
    assert!(pids.contains(&1.0), "worker view present");
    assert!(pids.contains(&2.0), "stream view present");

    // Round-trip: the inspect parser recovers the worker view, where every
    // recorded event (spans and markers alike) appears exactly once.
    let back = parse_trace(&text).expect("inspect parses its own export");
    assert_eq!(back.len(), events.len(), "every event round-trips via the worker view");

    // The JSONL export parses line-by-line and keeps every event.
    let jsonl = spans_jsonl(&events);
    let back_jsonl = parse_trace(&jsonl).expect("inspect parses span JSONL");
    assert_eq!(back_jsonl.len(), events.len());
}

/// The time-series sampler records snapshots into the bounded ring and to
/// JSONL, each carrying the report schema version.
#[test]
fn sampler_records_snapshots_and_jsonl() {
    let out = std::env::temp_dir().join("trex-test-telemetry.jsonl");
    let _ = std::fs::remove_file(&out);
    let handle = start(PoolConfig {
        workers: 1,
        telemetry: Some(TelemetryConfig {
            interval: Duration::from_micros(500),
            capacity: 64,
            out: Some(out.clone()),
            ..TelemetryConfig::default()
        }),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    });
    for i in 0..4u64 {
        handle.submit(Request::new(i, 4, vec![0.1; 4 * D]).with_generate(6)).unwrap();
    }
    for _ in 0..4 {
        handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let report = handle.shutdown().unwrap();

    let ring = report.telemetry.as_ref().expect("telemetry ring in report");
    assert!(ring.taken() >= 1, "sampler took at least one snapshot");
    let last = ring.last().unwrap();
    assert_eq!(last.completed, 4);

    let text = std::fs::read_to_string(&out).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
    assert!(!lines.is_empty());
    for line in &lines {
        let j = Json::parse(line).expect("telemetry line is valid JSON");
        assert!(j.get("schema_version").unwrap().as_u64().unwrap() >= 1);
    }
    let _ = std::fs::remove_file(&out);
}

/// A forced lifecycle-ledger violation produces an anomaly dump whose
/// final lines restate exactly the violations it was taken for, after the
/// recorder's retained spans.
#[test]
fn ledger_violation_anomaly_dump_ends_with_the_violation() {
    let recorder = Arc::new(FlightRecorder::for_pool(1, 256));
    let handle = start(PoolConfig {
        workers: 1,
        lifecycle_ledger: true,
        recorder: Some(Arc::clone(&recorder)),
        batcher: BatcherConfig { max_seq: MAX_SEQ, max_wait: Duration::ZERO },
        ..PoolConfig::default()
    });
    handle.submit(Request::new(1, 4, vec![0.1; 4 * D])).unwrap();
    handle.responses.recv_timeout(Duration::from_secs(30)).unwrap();
    // Force the violation: an admission the pool never resolves.
    handle.metrics.ledger_admit(999);
    let report = handle.shutdown().unwrap();

    let audit = report.metrics.ledger_audit().expect("ledger was on");
    assert!(!audit.conserved(), "unresolved admission must fail the audit");
    assert!(!audit.violations.is_empty());

    let path = std::env::temp_dir().join("trex-test-ledger-anomaly.jsonl");
    let written = dump_anomaly(&recorder, &path, &audit.violations).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), written + audit.violations.len());

    // Final lines: one violation record per audit violation, verbatim.
    let tail = &lines[written..];
    for (line, v) in tail.iter().zip(&audit.violations) {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "violation");
        assert_eq!(j.get("detail").unwrap().as_str().unwrap(), v.as_str());
    }
    // And the span lines before them are the recorder's events.
    for line in &lines[..written] {
        let j = Json::parse(line).unwrap();
        assert!(j.opt("kind").is_some() && j.opt("ts_us").is_some());
    }
    let _ = std::fs::remove_file(&path);
}

/// The fuzz-failure path writes the same dump format and its reproduce
/// line names the dump, so one CI line carries seed + span history.
#[test]
fn fuzz_failure_dump_matches_violations_and_render_names_it() {
    // The dump exactly as `workload::fuzz::exec` writes it on a failing
    // interleaving: the run's recorder drained, violations appended last.
    let recorder = Arc::new(FlightRecorder::for_pool(2, 64));
    let w = SpanWriter::new(Arc::clone(&recorder), 0);
    w.record(trex::obs::SpanEvent::marker(SpanKind::Admit, 3, w.now_us()));
    w.record(trex::obs::SpanEvent::marker(SpanKind::Shed, 3, w.now_us()));
    let violations =
        vec!["conservation: admitted 3 != completed 1 + shed 1".to_string()];
    let path = std::env::temp_dir().join("trex-test-fuzz-anomaly.jsonl");
    let written = dump_anomaly(&recorder, &path, &violations).unwrap();
    assert_eq!(written, 2);
    let text = std::fs::read_to_string(&path).unwrap();
    let last = text.lines().last().unwrap();
    let j = Json::parse(last).unwrap();
    assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "violation");
    assert_eq!(j.get("detail").unwrap().as_str().unwrap(), violations[0]);

    let failure = FuzzFailure {
        seed: 0xBEEF,
        iteration: 4,
        violations,
        scenario: "workers=2 queue=8".to_string(),
        snippet: "0 0 chat 4 2".to_string(),
        dump_path: Some(path.display().to_string()),
    };
    let rendered = failure.render();
    assert!(
        rendered.contains(&format!("flight-recorder dump: {}", path.display())),
        "reproduce line names the dump: {rendered}"
    );
    assert!(rendered.contains("--seed 48879"), "reproduce line carries the seed");
    let _ = std::fs::remove_file(&path);
}
