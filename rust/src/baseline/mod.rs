//! Baselines for the paper's comparisons.
//!
//! * [`dense_program`] — the *unfactorized* comparator: the same chip runs
//!   the original model with dense 16b weights streamed from DRAM every
//!   layer, no dynamic batching. This is the denominator of the paper's
//!   "31–65.9× less EMA" and Fig. 23.1.1 EMA-share analysis.
//! * [`prior`] — the ISSCC/VLSI comparison rows of Fig. 23.1.6, with the
//!   paper's own method of adding EMA cost (3.7 pJ/b, 6.4 GB/s) to works
//!   that report core-only numbers.

pub mod prior;

pub use prior::{prior_works, PriorWork};

use crate::config::ModelConfig;
use crate::model::{Op, Program};

/// Build the dense-baseline op program: every weight matrix `W` is streamed
/// at 16b and multiplied as `X·W` on the DMM plane (w_bits = 16 — the
/// bit-serial MACs take 16 cycles against 8b activations' 2 passes… i.e.
/// `mac_cycles(8,16) = 8`).
pub fn dense_program(m: &ModelConfig, seq: usize) -> Program {
    let mut ops = Vec::new();
    let rows = seq; // no dynamic batching in the baseline
    let act_bytes = |elems: usize| (elems * m.act_bits as usize / 8) as u64;
    ops.push(Op::load_input(act_bytes(rows * m.d_model)));

    let layer_ops = |ops: &mut Vec<Op>, l: usize, cross_attn: bool| {
        let d = m.d_model;
        let ff = m.d_ff;
        let h = m.heads;
        let dh = d / h;
        let proj = |ops: &mut Vec<Op>, name: &'static str, d_in: usize, d_out: usize| {
            // Stream the dense 16b weight matrix.
            ops.push(Op::load_dense_weights(l, name, (d_in * d_out * 2) as u64));
            ops.push(Op::dmm_dense16(l, name, rows, d_in, d_out));
        };
        for name in ["wq", "wk", "wv"] {
            proj(ops, name, d, d);
        }
        ops.push(Op::dmm_batched(l, "attn_scores", h, seq, dh, seq));
        ops.push(Op::softmax(l, h * seq, seq));
        ops.push(Op::dmm_batched(l, "attn_context", h, seq, seq, dh));
        proj(ops, "wo", d, d);
        ops.push(Op::residual(l, rows, d));
        ops.push(Op::layernorm(l, rows, d));
        if cross_attn {
            for name in ["x_wq", "x_wk", "x_wv"] {
                proj(ops, name, d, d);
            }
            ops.push(Op::dmm_batched(l, "attn_scores", h, seq, dh, seq));
            ops.push(Op::softmax(l, h * seq, seq));
            ops.push(Op::dmm_batched(l, "attn_context", h, seq, seq, dh));
            proj(ops, "x_wo", d, d);
            ops.push(Op::residual(l, rows, d));
            ops.push(Op::layernorm(l, rows, d));
        }
        proj(ops, "ffn_up", d, ff);
        ops.push(Op::gelu(l, rows, ff));
        proj(ops, "ffn_down", ff, d);
        ops.push(Op::residual(l, rows, d));
        ops.push(Op::layernorm(l, rows, d));
    };

    for l in 0..m.enc_layers {
        layer_ops(&mut ops, l, false);
    }
    for l in 0..m.dec_layers {
        layer_ops(&mut ops, m.enc_layers + l, true);
    }
    ops.push(Op::store_output(act_bytes(rows * m.d_model)));
    Program::from_ops(format!("{}-dense", m.name), 1, seq, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::prior_works;
    use crate::config::{HwConfig, ModelConfig, WORKLOADS};
    use crate::sim::{simulate, SimOptions};

    #[test]
    fn dense_baseline_ema_ratio_in_paper_band() {
        // Paper Fig. 23.1.6: T-REX needs 31–65.9× less EMA than running the
        // unfactorized models (with dynamic batching on the T-REX side for
        // short-input workloads).
        let hw = HwConfig::default();
        let m = ModelConfig::bert_large();
        let dense = dense_program(&m, 32);
        let opts = SimOptions::paper(&hw);
        let d = simulate(&hw, &dense, &opts);
        // T-REX: same 4 × 32-token inputs in one batched pass.
        let trex = crate::model::build_program(&m, 32, 4);
        let t = simulate(&hw, &trex, &opts);
        let per_input_dense = d.ema_bytes() as f64; // 1 input
        let per_input_trex = t.ema_bytes() as f64 / t.inputs as f64;
        let ratio = per_input_dense / per_input_trex;
        // Paper band: 31–65.9×. Our batch amortization is ideal (no partial
        // batches, no scheduling slack), so we land at the top of / slightly
        // above the band — see EXPERIMENTS.md.
        assert!(
            (25.0..110.0).contains(&ratio),
            "EMA reduction {ratio:.1}× outside the paper's 31–65.9× neighborhood"
        );
    }

    #[test]
    fn prior_accelerators_are_ema_dominated() {
        // Fig. 23.1.1: EMA accounts for up to 81% of total energy when the
        // LPDDR3 cost is added to prior accelerators' core-only numbers.
        let max_share = prior_works()
            .iter()
            .filter(|w| !w.includes_ema)
            .map(|w| {
                let ema = w.uj_per_token_with_ema() - w.uj_per_token;
                ema / w.uj_per_token_with_ema()
            })
            .fold(0.0f64, f64::max);
        assert!((0.6..0.97).contains(&max_share), "max EMA share {max_share:.2}");
    }

    #[test]
    fn trex_flips_ema_share() {
        let hw = HwConfig::default();
        let m = ModelConfig::bert_large();
        let opts = SimOptions::paper(&hw);
        let dense = simulate(&hw, &dense_program(&m, 128), &opts);
        let trex = simulate(&hw, &crate::model::build_program(&m, 128, 1), &opts);
        assert!(trex.energy.ema_share() < dense.energy.ema_share());
    }

    #[test]
    fn utilization_gain_in_paper_band() {
        // Fig. 23.1.6: 1.2–3.4× higher utilization. The gain comes from the
        // two utilization features (dynamic batching + TRFs) at each
        // workload's characteristic input length: full-length ViT gets only
        // the TRF gain (paper's 1.2× floor); short-input BERT gets the full
        // batching recovery (paper's 3.4× ceiling).
        let hw = HwConfig::default();
        let on = SimOptions::paper(&hw);
        let mut gains = Vec::new();
        for name in WORKLOADS {
            let m = ModelConfig::preset(name).unwrap();
            let seq = (m.mean_input_len as usize).clamp(1, m.max_seq);
            let batch = crate::sim::batch_class(seq, hw.max_seq).unwrap().batch();
            // Batching-only gain (TRF on in both): the Fig. 23.1.4 claim,
            // "up to 3.31x" — ideal is `batch`, overheads shave it.
            let with = simulate(&hw, &crate::model::build_program(&m, seq, batch), &on);
            let without = simulate(&hw, &crate::model::build_program(&m, seq, 1), &on);
            let gain = with.utilization(&hw) / without.utilization(&hw);
            // Gain can exceed `batch` because batching also fills padded
            // MAC lanes (28-token inputs use 28 of 64 SMM lanes alone but
            // 112 of 128 four-up). The paper measures 3.31x peak; we land
            // 4-6.5x because our B1 starvation is ideal-worst-case — the
            // decomposition is reported by `fig4_dynamic_batching`.
            assert!(
                gain >= 0.99 && gain <= batch as f64 * 1.7,
                "{name}: batching gain {gain:.2} vs ideal batch {batch}"
            );
            gains.push((name, gain));
        }
        // Shape: the short-input workload (bert) gains the most, the
        // full-length one (vit, always batch-1) gains nothing from batching.
        let bert = gains.iter().find(|(n, _)| *n == "bert-large").unwrap().1;
        let vit = gains.iter().find(|(n, _)| *n == "vit-base").unwrap().1;
        assert!(bert > vit, "bert {bert:.2} should out-gain vit {vit:.2}");
        assert!(bert > 2.0, "bert gain {bert:.2} should approach the 3.31x ceiling");
        assert!((0.99..1.05).contains(&vit), "vit gain {vit:.2} should be ~1 (batch-1)");
    }

    #[test]
    fn dense_program_macs_exceed_factorized() {
        let m = ModelConfig::vit_base();
        let dense = dense_program(&m, 128);
        let fact = crate::model::build_program(&m, 128, 1);
        let ratio = dense.total_macs() as f64 / fact.total_macs() as f64;
        assert!(ratio > 1.0 && ratio < 2.5, "MAC ratio {ratio:.2}");
    }
}
