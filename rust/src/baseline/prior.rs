//! Prior-accelerator comparison rows (Fig. 23.1.6).
//!
//! The paper compares against recent transformer accelerators; for works
//! that report core-only energy/latency (excluding external memory), it adds
//! an EMA estimate "at 3.7 pJ/b and 6.4 GB/s, based on LPDDR3 SDRAM
//! [22,23]". We encode each comparison row with its published numbers and
//! apply the same adjustment.

use crate::util::json::Json;

/// One published accelerator's reported numbers.
#[derive(Debug, Clone)]
pub struct PriorWork {
    pub name: &'static str,
    pub reference: &'static str,
    pub tech_nm: u32,
    /// Reported energy per token, µJ (core-only unless `includes_ema`).
    pub uj_per_token: f64,
    /// Reported latency per token, µs (if published).
    pub us_per_token: Option<f64>,
    pub includes_ema: bool,
    /// Model weight bytes streamed per token for the workload it reports
    /// (used for the EMA adder when `includes_ema` is false).
    pub weight_bytes_per_token: f64,
}

/// The paper's own EMA-cost constants.
pub const EMA_PJ_PER_BIT: f64 = 3.7;
pub const EMA_GBPS: f64 = 6.4;

impl PriorWork {
    /// Energy per token with the paper's EMA adder applied.
    pub fn uj_per_token_with_ema(&self) -> f64 {
        if self.includes_ema {
            self.uj_per_token
        } else {
            self.uj_per_token + self.weight_bytes_per_token * 8.0 * EMA_PJ_PER_BIT * 1e-6
        }
    }
    /// Latency per token with the DRAM transfer adder (6.4 GB/s) applied.
    pub fn us_per_token_with_ema(&self) -> Option<f64> {
        self.us_per_token.map(|us| {
            if self.includes_ema {
                us
            } else {
                us + self.weight_bytes_per_token / EMA_GBPS * 1e-3
            }
        })
    }
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("reference", Json::str(self.reference)),
            ("tech_nm", Json::num(self.tech_nm as f64)),
            ("uj_per_token_reported", Json::num(self.uj_per_token)),
            ("uj_per_token_with_ema", Json::num(self.uj_per_token_with_ema())),
            ("includes_ema", Json::Bool(self.includes_ema)),
        ])
    }
}

/// Comparison rows. Energy numbers are the works' published per-token
/// figures; `weight_bytes_per_token` estimates use each work's evaluated
/// model (BERT-class encoders ≈ 100M params at their reported precision,
/// streamed once per ~128-token pass).
pub fn prior_works() -> Vec<PriorWork> {
    vec![
        PriorWork {
            name: "Bitline-Transpose CIM",
            reference: "[2] Tu et al., ISSCC 2022",
            tech_nm: 28,
            uj_per_token: 15.59,
            us_per_token: None,
            includes_ema: false,
            // 8b BERT-base-class: ~110M params / 128-token pass.
            weight_bytes_per_token: 110e6 / 128.0,
        },
        PriorWork {
            name: "MulTCIM",
            reference: "[10] Tu et al., ISSCC 2023",
            tech_nm: 28,
            uj_per_token: 2.24,
            us_per_token: None,
            includes_ema: false,
            weight_bytes_per_token: 110e6 / 128.0,
        },
        PriorWork {
            name: "C-Transformer",
            reference: "[21] Kim et al., ISSCC 2024",
            tech_nm: 28,
            uj_per_token: 2.6, // best of its 2.6–18.1 range
            us_per_token: None,
            includes_ema: true, // implicit weight generation targets EMA
            weight_bytes_per_token: 0.0,
        },
        PriorWork {
            name: "Sparse xfmr + butterfly skip",
            reference: "[3] Liu et al., ISSCC 2023",
            tech_nm: 28,
            uj_per_token: 8.2, // derived from 53.8 TOPS/W at BERT-base op count
            us_per_token: None,
            includes_ema: false,
            weight_bytes_per_token: 55e6 / 128.0, // 50% pruned
        },
        PriorWork {
            name: "Entropy early-exit xfmr",
            reference: "[19] Tambe et al., ISSCC 2023",
            tech_nm: 12,
            uj_per_token: 6.1, // derived from 18.1 TFLOPS/W at BERT-base op count
            us_per_token: None,
            includes_ema: false,
            weight_bytes_per_token: 80e6 / 128.0,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_adder_increases_core_only_numbers() {
        for w in prior_works() {
            if !w.includes_ema {
                assert!(w.uj_per_token_with_ema() > w.uj_per_token, "{}", w.name);
            } else {
                assert_eq!(w.uj_per_token_with_ema(), w.uj_per_token);
            }
        }
    }

    #[test]
    fn ema_adder_magnitude() {
        // 110M params / 128 tokens ≈ 859 kB/token → ×8×3.7pJ ≈ 25.4 µJ/token:
        // EMA dwarfs the core energy, which is exactly Fig. 23.1.1's point.
        let w = &prior_works()[0];
        let adder = w.uj_per_token_with_ema() - w.uj_per_token;
        assert!((20.0..35.0).contains(&adder), "adder {adder:.1} µJ/token");
        assert!(adder > w.uj_per_token, "EMA should dominate core energy");
    }

    #[test]
    fn rows_have_unique_names() {
        let works = prior_works();
        let mut names: Vec<_> = works.iter().map(|w| w.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), works.len());
    }
}
