//! Artifact manifest loader + self-test against the AOT check vectors.
//!
//! Two ways to build an [`ArtifactSet`]:
//!
//! * [`ArtifactSet::load`] — compile the AOT HLO artifacts from a
//!   `manifest.json` directory (requires the `pjrt` feature and
//!   `make artifacts`).
//! * [`ArtifactSet::reference`] — synthesize the three batch-class entries
//!   over the deterministic reference executable. Zero dependencies, no
//!   artifacts on disk; this is what CI and the pool benches/tests use.

use crate::error::{Error, Result};
use crate::runtime::client::{Executable, PjrtRuntime};
use crate::sim::BatchClass;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact (one dynamic-batch class) from the manifest.
pub struct ArtifactEntry {
    pub name: String,
    pub batch: usize,
    pub seq: usize,
    pub tokens: usize,
    pub d_model: usize,
    /// Empty for reference entries (no AOT check vector on disk).
    pub check_vector: PathBuf,
    pub input_elems: usize,
    pub output_elems: usize,
    pub exe: Executable,
}

impl ArtifactEntry {
    pub fn class(&self) -> Result<BatchClass> {
        match self.batch {
            1 => Ok(BatchClass::B1),
            2 => Ok(BatchClass::B2),
            4 => Ok(BatchClass::B4),
            b => Err(Error::runtime(format!("artifact batch {b} is not a batch class"))),
        }
    }
}

/// Geometry of the default reference/AOT proxy model (`aot.py`'s `tiny`):
/// one 32-token plane, 64-wide embeddings. Single source of truth for every
/// binary that falls back to the reference backend.
pub const TINY_MODEL: &str = "tiny";
pub const TINY_D_MODEL: usize = 64;
pub const TINY_MAX_SEQ: usize = 32;

/// All compiled artifacts for a model, keyed by batch class.
pub struct ArtifactSet {
    pub model_name: String,
    pub d_model: usize,
    pub max_seq: usize,
    pub entries: BTreeMap<BatchClass, ArtifactEntry>,
    pub dir: PathBuf,
}

impl ArtifactSet {
    /// Load `dir/manifest.json` and compile every artifact.
    pub fn load(rt: &PjrtRuntime, dir: &Path) -> Result<Self> {
        let manifest = Json::from_file(dir.join("manifest.json"))
            .map_err(|e| Error::runtime(format!("manifest: {e} (run `make artifacts`)")))?;
        let model = manifest.get("model")?;
        let model_name = model.get("name")?.as_str()?.to_string();
        let d_model = model.get("d_model")?.as_usize()?;
        let max_seq = model.get("max_seq")?.as_usize()?;
        let mut entries = BTreeMap::new();
        for a in manifest.get("artifacts")?.as_arr()? {
            let name = a.get("name")?.as_str()?.to_string();
            let exe = rt.compile_hlo_file(&dir.join(&name))?;
            let entry = ArtifactEntry {
                name,
                batch: a.get("batch")?.as_usize()?,
                seq: a.get("seq")?.as_usize()?,
                tokens: a.get("tokens")?.as_usize()?,
                d_model: a.get("d_model")?.as_usize()?,
                check_vector: dir.join(a.get("check_vector")?.as_str()?),
                input_elems: a.get("input_elems")?.as_usize()?,
                output_elems: a.get("output_elems")?.as_usize()?,
                exe,
            };
            entries.insert(entry.class()?, entry);
        }
        if entries.is_empty() {
            return Err(Error::runtime("manifest has no artifacts".to_string()));
        }
        Ok(ArtifactSet { model_name, d_model, max_seq, entries, dir: dir.to_path_buf() })
    }

    /// Reference set on the default tiny-plane geometry.
    pub fn reference_tiny() -> Result<Self> {
        Self::reference(TINY_MODEL, TINY_D_MODEL, TINY_MAX_SEQ)
    }

    /// Build the three batch-class entries over the deterministic reference
    /// executable — one `max_seq`-token plane split into 1/2/4 slots, the
    /// same geometry `aot.py` emits for the AOT artifacts.
    pub fn reference(model_name: &str, d_model: usize, max_seq: usize) -> Result<Self> {
        if d_model == 0 || max_seq % 4 != 0 {
            return Err(Error::runtime(format!(
                "reference artifacts need d_model > 0 and max_seq divisible by 4, \
                 got d_model={d_model} max_seq={max_seq}"
            )));
        }
        let mut entries = BTreeMap::new();
        for class in BatchClass::ALL {
            let batch = class.batch();
            let entry = ArtifactEntry {
                name: format!("{model_name}_ref_b{batch}"),
                batch,
                seq: max_seq / batch,
                tokens: max_seq,
                d_model,
                check_vector: PathBuf::new(),
                input_elems: max_seq * d_model,
                output_elems: max_seq * d_model,
                exe: Executable::reference(model_name, d_model),
            };
            entries.insert(class, entry);
        }
        Ok(ArtifactSet {
            model_name: model_name.to_string(),
            d_model,
            max_seq,
            entries,
            dir: PathBuf::new(),
        })
    }

    pub fn get(&self, class: BatchClass) -> Result<&ArtifactEntry> {
        self.entries
            .get(&class)
            .ok_or_else(|| Error::runtime(format!("no artifact for class {}", class.name())))
    }

    /// Execute every artifact on its AOT check vector and compare against
    /// the jax-computed output — proves PJRT-side numerics match the
    /// compile-side numerics bit-for-bit-ish (f32 tolerance). Reference
    /// entries (no check vector) get a shape + padding-invariant check.
    pub fn self_test(&self) -> Result<()> {
        for (class, e) in &self.entries {
            if e.check_vector.as_os_str().is_empty() {
                let zeros = vec![0.0f32; e.input_elems];
                let out = e.exe.run_f32(&zeros, e.tokens, e.d_model)?;
                if out.len() != e.output_elems || out.iter().any(|v| *v != 0.0) {
                    return Err(Error::runtime(format!(
                        "{}: reference self-test failed (class {})",
                        e.name,
                        class.name()
                    )));
                }
                continue;
            }
            let blob = std::fs::read(&e.check_vector)?;
            let need = 4 * (e.input_elems + e.output_elems);
            if blob.len() != need {
                return Err(Error::runtime(format!(
                    "{}: check vector {} bytes, expected {need}",
                    e.name,
                    blob.len()
                )));
            }
            let read_f32 = |bytes: &[u8]| -> Vec<f32> {
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            };
            let x = read_f32(&blob[..4 * e.input_elems]);
            let want = read_f32(&blob[4 * e.input_elems..]);
            let got = e.exe.run_f32(&x, e.tokens, e.d_model)?;
            if got.len() != want.len() {
                return Err(Error::runtime(format!(
                    "{}: output len {} vs expected {}",
                    e.name,
                    got.len(),
                    want.len()
                )));
            }
            let max_err = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if max_err > 1e-4 {
                return Err(Error::runtime(format!(
                    "{}: self-test max err {max_err} (class {})",
                    e.name,
                    class.name()
                )));
            }
        }
        Ok(())
    }
}

/// Default artifacts directory: `$TREX_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("TREX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_set_has_all_classes_and_passes_self_test() {
        let set = ArtifactSet::reference("tiny", 64, 32).unwrap();
        assert_eq!(set.entries.len(), 3);
        let b4 = set.get(BatchClass::B4).unwrap();
        assert_eq!((b4.batch, b4.seq, b4.tokens), (4, 8, 32));
        let b1 = set.get(BatchClass::B1).unwrap();
        assert_eq!((b1.batch, b1.seq, b1.tokens), (1, 32, 32));
        set.self_test().unwrap();
    }

    #[test]
    fn reference_set_rejects_bad_geometry() {
        assert!(ArtifactSet::reference("tiny", 0, 32).is_err());
        assert!(ArtifactSet::reference("tiny", 64, 30).is_err());
    }
}
