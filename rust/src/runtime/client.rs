//! Thin typed wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits serialized protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and `python/compile/aot.py`).

use crate::error::{Error, Result};

/// A PJRT client (CPU). One per process; executables borrow it.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it.
    pub fn compile_hlo_file(&self, path: &std::path::Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::runtime("non-utf8 path".to_string()))?,
        )
        .map_err(|e| Error::runtime(format!("HLO parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {}: {e}", path.display())))?;
        Ok(Executable { exe })
    }
}

/// A compiled executable taking one f32 tensor and returning one f32 tensor
/// (the model artifacts' calling convention: activations in → out).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on a `(rows, cols)` f32 input; returns the flat f32 output.
    pub fn run_f32(&self, input: &[f32], rows: usize, cols: usize) -> Result<Vec<f32>> {
        if input.len() != rows * cols {
            return Err(Error::shape(format!(
                "run_f32: input len {} != {rows}x{cols}",
                input.len()
            )));
        }
        let lit = xla::Literal::vec1(input)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| Error::runtime(format!("reshape: {e}")))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| Error::runtime(format!("execute: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("to_literal: {e}")))?;
        // aot.py lowers with return_tuple=True ⇒ unwrap the 1-tuple.
        let out = out
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("to_tuple1: {e}")))?;
        out.to_vec::<f32>()
            .map_err(|e| Error::runtime(format!("to_vec: {e}")))
    }
}
