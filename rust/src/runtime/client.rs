//! Execution backends for the serving plane.
//!
//! Two backends sit behind one [`Executable`] type:
//!
//! * **PJRT** (feature `pjrt`): a thin typed wrapper over the `xla` crate's
//!   PJRT CPU client. Interchange is HLO **text**: jax ≥ 0.5 emits
//!   serialized protos with 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids (see `python/compile/aot.py`).
//! * **Reference** (always available, zero dependencies): a deterministic
//!   row-wise projection + GELU. Each token row is transformed
//!   independently, so a request's numerics are identical regardless of
//!   batch composition, slot position, or which pool worker served it —
//!   exactly the invariant the multi-worker coordinator tests rely on.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// A PJRT client (CPU). One per process; executables borrow it.
pub struct PjrtRuntime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(PjrtRuntime { client })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Self> {
        Err(Error::runtime(
            "trex was built without the `pjrt` feature; use ArtifactSet::reference \
             or rebuild with --features pjrt (requires the xla crate, see README.md)"
                .to_string(),
        ))
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "none".to_string()
        }
    }

    /// Load an HLO-text file and compile it.
    #[cfg(feature = "pjrt")]
    pub fn compile_hlo_file(&self, path: &std::path::Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::runtime("non-utf8 path".to_string()))?,
        )
        .map_err(|e| Error::runtime(format!("HLO parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {}: {e}", path.display())))?;
        Ok(Executable { inner: Inner::Pjrt(exe) })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn compile_hlo_file(&self, path: &std::path::Path) -> Result<Executable> {
        Err(Error::runtime(format!(
            "cannot compile {}: built without the `pjrt` feature",
            path.display()
        )))
    }
}

/// A compiled executable taking one f32 tensor and returning one f32 tensor
/// (the model artifacts' calling convention: activations in → out).
pub struct Executable {
    inner: Inner,
}

enum Inner {
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtLoadedExecutable),
    Reference(RefModel),
}

impl Executable {
    /// Deterministic reference executable for a `d_model`-wide plane.
    pub fn reference(model_name: &str, d_model: usize) -> Executable {
        Executable { inner: Inner::Reference(RefModel::new(model_name, d_model)) }
    }

    /// Execute on a `(rows, cols)` f32 input; returns the flat f32 output.
    pub fn run_f32(&self, input: &[f32], rows: usize, cols: usize) -> Result<Vec<f32>> {
        if input.len() != rows * cols {
            return Err(Error::shape(format!(
                "run_f32: input len {} != {rows}x{cols}",
                input.len()
            )));
        }
        match &self.inner {
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(exe) => {
                let lit = xla::Literal::vec1(input)
                    .reshape(&[rows as i64, cols as i64])
                    .map_err(|e| Error::runtime(format!("reshape: {e}")))?;
                let result = exe
                    .execute::<xla::Literal>(&[lit])
                    .map_err(|e| Error::runtime(format!("execute: {e}")))?;
                let out = result[0][0]
                    .to_literal_sync()
                    .map_err(|e| Error::runtime(format!("to_literal: {e}")))?;
                // aot.py lowers with return_tuple=True ⇒ unwrap the 1-tuple.
                let out = out
                    .to_tuple1()
                    .map_err(|e| Error::runtime(format!("to_tuple1: {e}")))?;
                out.to_vec::<f32>()
                    .map_err(|e| Error::runtime(format!("to_vec: {e}")))
            }
            Inner::Reference(m) => m.run(input, rows, cols),
        }
    }
}

/// Pure-Rust fallback numerics: `y = gelu(x · W)` applied row-by-row with a
/// seeded `d×d` projection. No bias term, so zero padding rows map to zero.
struct RefModel {
    d: usize,
    w: Vec<f32>,
}

impl RefModel {
    fn new(model_name: &str, d: usize) -> Self {
        // Seed from the model name so distinct models get distinct weights
        // while every process computes the same matrix.
        let mut seed = 0x7_5EED ^ d as u64;
        for b in model_name.bytes() {
            seed = seed.wrapping_mul(0x100000001b3).wrapping_add(b as u64);
        }
        let mut rng = Rng::new(seed);
        let scale = 1.0 / (d as f32).sqrt();
        let w = (0..d * d).map(|_| rng.normal_f32() * scale).collect();
        RefModel { d, w }
    }

    fn run(&self, input: &[f32], rows: usize, cols: usize) -> Result<Vec<f32>> {
        if cols != self.d {
            return Err(Error::shape(format!(
                "reference model is d={} but input has {cols} columns",
                self.d
            )));
        }
        let d = self.d;
        let mut out = vec![0.0f32; rows * d];
        for r in 0..rows {
            let x = &input[r * d..(r + 1) * d];
            let y = &mut out[r * d..(r + 1) * d];
            for (k, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &self.w[k * d..(k + 1) * d];
                for (yv, &wv) in y.iter_mut().zip(wrow) {
                    *yv += xv * wv;
                }
            }
            for yv in y.iter_mut() {
                *yv = gelu(*yv);
            }
        }
        Ok(out)
    }
}

/// tanh-approximation GELU (matches the AFU's activation family).
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044_715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_is_deterministic_and_rowwise() {
        let d = 16;
        let exe = Executable::reference("tiny", d);
        let mut rng = Rng::new(7);
        let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

        // Same row alone vs embedded in a larger plane with other rows: the
        // per-row output must be bit-identical (batching independence).
        let solo = exe.run_f32(&row, 1, d).unwrap();
        let mut plane = vec![0.0f32; 4 * d];
        plane[2 * d..3 * d].copy_from_slice(&row);
        plane[..d].iter_mut().for_each(|v| *v = 1.5);
        let out = exe.run_f32(&plane, 4, d).unwrap();
        assert_eq!(&out[2 * d..3 * d], &solo[..]);

        // Zero rows map to zero (padding stays padding).
        assert!(out[d..2 * d].iter().all(|&v| v == 0.0));

        // A second compile of the same model gives identical numerics.
        let exe2 = Executable::reference("tiny", d);
        assert_eq!(exe2.run_f32(&row, 1, d).unwrap(), solo);

        // A different model name gives different weights.
        let other = Executable::reference("other", d);
        assert_ne!(other.run_f32(&row, 1, d).unwrap(), solo);
    }

    #[test]
    fn reference_rejects_bad_shapes() {
        let exe = Executable::reference("tiny", 8);
        assert!(exe.run_f32(&[0.0; 7], 1, 7).is_err());
        assert!(exe.run_f32(&[0.0; 8], 1, 4).is_err());
    }
}
