//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the Rust hot path. Python never runs at serve time.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactEntry, ArtifactSet};
pub use client::{Executable, PjrtRuntime};
