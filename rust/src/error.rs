//! Crate-wide error type (hand-rolled Display/Error impls — the crate
//! builds with zero external dependencies so the tier-1 gate runs offline).

/// Unified error type for the T-REX stack.
#[derive(Debug)]
pub enum Error {
    /// JSON syntax or type mismatch while reading a config / manifest.
    Json(String),
    /// Configuration value out of the range the hardware supports.
    Config(String),
    /// Codec violation (bit-width overflow, bad stream, invariant break).
    Codec(String),
    /// Shape mismatch in matrix / model plumbing.
    Shape(String),
    /// Simulator programming error (bad op, resource oversubscription).
    Sim(String),
    /// Serving-plane error (queue closed, engine dead, bad request,
    /// admission rejected under backpressure).
    Serve(String),
    /// PJRT / artifact-loading error.
    Runtime(String),
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Sim(m) => write!(f, "sim error: {m}"),
            Error::Serve(m) => write!(f, "serve error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    pub fn json(m: impl Into<String>) -> Self {
        Error::Json(m.into())
    }
    pub fn config(m: impl Into<String>) -> Self {
        Error::Config(m.into())
    }
    pub fn codec(m: impl Into<String>) -> Self {
        Error::Codec(m.into())
    }
    pub fn shape(m: impl Into<String>) -> Self {
        Error::Shape(m.into())
    }
    pub fn sim(m: impl Into<String>) -> Self {
        Error::Sim(m.into())
    }
    pub fn serve(m: impl Into<String>) -> Self {
        Error::Serve(m.into())
    }
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
