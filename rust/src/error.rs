//! Crate-wide error type.

/// Unified error type for the T-REX stack.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// JSON syntax or type mismatch while reading a config / manifest.
    #[error("json error: {0}")]
    Json(String),
    /// Configuration value out of the range the hardware supports.
    #[error("config error: {0}")]
    Config(String),
    /// Codec violation (bit-width overflow, bad stream, invariant break).
    #[error("codec error: {0}")]
    Codec(String),
    /// Shape mismatch in matrix / model plumbing.
    #[error("shape error: {0}")]
    Shape(String),
    /// Simulator programming error (bad op, resource oversubscription).
    #[error("sim error: {0}")]
    Sim(String),
    /// Serving-plane error (queue closed, engine dead, bad request).
    #[error("serve error: {0}")]
    Serve(String),
    /// PJRT / artifact-loading error.
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    pub fn json(m: impl Into<String>) -> Self {
        Error::Json(m.into())
    }
    pub fn config(m: impl Into<String>) -> Self {
        Error::Config(m.into())
    }
    pub fn codec(m: impl Into<String>) -> Self {
        Error::Codec(m.into())
    }
    pub fn shape(m: impl Into<String>) -> Self {
        Error::Shape(m.into())
    }
    pub fn sim(m: impl Into<String>) -> Self {
        Error::Sim(m.into())
    }
    pub fn serve(m: impl Into<String>) -> Self {
        Error::Serve(m.into())
    }
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
