//! Model (workload) configuration: the four paper workloads + a tiny preset.
//!
//! The paper evaluates ViT [25], R-Drop NMT [26], fairseq-S2T [27] and
//! BERT-Large [28]. Dimensions follow the cited upstream models; the
//! factorization rank `r` and NZ-per-column follow DictFormer-style settings
//! that land the paper's 8.5–10.7× factorization-EMA band (verified by
//! `cargo bench --bench fig3_factorization`).

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Encoder-only vs encoder-decoder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArchKind {
    Encoder,
    EncoderDecoder,
}

impl ArchKind {
    pub fn name(self) -> &'static str {
        match self {
            ArchKind::Encoder => "encoder",
            ArchKind::EncoderDecoder => "encoder-decoder",
        }
    }
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "encoder" => Ok(ArchKind::Encoder),
            "encoder-decoder" => Ok(ArchKind::EncoderDecoder),
            other => Err(Error::config(format!("unknown arch kind '{other}'"))),
        }
    }
}

/// A transformer workload, factorized per the T-REX training model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub arch: ArchKind,
    /// Encoder layers.
    pub enc_layers: usize,
    /// Decoder layers (0 for encoder-only).
    pub dec_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub heads: usize,
    /// Maximum sequence length the model is served at (≤ hw.max_seq).
    pub max_seq: usize,
    /// Shared-matrix rank: W_S ∈ R^{d×r}, W_D ∈ R^{r×d_out}.
    pub rank: usize,
    /// Non-zeros per column of W_D (fixed — trained with the regularizer).
    pub nnz_per_col: usize,
    /// Activation/MAC precision served on chip.
    pub act_bits: u32,
    /// Mean input length for the workload's arrival trace (drives the
    /// dynamic-batching evaluation; BERT-style NLU inputs are short).
    pub mean_input_len: f64,
}

impl ModelConfig {
    /// Total transformer layers.
    pub fn layers(&self) -> usize {
        self.enc_layers + self.dec_layers
    }

    /// Per-layer weight-matrix output dimensions of the attention+FFN stack:
    /// Q, K, V, O (d_model each), FFN up (d_ff), FFN down (d_model, from d_ff).
    /// Returns `(d_in, d_out)` pairs for the unfactorized baseline.
    pub fn layer_matrices(&self) -> Vec<(usize, usize)> {
        vec![
            (self.d_model, self.d_model), // Wq
            (self.d_model, self.d_model), // Wk
            (self.d_model, self.d_model), // Wv
            (self.d_model, self.d_model), // Wo
            (self.d_model, self.d_ff),    // FFN up
            (self.d_ff, self.d_model),    // FFN down
        ]
    }

    /// Shared-matrix groups. The paper keeps separate W_S (with independent
    /// quantization LUTs) for encoder-attention, encoder-FFN and, when a
    /// decoder exists, decoder-attention and decoder-FFN.
    /// Each group: `(name, d_in, rank)` for the W_S, plus the list of
    /// per-layer W_D output dims it feeds.
    pub fn shared_groups(&self) -> Vec<SharedGroup> {
        let mut gs = Vec::new();
        let attn_outs = vec![self.d_model; 4];
        // FFN group needs W_S for both d_model→r (up path) and d_ff→r (down
        // path); the paper defines separate W_S per in-dimension.
        gs.push(SharedGroup {
            name: "enc_attn".into(),
            d_in: self.d_model,
            rank: self.rank,
            wd_outs: attn_outs.clone(),
            layers: self.enc_layers,
        });
        gs.push(SharedGroup {
            name: "enc_ffn_up".into(),
            d_in: self.d_model,
            rank: self.rank,
            wd_outs: vec![self.d_ff],
            layers: self.enc_layers,
        });
        gs.push(SharedGroup {
            name: "enc_ffn_down".into(),
            d_in: self.d_ff,
            rank: self.rank,
            wd_outs: vec![self.d_model],
            layers: self.enc_layers,
        });
        if self.dec_layers > 0 {
            gs.push(SharedGroup {
                name: "dec_attn".into(),
                d_in: self.d_model,
                rank: self.rank,
                // self-attn QKVO + cross-attn QKVO
                wd_outs: vec![self.d_model; 8],
                layers: self.dec_layers,
            });
            gs.push(SharedGroup {
                name: "dec_ffn_up".into(),
                d_in: self.d_model,
                rank: self.rank,
                wd_outs: vec![self.d_ff],
                layers: self.dec_layers,
            });
            gs.push(SharedGroup {
                name: "dec_ffn_down".into(),
                d_in: self.d_ff,
                rank: self.rank,
                wd_outs: vec![self.d_model],
                layers: self.dec_layers,
            });
        }
        gs
    }

    /// Unfactorized parameter count (weights only, attention+FFN stack).
    pub fn baseline_params(&self) -> usize {
        let per_enc: usize = self.layer_matrices().iter().map(|(i, o)| i * o).sum();
        // Decoder layer adds cross-attention (4 more d_model×d_model).
        let per_dec = per_enc + 4 * self.d_model * self.d_model;
        self.enc_layers * per_enc + self.dec_layers * per_dec
    }

    /// Factorized parameter count: shared W_S once per group + per-layer
    /// sparse W_D non-zeros (value + index each).
    pub fn factorized_params(&self) -> usize {
        let mut total = 0usize;
        for g in self.shared_groups() {
            total += g.d_in * g.rank; // W_S once
            let nz_per_wd: usize = g.wd_outs.iter().map(|&o| o * self.nnz_per_col).sum();
            total += g.layers * nz_per_wd; // W_D values (indices counted as bytes elsewhere)
        }
        total
    }

    pub fn validate(&self, hw_max_seq: usize) -> Result<()> {
        if self.d_model % self.heads != 0 {
            return Err(Error::config(format!(
                "{}: d_model {} not divisible by heads {}",
                self.name, self.d_model, self.heads
            )));
        }
        if self.max_seq > hw_max_seq {
            return Err(Error::config(format!(
                "{}: max_seq {} exceeds hw max {}",
                self.name, self.max_seq, hw_max_seq
            )));
        }
        if self.rank == 0 || self.rank > self.d_model.min(self.d_ff) {
            return Err(Error::config(format!("{}: bad rank {}", self.name, self.rank)));
        }
        if self.nnz_per_col == 0 || self.nnz_per_col > self.rank {
            return Err(Error::config(format!(
                "{}: nnz_per_col {} not in 1..=rank {}",
                self.name, self.nnz_per_col, self.rank
            )));
        }
        if self.arch == ArchKind::Encoder && self.dec_layers != 0 {
            return Err(Error::config(format!("{}: encoder arch with decoder layers", self.name)));
        }
        Ok(())
    }

    // ------------------------------------------------------------- presets

    /// BERT-Large [28]: 24 layers, 1024/4096, 16 heads. Short NLU inputs.
    pub fn bert_large() -> Self {
        ModelConfig {
            name: "bert-large".into(),
            arch: ArchKind::Encoder,
            enc_layers: 24,
            dec_layers: 0,
            d_model: 1024,
            d_ff: 4096,
            heads: 16,
            max_seq: 128,
            rank: 640,
            nnz_per_col: 84,
            act_bits: 8,
            mean_input_len: 28.0,
        }
    }

    /// ViT-Base [25]: 12 layers, 768/3072, 12 heads; 196+1 patches served in
    /// two 128-token passes ⇒ modelled at max_seq 128, fixed length.
    pub fn vit_base() -> Self {
        ModelConfig {
            name: "vit-base".into(),
            arch: ArchKind::Encoder,
            enc_layers: 12,
            dec_layers: 0,
            d_model: 768,
            d_ff: 3072,
            heads: 12,
            max_seq: 128,
            rank: 512,
            nnz_per_col: 52,
            act_bits: 8,
            mean_input_len: 128.0,
        }
    }

    /// fairseq-S2T small [27]: 12-enc/6-dec, 256/2048, 4 heads.
    pub fn s2t_small() -> Self {
        ModelConfig {
            name: "s2t-small".into(),
            arch: ArchKind::EncoderDecoder,
            enc_layers: 12,
            dec_layers: 6,
            d_model: 256,
            d_ff: 2048,
            heads: 4,
            max_seq: 128,
            rank: 192,
            nnz_per_col: 16,
            act_bits: 8,
            mean_input_len: 72.0,
        }
    }

    /// R-Drop NMT [26] (transformer-base): 6-enc/6-dec, 512/2048, 8 heads.
    pub fn nmt_rdrop() -> Self {
        ModelConfig {
            name: "nmt-rdrop".into(),
            arch: ArchKind::EncoderDecoder,
            enc_layers: 6,
            dec_layers: 6,
            d_model: 512,
            d_ff: 2048,
            heads: 8,
            max_seq: 128,
            rank: 384,
            nnz_per_col: 24,
            act_bits: 8,
            mean_input_len: 40.0,
        }
    }

    /// Tiny config for tests and the AOT end-to-end example.
    pub fn tiny() -> Self {
        ModelConfig {
            name: "tiny".into(),
            arch: ArchKind::Encoder,
            enc_layers: 2,
            dec_layers: 0,
            d_model: 64,
            d_ff: 128,
            heads: 4,
            max_seq: 32,
            rank: 16,
            nnz_per_col: 4,
            act_bits: 8,
            mean_input_len: 16.0,
        }
    }

    pub fn preset(name: &str) -> Result<Self> {
        match name {
            "bert-large" => Ok(Self::bert_large()),
            "vit-base" => Ok(Self::vit_base()),
            "s2t-small" => Ok(Self::s2t_small()),
            "nmt-rdrop" => Ok(Self::nmt_rdrop()),
            "tiny" => Ok(Self::tiny()),
            other => Err(Error::config(format!("unknown model preset '{other}'"))),
        }
    }

    // ------------------------------------------------------------- JSON
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("arch", Json::str(self.arch.name())),
            ("enc_layers", Json::num(self.enc_layers as f64)),
            ("dec_layers", Json::num(self.dec_layers as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("heads", Json::num(self.heads as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            ("rank", Json::num(self.rank as f64)),
            ("nnz_per_col", Json::num(self.nnz_per_col as f64)),
            ("act_bits", Json::num(self.act_bits as f64)),
            ("mean_input_len", Json::num(self.mean_input_len)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            arch: ArchKind::parse(j.get("arch")?.as_str()?)?,
            enc_layers: j.get("enc_layers")?.as_usize()?,
            dec_layers: j.get("dec_layers")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            heads: j.get("heads")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            rank: j.get("rank")?.as_usize()?,
            nnz_per_col: j.get("nnz_per_col")?.as_usize()?,
            act_bits: j.get("act_bits")?.as_u64()? as u32,
            mean_input_len: j.get("mean_input_len")?.as_f64()?,
        })
    }
}

/// One shared-W_S group: its geometry and the per-layer W_Ds hanging off it.
#[derive(Debug, Clone, PartialEq)]
pub struct SharedGroup {
    pub name: String,
    pub d_in: usize,
    pub rank: usize,
    /// Output dims of the W_D matrices each layer derives from this W_S.
    pub wd_outs: Vec<usize>,
    /// Number of layers sharing this W_S.
    pub layers: usize,
}

/// The paper's four evaluation workloads.
pub const WORKLOADS: [&str; 4] = ["vit-base", "nmt-rdrop", "s2t-small", "bert-large"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in WORKLOADS.iter().chain(["tiny"].iter()) {
            let m = ModelConfig::preset(name).unwrap();
            m.validate(128).unwrap();
        }
        assert!(ModelConfig::preset("nope").is_err());
    }

    #[test]
    fn bert_large_param_count_sane() {
        let m = ModelConfig::bert_large();
        // 24 × (4×1024² + 2×1024×4096) = 24 × 12.58M ≈ 302M
        let p = m.baseline_params();
        assert!((290_000_000..320_000_000).contains(&p), "params={p}");
        // Factorized must be much smaller.
        let f = m.factorized_params();
        assert!(f * 10 < p, "factorized {f} vs baseline {p}");
    }

    #[test]
    fn factorized_param_reduction_in_paper_band() {
        // Paper: 15.9–25.5× parameter-size reduction across workloads
        // (that figure includes quantization; raw count reduction must be
        // lower but same order). Check count reduction is ≥4× everywhere.
        for name in WORKLOADS {
            let m = ModelConfig::preset(name).unwrap();
            let ratio = m.baseline_params() as f64 / m.factorized_params() as f64;
            assert!(ratio > 4.0, "{name}: count ratio {ratio:.1}");
        }
    }

    #[test]
    fn shared_groups_cover_all_matrices() {
        let m = ModelConfig::s2t_small();
        let gs = m.shared_groups();
        assert_eq!(gs.len(), 6); // enc attn/up/down + dec attn/up/down
        let dec_attn = gs.iter().find(|g| g.name == "dec_attn").unwrap();
        assert_eq!(dec_attn.wd_outs.len(), 8); // self + cross QKVO
        let enc = ModelConfig::bert_large();
        assert_eq!(enc.shared_groups().len(), 3);
    }

    #[test]
    fn json_roundtrip() {
        for name in WORKLOADS {
            let m = ModelConfig::preset(name).unwrap();
            let m2 = ModelConfig::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(m, m2);
        }
    }

    #[test]
    fn validation_catches_errors() {
        let mut m = ModelConfig::tiny();
        m.heads = 3; // 64 % 3 != 0
        assert!(m.validate(128).is_err());
        let mut m = ModelConfig::tiny();
        m.max_seq = 256;
        assert!(m.validate(128).is_err());
        let mut m = ModelConfig::tiny();
        m.nnz_per_col = m.rank + 1;
        assert!(m.validate(128).is_err());
        let mut m = ModelConfig::tiny();
        m.rank = 0;
        assert!(m.validate(128).is_err());
    }
}
