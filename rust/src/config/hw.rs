//! T-REX chip geometry, operating points and energy table.
//!
//! All geometry numbers come straight from the paper (Fig. 23.1.2):
//! 4 DMM cores with 4×4 PEs of 4×4 MACs (outer-product, 16×16 tiles),
//! 4 SMM cores with 8×8 MACs, 2 AFUs (64 IAUs + 16 FAUs), a global buffer,
//! and a DMA to LPDDR3 modelled at the paper's own 3.7 pJ/b and 6.4 GB/s.
//! The MAC is bit-serial on the 4b multiplier: a 16b/8b/4b multiply takes
//! 16/4/1 cycles. Operating points span 0.45–0.85 V, 60–450 MHz,
//! 7.12–152.5 mW (Fig. 23.1.7).

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Operand precision of a MAC operation. The multiplier is 4-bit; wider
/// operands are processed bit-serially over multiple cycles (paper: 16b/8b/4b
/// over 16/4/1 cycles — quadratic in the width ratio).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Int4,
    Int8,
    Int16,
}

impl Precision {
    /// Cycles one MAC unit needs per multiply-accumulate at this precision.
    pub fn mac_cycles(self) -> u64 {
        match self {
            Precision::Int4 => 1,
            Precision::Int8 => 4,
            Precision::Int16 => 16,
        }
    }
    pub fn bits(self) -> u32 {
        match self {
            Precision::Int4 => 4,
            Precision::Int8 => 8,
            Precision::Int16 => 16,
        }
    }
    pub fn from_bits(bits: u32) -> Result<Self> {
        match bits {
            4 => Ok(Precision::Int4),
            8 => Ok(Precision::Int8),
            16 => Ok(Precision::Int16),
            b => Err(Error::config(format!("unsupported MAC precision: {b}b"))),
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Precision::Int4 => "int4",
            Precision::Int8 => "int8",
            Precision::Int16 => "int16",
        }
    }
}

/// One measured voltage/frequency/power point from Fig. 23.1.7.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub vdd: f64,      // volts
    pub freq_mhz: f64, // MHz
    /// Peak (fully active) chip power at this point, mW — measurement anchor.
    pub peak_mw: f64,
}

impl OperatingPoint {
    pub fn cycle_ns(&self) -> f64 {
        1e3 / self.freq_mhz
    }
    /// Peak energy per cycle, pJ.
    pub fn peak_pj_per_cycle(&self) -> f64 {
        // mW / MHz = nJ/cycle → ×1e3 = pJ/cycle
        self.peak_mw / self.freq_mhz * 1e3
    }
}

/// Per-event energy constants (pJ), derived from the operating point by
/// [`HwConfig::energy_at`]. The split across blocks follows the typical
/// breakdown for 16nm MAC-array accelerators; the *total* is anchored to the
/// chip's measured power so end-to-end µJ/token is calibrated, and EMA uses
/// the paper's own LPDDR3 constant.
#[derive(Debug, Clone, Copy)]
pub struct EnergyTable {
    /// Energy per MAC-cycle (one 4b multiply step), pJ.
    pub mac_pj: f64,
    /// Register-file (TRF / line-buffer) access, pJ per 16b word.
    pub rf_pj: f64,
    /// Global-buffer SRAM access, pJ per 16b word.
    pub gb_pj: f64,
    /// AFU arithmetic op (IAU/FAU/LUT lookup), pJ per op.
    pub afu_pj: f64,
    /// Static/idle leakage per block per cycle, pJ.
    pub idle_pj: f64,
    /// External memory access, pJ per *bit* (paper: 3.7 pJ/b LPDDR3).
    pub ema_pj_per_bit: f64,
}

/// Chip geometry + memory system + operating points.
#[derive(Debug, Clone)]
pub struct HwConfig {
    // --- compute geometry (Fig. 23.1.2) ---
    pub dmm_cores: usize,
    /// PEs per DMM core along each dimension (4 ⇒ 4×4 = 16 PEs).
    pub dmm_pe_dim: usize,
    /// MACs per PE along each dimension (4 ⇒ 4×4 = 16 MACs; PE = 4×4 outer product).
    pub pe_mac_dim: usize,
    pub smm_cores: usize,
    /// MACs per SMM core along each dimension (8 ⇒ 8×8 = 64 MACs).
    pub smm_mac_dim: usize,
    pub afus: usize,
    pub afu_iaus: usize,
    pub afu_faus: usize,

    // --- memory system ---
    /// Global buffer capacity, bytes (holds compressed W_S + one layer's W_D
    /// + intermediates).
    pub gb_bytes: usize,
    /// TRF submatrix dimension (square, two-direction accessible).
    pub trf_dim: usize,
    /// DRAM bandwidth, GB/s (paper uses 6.4 GB/s LPDDR3 for latency adders).
    pub dram_gbps: f64,
    /// DRAM energy, pJ/bit (paper: 3.7).
    pub dram_pj_per_bit: f64,
    /// Fixed page size of the GB's KV-cache arena, bytes (the allocation
    /// granule of [`crate::kv::KvManager`]).
    pub kv_page_bytes: usize,

    // --- limits ---
    /// Maximum supported input length (tokens).
    pub max_seq: usize,

    // --- measured operating points, ascending vdd ---
    pub points: Vec<OperatingPoint>,
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig {
            dmm_cores: 4,
            dmm_pe_dim: 4,
            pe_mac_dim: 4,
            smm_cores: 4,
            smm_mac_dim: 8,
            afus: 2,
            afu_iaus: 64,
            afu_faus: 16,
            // 4 MB global buffer: fits compressed W_S of the largest workload
            // (BERT-Large: 1024×256×4 groups ×4b ≈ 0.5 MB) + one layer's W_D
            // + activations for 128×1024.
            gb_bytes: 4 << 20,
            trf_dim: 16,
            dram_gbps: 6.4,
            dram_pj_per_bit: 3.7,
            kv_page_bytes: 2048,
            max_seq: 128,
            points: vec![
                OperatingPoint { vdd: 0.45, freq_mhz: 60.0, peak_mw: 7.12 },
                OperatingPoint { vdd: 0.55, freq_mhz: 150.0, peak_mw: 24.6 },
                OperatingPoint { vdd: 0.65, freq_mhz: 250.0, peak_mw: 55.3 },
                OperatingPoint { vdd: 0.75, freq_mhz: 350.0, peak_mw: 98.7 },
                OperatingPoint { vdd: 0.85, freq_mhz: 450.0, peak_mw: 152.5 },
            ],
        }
    }
}

impl HwConfig {
    /// Total MAC units in the DMM plane.
    pub fn dmm_macs(&self) -> usize {
        self.dmm_cores * self.dmm_pe_dim * self.dmm_pe_dim * self.pe_mac_dim * self.pe_mac_dim
    }
    /// MAC units per DMM core.
    pub fn dmm_macs_per_core(&self) -> usize {
        self.dmm_pe_dim * self.dmm_pe_dim * self.pe_mac_dim * self.pe_mac_dim
    }
    /// Output tile edge a DMM core produces per pass (4×4 PEs × 4×4 MACs ⇒ 16).
    pub fn dmm_tile(&self) -> usize {
        self.dmm_pe_dim * self.pe_mac_dim
    }
    /// Total MAC units in the SMM plane.
    pub fn smm_macs(&self) -> usize {
        self.smm_cores * self.smm_mac_dim * self.smm_mac_dim
    }
    pub fn smm_macs_per_core(&self) -> usize {
        self.smm_mac_dim * self.smm_mac_dim
    }
    pub fn total_macs(&self) -> usize {
        self.dmm_macs() + self.smm_macs()
    }

    /// The fastest (max-Vdd) operating point.
    pub fn max_point(&self) -> OperatingPoint {
        *self.points.last().expect("HwConfig.points empty")
    }
    /// The slowest (min-Vdd) operating point.
    pub fn min_point(&self) -> OperatingPoint {
        *self.points.first().expect("HwConfig.points empty")
    }

    /// Interpolate an operating point at `vdd` (clamped to the table range).
    pub fn point_at_vdd(&self, vdd: f64) -> OperatingPoint {
        self.point_at_vdd_checked(vdd).0
    }

    /// Like [`HwConfig::point_at_vdd`], but the second element reports
    /// whether `vdd` fell outside the table and was clamped to an edge
    /// point. Callers that *set* operating points (fleet build, the DVFS
    /// governor, `sim --vdd`) use this to surface out-of-range requests
    /// instead of silently running at the nearest corner; a NaN `vdd`
    /// clamps to the slowest point and reports `clamped = true`.
    pub fn point_at_vdd_checked(&self, vdd: f64) -> (OperatingPoint, bool) {
        let pts = &self.points;
        if !(vdd > pts[0].vdd) {
            return (pts[0], vdd != pts[0].vdd);
        }
        if vdd >= pts[pts.len() - 1].vdd {
            return (pts[pts.len() - 1], vdd != pts[pts.len() - 1].vdd);
        }
        for w in pts.windows(2) {
            let (a, b) = (w[0], w[1]);
            if vdd >= a.vdd && vdd <= b.vdd {
                let t = (vdd - a.vdd) / (b.vdd - a.vdd);
                return (
                    OperatingPoint {
                        vdd,
                        freq_mhz: a.freq_mhz + t * (b.freq_mhz - a.freq_mhz),
                        peak_mw: a.peak_mw + t * (b.peak_mw - a.peak_mw),
                    },
                    false,
                );
            }
        }
        unreachable!()
    }

    /// A copy of this config pinned to the single operating point at `vdd`
    /// (interpolated/clamped like [`HwConfig::point_at_vdd`]). With a
    /// one-point table, `max_point()`/`min_point()`/`point_at_vdd(..)` all
    /// resolve to the pinned point, so pricing everywhere — the simulator,
    /// plan compilation, DRAM adders — runs the chip at exactly that point.
    /// This is how a fleet chip binds its VDD/frequency operating point
    /// without any simulator changes.
    pub fn pinned_at_vdd(&self, vdd: f64) -> HwConfig {
        let mut hw = self.clone();
        hw.points = vec![self.point_at_vdd(vdd)];
        hw
    }

    /// Derive the per-event energy table at an operating point.
    ///
    /// Peak power is decomposed as: 62% MAC arrays, 18% on-chip SRAM/RF
    /// traffic, 10% AFU, 10% idle/leak+clock — a standard split for dense
    /// 16nm MAC-array accelerators; the decomposition only shifts energy
    /// *between on-chip blocks*, the anchored total and the paper's own
    /// EMA constant dominate every reproduced number.
    pub fn energy_at(&self, op: OperatingPoint) -> EnergyTable {
        let pj_cycle = op.peak_pj_per_cycle();
        let macs = self.total_macs() as f64;
        // At peak, every MAC busy every cycle:
        let mac_pj = pj_cycle * 0.62 / macs;
        // RF+GB traffic at peak ≈ 2 words per active MAC lane per cycle.
        let rf_pj = pj_cycle * 0.12 / (macs * 2.0);
        let gb_pj = pj_cycle * 0.06 / (macs / 8.0);
        let afu_units = (self.afus * (self.afu_iaus + self.afu_faus)) as f64;
        let afu_pj = pj_cycle * 0.10 / afu_units;
        // Idle/leak spread across the ~10 major blocks.
        let blocks = (self.dmm_cores + self.smm_cores + self.afus) as f64;
        let idle_pj = pj_cycle * 0.10 / blocks;
        EnergyTable {
            mac_pj,
            rf_pj,
            gb_pj,
            afu_pj,
            idle_pj,
            ema_pj_per_bit: self.dram_pj_per_bit,
        }
    }

    /// DRAM transfer time for `bytes`, in nanoseconds.
    pub fn dram_ns(&self, bytes: usize) -> f64 {
        bytes as f64 / self.dram_gbps // bytes / (GB/s) = ns
    }
    /// DRAM energy for `bytes`, in picojoules.
    pub fn dram_pj(&self, bytes: usize) -> f64 {
        bytes as f64 * 8.0 * self.dram_pj_per_bit
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.points.is_empty() {
            return Err(Error::config("no operating points"));
        }
        if !self.points.windows(2).all(|w| w[0].vdd < w[1].vdd) {
            return Err(Error::config("operating points must be ascending in vdd"));
        }
        if self.dmm_tile() == 0 || self.trf_dim == 0 {
            return Err(Error::config("zero tile size"));
        }
        if self.max_seq == 0 || self.gb_bytes == 0 {
            return Err(Error::config("zero capacity"));
        }
        if self.kv_page_bytes == 0 {
            return Err(Error::config("zero kv page size"));
        }
        Ok(())
    }

    // ------------------------------------------------------------- JSON
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dmm_cores", Json::num(self.dmm_cores as f64)),
            ("dmm_pe_dim", Json::num(self.dmm_pe_dim as f64)),
            ("pe_mac_dim", Json::num(self.pe_mac_dim as f64)),
            ("smm_cores", Json::num(self.smm_cores as f64)),
            ("smm_mac_dim", Json::num(self.smm_mac_dim as f64)),
            ("afus", Json::num(self.afus as f64)),
            ("afu_iaus", Json::num(self.afu_iaus as f64)),
            ("afu_faus", Json::num(self.afu_faus as f64)),
            ("gb_bytes", Json::num(self.gb_bytes as f64)),
            ("trf_dim", Json::num(self.trf_dim as f64)),
            ("dram_gbps", Json::num(self.dram_gbps)),
            ("dram_pj_per_bit", Json::num(self.dram_pj_per_bit)),
            ("kv_page_bytes", Json::num(self.kv_page_bytes as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("vdd", Json::num(p.vdd)),
                                ("freq_mhz", Json::num(p.freq_mhz)),
                                ("peak_mw", Json::num(p.peak_mw)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let points = j
            .get("points")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(OperatingPoint {
                    vdd: p.get("vdd")?.as_f64()?,
                    freq_mhz: p.get("freq_mhz")?.as_f64()?,
                    peak_mw: p.get("peak_mw")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let cfg = HwConfig {
            dmm_cores: j.get("dmm_cores")?.as_usize()?,
            dmm_pe_dim: j.get("dmm_pe_dim")?.as_usize()?,
            pe_mac_dim: j.get("pe_mac_dim")?.as_usize()?,
            smm_cores: j.get("smm_cores")?.as_usize()?,
            smm_mac_dim: j.get("smm_mac_dim")?.as_usize()?,
            afus: j.get("afus")?.as_usize()?,
            afu_iaus: j.get("afu_iaus")?.as_usize()?,
            afu_faus: j.get("afu_faus")?.as_usize()?,
            gb_bytes: j.get("gb_bytes")?.as_usize()?,
            trf_dim: j.get("trf_dim")?.as_usize()?,
            dram_gbps: j.get("dram_gbps")?.as_f64()?,
            dram_pj_per_bit: j.get("dram_pj_per_bit")?.as_f64()?,
            // Absent in pre-KV-arena configs: fall back to the default page.
            kv_page_bytes: match j.get("kv_page_bytes") {
                Ok(v) => v.as_usize()?,
                Err(_) => 2048,
            },
            max_seq: j.get("max_seq")?.as_usize()?,
            points,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_paper() {
        let hw = HwConfig::default();
        assert_eq!(hw.dmm_macs(), 4 * 16 * 16); // 1024 DMM MACs
        assert_eq!(hw.smm_macs(), 4 * 64); // 256 SMM MACs
        assert_eq!(hw.dmm_tile(), 16); // 16×16 output tile
        hw.validate().unwrap();
    }

    #[test]
    fn mac_cycles_bit_serial() {
        assert_eq!(Precision::Int16.mac_cycles(), 16);
        assert_eq!(Precision::Int8.mac_cycles(), 4);
        assert_eq!(Precision::Int4.mac_cycles(), 1);
    }

    #[test]
    fn operating_point_range_matches_fig7() {
        let hw = HwConfig::default();
        let lo = hw.min_point();
        let hi = hw.max_point();
        assert_eq!((lo.vdd, lo.freq_mhz, lo.peak_mw), (0.45, 60.0, 7.12));
        assert_eq!((hi.vdd, hi.freq_mhz, hi.peak_mw), (0.85, 450.0, 152.5));
    }

    #[test]
    fn point_interpolation_monotone() {
        let hw = HwConfig::default();
        let mut prev = 0.0;
        for i in 0..=40 {
            let vdd = 0.45 + i as f64 * 0.01;
            let p = hw.point_at_vdd(vdd);
            assert!(p.freq_mhz >= prev);
            prev = p.freq_mhz;
        }
        // Clamp behaviour
        assert_eq!(hw.point_at_vdd(0.1).freq_mhz, 60.0);
        assert_eq!(hw.point_at_vdd(2.0).freq_mhz, 450.0);
    }

    #[test]
    fn point_at_vdd_checked_reports_clamping() {
        let hw = HwConfig::default();
        // In-range requests (edges included) are not clamped.
        assert!(!hw.point_at_vdd_checked(0.45).1);
        assert!(!hw.point_at_vdd_checked(0.60).1);
        assert!(!hw.point_at_vdd_checked(0.85).1);
        // Out-of-range requests clamp to the edge and say so.
        let (lo, clamped_lo) = hw.point_at_vdd_checked(0.1);
        assert!(clamped_lo);
        assert_eq!((lo.vdd, lo.freq_mhz), (0.45, 60.0));
        let (hi, clamped_hi) = hw.point_at_vdd_checked(2.0);
        assert!(clamped_hi);
        assert_eq!((hi.vdd, hi.freq_mhz), (0.85, 450.0));
        // NaN clamps to the slowest point rather than poisoning pricing.
        let (nan_pt, nan_clamped) = hw.point_at_vdd_checked(f64::NAN);
        assert!(nan_clamped);
        assert_eq!(nan_pt.vdd, 0.45);
        assert!(hw.point_at_vdd(f64::NAN).freq_mhz == 60.0);
    }

    #[test]
    fn pinned_config_prices_everything_at_one_point() {
        let hw = HwConfig::default();
        let pinned = hw.pinned_at_vdd(0.60);
        pinned.validate().unwrap();
        assert_eq!(pinned.points.len(), 1);
        let want = hw.point_at_vdd(0.60);
        assert_eq!(pinned.max_point(), want);
        assert_eq!(pinned.min_point(), want);
        assert_eq!(pinned.point_at_vdd(0.85), want, "one-point table clamps");
        // Geometry and the DRAM model are untouched.
        assert_eq!(pinned.dmm_macs(), hw.dmm_macs());
        assert_eq!(pinned.dram_ns(64), hw.dram_ns(64));
    }

    #[test]
    fn energy_table_sums_to_peak() {
        // The decomposition must re-assemble into the measured peak power.
        let hw = HwConfig::default();
        for &p in &hw.points {
            let e = hw.energy_at(p);
            let macs = hw.total_macs() as f64;
            let afu_units = (hw.afus * (hw.afu_iaus + hw.afu_faus)) as f64;
            let blocks = (hw.dmm_cores + hw.smm_cores + hw.afus) as f64;
            let total = e.mac_pj * macs
                + e.rf_pj * macs * 2.0
                + e.gb_pj * macs / 8.0
                + e.afu_pj * afu_units
                + e.idle_pj * blocks;
            let expect = p.peak_pj_per_cycle();
            assert!(
                (total - expect).abs() / expect < 1e-9,
                "vdd={} total={total} expect={expect}",
                p.vdd
            );
        }
    }

    #[test]
    fn dram_model_paper_constants() {
        let hw = HwConfig::default();
        // 1 byte at 6.4 GB/s = 0.15625 ns; 8 bits × 3.7 pJ/b = 29.6 pJ.
        assert!((hw.dram_ns(1) - 0.15625).abs() < 1e-12);
        assert!((hw.dram_pj(1) - 29.6).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let hw = HwConfig::default();
        let j = hw.to_json();
        let hw2 = HwConfig::from_json(&j).unwrap();
        assert_eq!(hw.dmm_macs(), hw2.dmm_macs());
        assert_eq!(hw.points, hw2.points);
        assert_eq!(hw.gb_bytes, hw2.gb_bytes);
        assert_eq!(hw2.kv_page_bytes, 2048);
        // And via text
        let hw3 = HwConfig::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(hw3.dram_gbps, hw.dram_gbps);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut hw = HwConfig::default();
        hw.points.clear();
        assert!(hw.validate().is_err());
        let mut hw = HwConfig::default();
        hw.points.reverse();
        assert!(hw.validate().is_err());
        let mut hw = HwConfig::default();
        hw.max_seq = 0;
        assert!(hw.validate().is_err());
        let mut hw = HwConfig::default();
        hw.kv_page_bytes = 0;
        assert!(hw.validate().is_err());
    }
}
