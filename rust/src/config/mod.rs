//! Hardware and model configuration.
//!
//! [`HwConfig`] captures the T-REX chip geometry and its published operating
//! points (Fig. 23.1.7); [`ModelConfig`] captures the four paper workloads
//! (Fig. 23.1.6) plus a `tiny` preset used by tests and the end-to-end
//! example. Both serialize to/from JSON via [`crate::util::json`].

mod hw;
mod model;

pub use hw::{EnergyTable, HwConfig, OperatingPoint, Precision};
pub use model::{ArchKind, ModelConfig, WORKLOADS};
