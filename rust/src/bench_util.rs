//! Minimal benchmark harness (criterion is not vendored in the offline
//! environment). Provides warmup + timed iterations with mean/σ/percentiles,
//! and a tabular reporter shared by all `benches/fig*.rs` targets.

use crate::util::stats;
use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Value of a `--key VALUE` pair in this process's CLI args (the benches'
/// shared flag parser — clap is not vendored offline).
pub fn arg_value(key: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

/// Time `f` for `iters` iterations after `warmup` iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        stddev_ns: stats::stddev(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p99_ns: stats::percentile(&samples, 99.0),
    }
}

/// Print a header box for a figure reproduction.
pub fn banner(title: &str) {
    let line = "=".repeat(title.len() + 4);
    println!("\n{line}\n| {title} |\n{line}");
}

/// Print a table: header row + data rows, left-aligned columns.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Format helper: "12.3x" style ratios.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format helper: engineering quantities.
pub fn si(x: f64, unit: &str) -> String {
    let (v, p) = if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{v:.2} {p}{unit}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("spin", 2, 10, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        std::hint::black_box(x);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(1500.0, "B"), "1.50 kB");
        assert_eq!(si(2.5e6, "B/s"), "2.50 MB/s");
        assert_eq!(si(3.0, "J"), "3.00 J");
    }

    #[test]
    fn table_does_not_panic_on_ragged_rows() {
        table(&["a", "b"], &[vec!["1".into()], vec!["1".into(), "2".into(), "3".into()]]);
    }
}
