//! Summary statistics used by the bench harness and the metrics plane.
//!
//! Every summary here drops non-finite samples (NaN and ±∞) before
//! aggregating: one poisoned latency observation must never turn a whole
//! report's mean/min/max into NaN or `inf` — both serialize as invalid
//! JSON. All-non-finite (or empty) inputs clamp to 0.

fn finite(xs: &[f64]) -> impl Iterator<Item = f64> + '_ {
    xs.iter().copied().filter(|x| x.is_finite())
}

/// Mean over the finite samples (0 when none).
pub fn mean(xs: &[f64]) -> f64 {
    let (mut n, mut sum) = (0u64, 0.0);
    for x in finite(xs) {
        n += 1;
        sum += x;
    }
    if n == 0 {
        return 0.0;
    }
    sum / n as f64
}

/// Sample standard deviation over the finite samples (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    let n = finite(xs).count();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (finite(xs).map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
}

/// Percentile by linear interpolation on the sorted data, `p` in [0, 100].
///
/// Robust twice over: samples sort by `f64::total_cmp` (no
/// `partial_cmp().unwrap()` panic — a single bad latency sample must never
/// take the metrics thread down), and non-finite samples are dropped before
/// ranking so the result itself stays finite (a NaN or ±∞ percentile would
/// serialize as invalid JSON in reports).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = finite(xs).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Minimum of the finite samples (0 when none — ±∞ from the fold identity
/// would serialize as invalid JSON in reports).
pub fn min(xs: &[f64]) -> f64 {
    let m = finite(xs).fold(f64::INFINITY, f64::min);
    if m.is_finite() {
        m
    } else {
        0.0
    }
}

/// Maximum of the finite samples (0 when none; see [`min`]).
pub fn max(xs: &[f64]) -> f64 {
    let m = finite(xs).fold(f64::NEG_INFINITY, f64::max);
    if m.is_finite() {
        m
    } else {
        0.0
    }
}

/// Geometric mean of the finite samples (for speedup aggregation across
/// workloads; 0 when none).
pub fn geomean(xs: &[f64]) -> f64 {
    let (mut n, mut lnsum) = (0u64, 0.0);
    for x in finite(xs) {
        n += 1;
        lnsum += x.ln();
    }
    if n == 0 {
        return 0.0;
    }
    (lnsum / n as f64).exp()
}

/// Running-summary accumulator used in the serving metrics hot path —
/// O(1) per observation, no allocation.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub sum2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, sum: 0.0, sum2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    /// Fold one observation in. Non-finite samples are dropped (same
    /// contract as the batch [`mean`]/[`min`]/[`max`] above): one NaN
    /// would otherwise poison `sum` for the lifetime of the accumulator.
    pub fn push(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.n += 1;
        self.sum += x;
        self.sum2 += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum2 / self.n as f64 - m * m).max(0.0) * self.n as f64 / (self.n - 1) as f64).sqrt()
    }
    pub fn merge(&mut self, other: &Running) {
        self.n += other.n;
        self.sum += other.sum;
        self.sum2 += other.sum2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Bounded percentile sampler: keeps every observation up to `cap`, then
/// switches to uniform reservoir sampling (Vitter's algorithm R) so a
/// long-running pool's latency metrics stay O(cap) memory no matter how
/// much traffic flows. Below `cap` the percentiles are exact — the small
/// deterministic workloads the tests pin are unaffected.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: crate::util::rng::Rng,
}

/// Default reservoir size: plenty for stable p50/p95/p99, tiny in memory.
pub const RESERVOIR_CAP: usize = 4096;

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir::new(RESERVOIR_CAP)
    }
}

impl Reservoir {
    pub fn new(cap: usize) -> Self {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            samples: Vec::new(),
            rng: crate::util::rng::Rng::new(0x5EED_5A3B),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Replace a random slot with probability cap/seen — every
            // observation ends up retained with equal probability.
            let j = ((self.rng.next_u64() as u128 * self.seen as u128) >> 64) as u64;
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// Observations offered (not retained).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Observations retained (≤ cap).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Percentile over the retained sample (exact below cap).
    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((stddev(&xs) - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 5.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: `partial_cmp().unwrap()` panicked on any NaN sample,
        // taking the metrics thread down with it. NaNs are dropped before
        // ranking, so every percentile stays finite (JSON-serializable).
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p50 = percentile(&xs, 50.0);
        assert_eq!(p50, 2.0, "percentile over the finite samples [1, 2, 3]");
        let p100 = percentile(&xs, 100.0);
        assert!(p100.is_finite(), "top percentile must not surface the NaN: {p100}");
        assert_eq!(p100, 3.0);
        assert_eq!(percentile(&[f64::NAN], 50.0), 0.0, "all-NaN input clamps to 0");
    }

    #[test]
    fn non_finite_samples_are_dropped_consistently() {
        // Regression: percentile filtered NaN but mean/stddev/min/max did
        // not — one poisoned sample turned every other summary in a report
        // into NaN (invalid JSON) while the percentiles looked healthy.
        let xs = [3.0, f64::NAN, 1.0, f64::INFINITY, 2.0, f64::NEG_INFINITY];
        assert_eq!(mean(&xs), 2.0);
        assert!((stddev(&xs) - 1.0).abs() < 1e-12);
        assert_eq!(min(&xs), 1.0, "min must not surface -inf");
        assert_eq!(max(&xs), 3.0, "max must not surface +inf");
        assert_eq!(percentile(&xs, 100.0), 3.0, "percentile must drop +inf, not just NaN");
        assert_eq!(geomean(&[2.0, f64::NAN, 8.0]), geomean(&[2.0, 8.0]));

        // All-non-finite behaves exactly like empty: everything clamps to 0.
        let bad = [f64::NAN, f64::INFINITY];
        assert_eq!(mean(&bad), 0.0);
        assert_eq!(stddev(&bad), 0.0);
        assert_eq!(min(&bad), 0.0);
        assert_eq!(max(&bad), 0.0);
        assert_eq!(percentile(&bad, 50.0), 0.0);

        // The O(1) running accumulator applies the same filter.
        let mut run = Running::new();
        for &x in &xs {
            run.push(x);
        }
        assert_eq!(run.n, 3);
        assert_eq!(run.mean(), 2.0);
        assert_eq!(run.min, 1.0);
        assert_eq!(run.max, 3.0);
    }

    #[test]
    fn empty_min_max_serialize_to_valid_json() {
        // Regression: ±INFINITY from the fold identities reached Json::num
        // and serialized as non-JSON ("inf"). Empty summaries clamp to 0.
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        let j = crate::util::json::Json::obj(vec![
            ("min", crate::util::json::Json::num(min(&[]))),
            ("max", crate::util::json::Json::num(max(&[]))),
        ]);
        let s = j.to_string();
        assert!(!s.contains("inf") && !s.contains("Inf"), "invalid JSON: {s}");
        assert!(s.contains('0'));
    }

    #[test]
    fn reservoir_exact_below_cap_and_bounded_above() {
        // Below cap: identical to the unbounded percentile.
        let mut r = Reservoir::new(64);
        let xs: Vec<f64> = (1..=50).map(|i| i as f64).collect();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.len(), 50);
        assert_eq!(r.percentile(50.0), percentile(&xs, 50.0));
        assert_eq!(r.percentile(95.0), percentile(&xs, 95.0));

        // Far above cap: memory stays bounded and the sampled percentile
        // tracks the true distribution (uniform 0..10_000 here).
        let mut big = Reservoir::new(512);
        for i in 0..100_000u64 {
            big.push((i % 10_000) as f64);
        }
        assert_eq!(big.len(), 512, "reservoir never outgrows its cap");
        assert_eq!(big.seen(), 100_000);
        let p50 = big.percentile(50.0);
        assert!((3500.0..6500.0).contains(&p50), "sampled p50 {p50} off a uniform median");
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn running_matches_batch() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(42);
        let xs: Vec<f64> = (0..1000).map(|_| r.normal() * 3.0 + 7.0).collect();
        let mut run = Running::new();
        for &x in &xs {
            run.push(x);
        }
        assert!((run.mean() - mean(&xs)).abs() < 1e-9);
        assert!((run.stddev() - stddev(&xs)).abs() < 1e-6);
        assert_eq!(run.min, min(&xs));
        assert_eq!(run.max, max(&xs));

        // merge property: split halves and merge == whole
        let (a, b) = xs.split_at(400);
        let mut ra = Running::new();
        let mut rb = Running::new();
        a.iter().for_each(|&x| ra.push(x));
        b.iter().for_each(|&x| rb.push(x));
        ra.merge(&rb);
        assert!((ra.mean() - run.mean()).abs() < 1e-9);
        assert_eq!(ra.n, run.n);
    }
}
