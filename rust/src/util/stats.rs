//! Summary statistics used by the bench harness and the metrics plane.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile by linear interpolation on the sorted data, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Geometric mean (for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Running-summary accumulator used in the serving metrics hot path —
/// O(1) per observation, no allocation.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub sum2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, sum: 0.0, sum2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum2 += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum2 / self.n as f64 - m * m).max(0.0) * self.n as f64 / (self.n - 1) as f64).sqrt()
    }
    pub fn merge(&mut self, other: &Running) {
        self.n += other.n;
        self.sum += other.sum;
        self.sum2 += other.sum2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((stddev(&xs) - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 5.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn running_matches_batch() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(42);
        let xs: Vec<f64> = (0..1000).map(|_| r.normal() * 3.0 + 7.0).collect();
        let mut run = Running::new();
        for &x in &xs {
            run.push(x);
        }
        assert!((run.mean() - mean(&xs)).abs() < 1e-9);
        assert!((run.stddev() - stddev(&xs)).abs() < 1e-6);
        assert_eq!(run.min, min(&xs));
        assert_eq!(run.max, max(&xs));

        // merge property: split halves and merge == whole
        let (a, b) = xs.split_at(400);
        let mut ra = Running::new();
        let mut rb = Running::new();
        a.iter().for_each(|&x| ra.push(x));
        b.iter().for_each(|&x| rb.push(x));
        ra.merge(&rb);
        assert!((ra.mean() - run.mean()).abs() < 1e-9);
        assert_eq!(ra.n, run.n);
    }
}
