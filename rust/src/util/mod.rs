//! Dependency-free utilities: JSON, RNG, statistics, matrices, bit-packing.
//!
//! The offline build environment vendors neither `serde` nor `rand` nor
//! `criterion`, so the small pieces of each that T-REX needs are implemented
//! here (and exercised by their own unit + property tests).

pub mod bitpack;
pub mod json;
pub mod mat;
pub mod rng;
pub mod stats;

pub use bitpack::{BitReader, BitWriter};
pub use json::Json;
pub use mat::Mat;
pub use rng::Rng;
