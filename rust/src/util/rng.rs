//! Seeded SplitMix64 PRNG.
//!
//! `rand` is not vendored in the offline environment; SplitMix64 is small,
//! fast, and statistically adequate for workload generation, synthetic
//! weights and the generative property tests used across the crate.
//! Deterministic by construction — every experiment records its seed.

/// SplitMix64 generator (public-domain algorithm by Sebastiano Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (Lemire); bias is < 2^-64 * n,
        // negligible for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// f32 standard normal (weights, activations).
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Random printable ASCII char (for string property tests).
    pub fn ascii(&mut self) -> char {
        (b' ' + self.below(95) as u8) as char
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Geometric-ish sequence length sampler used by the workload generators:
    /// clamps an exponential draw to `[1, max]` with mean ~`mean`.
    pub fn seq_len(&mut self, mean: f64, max: usize) -> usize {
        let x = -mean * (1.0 - self.f64()).ln();
        (x.round() as usize).clamp(1, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let n = 1 + (r.next_u64() % 1000) as usize;
            assert!(r.below(n) < n);
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        const N: usize = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..N {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / N as f64;
        let var = s2 / N as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(4);
        for _ in 0..200 {
            let n = r.range(1, 64);
            let k = r.range(0, n);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn seq_len_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            let l = r.seq_len(40.0, 128);
            assert!((1..=128).contains(&l));
        }
    }
}
