//! Minimal JSON value, recursive-descent parser and serializer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for hardware/model configs and the
//! artifact manifest written by `python/compile/aot.py`. Object key order is
//! preserved (insertion order) so round-trips are stable.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep keys sorted (BTreeMap) — deterministic serialization.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::json(format!("expected number, got {other:?}"))),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::json(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as usize)
    }
    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::json(format!("expected bool, got {other:?}"))),
        }
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::json(format!("expected string, got {other:?}"))),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(Error::json(format!("expected array, got {other:?}"))),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::json(format!("expected object, got {other:?}"))),
        }
    }
    /// Required object field.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::json(format!("missing field '{key}'")))
    }
    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // -------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------------------------------------- parsing
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::json(format!("trailing bytes at offset {}", p.i)));
        }
        Ok(v)
    }

    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Json::parse(&text)
    }

    // --------------------------------------------------------- serializing
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s.push('\n');
        s
    }
    pub fn to_file(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        Ok(std::fs::write(path, self.to_string_pretty())?)
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (k, item) in v.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, val)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, key);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::json("unexpected end of input"))
    }
    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::json(format!(
                "expected '{}' at offset {}, got '{}'",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::json(format!("bad literal at offset {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(Error::json(format!("unexpected '{}' at offset {}", c as char, self.i))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(Error::json(format!("expected ',' or '}}', got '{}'", c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(Error::json(format!("expected ',' or ']', got '{}'", c as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::json("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::json("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::json("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: keep simple, reject unpaired.
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| Error::json("surrogate \\u escape unsupported"))?,
                            );
                        }
                        c => return Err(Error::json(format!("bad escape '\\{}'", c as char))),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: back up and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| Error::json("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::json(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".to_string()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": true}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_bool().unwrap(), true);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":"bert-large","dims":[1024,4096],"ratio":2.14,"ok":true,"x":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" slash\\ nl\n tab\t unicode:µβ 中";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        let v = Json::parse("{\"a\":1}").unwrap();
        assert!(v.get("b").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_usize().is_err());
    }

    #[test]
    fn property_roundtrip_random_values() {
        // Hand-rolled generative test (proptest is not vendored): build random
        // Json trees from a seeded RNG and check parse(serialize(v)) == v.
        use crate::util::rng::Rng;
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::Num((rng.below(2_000_001) as f64 - 1_000_000.0) / 64.0),
                3 => {
                    let n = rng.below(8);
                    Json::Str((0..n).map(|_| rng.ascii()).collect())
                }
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|k| (format!("k{k}_{}", rng.below(100)), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut rng = Rng::new(0x7E57_0001);
        for _ in 0..200 {
            let v = gen(&mut rng, 3);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
            assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
        }
    }
}
