//! Dense row-major f32 matrix with the tiled operations the chip performs.
//!
//! This is the *reference numerics* backing for the Rust-side tests and the
//! simulator's functional mode — the production numerics run through the
//! PJRT-compiled JAX/Pallas artifacts in [`crate::runtime`].

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "Mat::from_vec: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Gaussian random matrix scaled like a typical init (`σ = 1/√cols`).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let scale = 1.0 / (cols as f32).sqrt();
        let data = (0..rows * cols).map(|_| rng.normal_f32() * scale).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *t.at_mut(c, r) = self.at(r, c);
            }
        }
        t
    }

    /// `self · other` — blocked i-k-j loop (cache-friendly; the Rust-side
    /// reference, not the serving hot path).
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::shape(format!(
                "matmul: {}x{} · {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Mat::zeros(self.rows, other.cols);
        let n = other.cols;
        for i in 0..self.rows {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * n..(k + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(out)
    }

    pub fn add(&self, other: &Mat) -> Result<Mat> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::shape("add: shape mismatch".to_string()));
        }
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Ok(Mat { rows: self.rows, cols: self.cols, data })
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Relative reconstruction error `‖self − other‖_F / ‖self‖_F`.
    pub fn rel_err(&self, other: &Mat) -> f64 {
        let mut diff = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (*a - *b) as f64;
            diff += d * d;
        }
        diff.sqrt() / self.fro().max(1e-30)
    }

    /// Max absolute element difference.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Extract the `tr`-th, `tc`-th tile of size `t×t` (zero-padded at edges).
    /// This is the granule the DMM cores operate on (t = 16 on the chip).
    pub fn tile(&self, tr: usize, tc: usize, t: usize) -> Mat {
        let mut out = Mat::zeros(t, t);
        for r in 0..t {
            for c in 0..t {
                let (gr, gc) = (tr * t + r, tc * t + c);
                if gr < self.rows && gc < self.cols {
                    *out.at_mut(r, c) = self.at(gr, gc);
                }
            }
        }
        out
    }

    /// Number of `t×t` tiles covering this matrix, (tile_rows, tile_cols).
    pub fn tiles(&self, t: usize) -> (usize, usize) {
        (self.rows.div_ceil(t), self.cols.div_ceil(t))
    }

    /// Apply a column permutation: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Result<Mat> {
        if perm.len() != self.cols {
            return Err(Error::shape("permute_cols: bad perm length".to_string()));
        }
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for (j, &p) in perm.iter().enumerate() {
                *out.at_mut(r, j) = self.at(r, p);
            }
        }
        Ok(out)
    }

    /// Apply a row permutation: `out[i, :] = self[perm[i], :]`.
    pub fn permute_rows(&self, perm: &[usize]) -> Result<Mat> {
        if perm.len() != self.rows {
            return Err(Error::shape("permute_rows: bad perm length".to_string()));
        }
        let mut out = Mat::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            out.data[i * self.cols..(i + 1) * self.cols]
                .copy_from_slice(&self.data[p * self.cols..(p + 1) * self.cols]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_shape_error() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(7, 13, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_associativity_factorized_order() {
        // (X·Ws)·Wd == X·(Ws·Wd) — the paper's computing-order equivalence.
        let mut rng = Rng::new(10);
        let x = Mat::randn(8, 32, &mut rng);
        let ws = Mat::randn(32, 12, &mut rng);
        let wd = Mat::randn(12, 24, &mut rng);
        let a = x.matmul(&ws).unwrap().matmul(&wd).unwrap();
        let b = x.matmul(&ws.matmul(&wd).unwrap()).unwrap();
        assert!(a.rel_err(&b) < 1e-5, "rel err {}", a.rel_err(&b));
    }

    #[test]
    fn tiles_cover_matrix() {
        let m = Mat::zeros(33, 47);
        assert_eq!(m.tiles(16), (3, 3));
        let m = Mat::zeros(32, 48);
        assert_eq!(m.tiles(16), (2, 3));
    }

    #[test]
    fn tile_extraction_zero_pad() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(20, 20, &mut rng);
        let t = a.tile(1, 1, 16); // covers rows 16..32 → only 16..20 valid
        assert_eq!(t.at(0, 0), a.at(16, 16));
        assert_eq!(t.at(5, 5), 0.0); // padded region
    }

    #[test]
    fn permutation_inverse() {
        let mut rng = Rng::new(12);
        let a = Mat::randn(6, 10, &mut rng);
        let mut perm: Vec<usize> = (0..10).collect();
        rng.shuffle(&mut perm);
        let mut inv = vec![0usize; 10];
        for (i, &p) in perm.iter().enumerate() {
            inv[p] = i;
        }
        let b = a.permute_cols(&perm).unwrap().permute_cols(&inv).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn permute_preserves_product() {
        // Permuting columns of Ws and rows of Wd by the same perm leaves
        // Ws·Wd unchanged — the invariant behind the paper's delta-encoding
        // rearrangement.
        let mut rng = Rng::new(13);
        let ws = Mat::randn(16, 12, &mut rng);
        let wd = Mat::randn(12, 20, &mut rng);
        let mut perm: Vec<usize> = (0..12).collect();
        rng.shuffle(&mut perm);
        let ws_p = ws.permute_cols(&perm).unwrap();
        let wd_p = wd.permute_rows(&perm).unwrap();
        let w1 = ws.matmul(&wd).unwrap();
        let w2 = ws_p.matmul(&wd_p).unwrap();
        assert!(w1.rel_err(&w2) < 1e-6);
    }
}
