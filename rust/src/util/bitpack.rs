//! Bit-level packing for the sub-byte streams the chip stores in DRAM:
//! 4-bit non-uniform codes (W_S), 5-bit delta-encoded indices and 6-bit
//! uniform codes (W_D). LSB-first within each byte, matching
//! `python/compile/compress.py` bit-for-bit (cross-language tests in
//! `rust/tests/integration_compress.rs`).

use crate::error::{Error, Result};

/// Append-only bit stream writer, LSB-first.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the last byte (0 ⇒ byte-aligned).
    bit: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `width` bits of `value` (width 1..=32).
    pub fn put(&mut self, value: u32, width: u32) -> Result<()> {
        if width == 0 || width > 32 {
            return Err(Error::codec(format!("BitWriter: bad width {width}")));
        }
        if width < 32 && value >> width != 0 {
            return Err(Error::codec(format!(
                "BitWriter: value {value} does not fit in {width} bits"
            )));
        }
        let mut remaining = width;
        let mut v = value as u64;
        while remaining > 0 {
            if self.bit == 0 {
                self.buf.push(0);
            }
            let space = 8 - self.bit;
            let take = remaining.min(space);
            let last = self.buf.last_mut().unwrap();
            *last |= ((v & ((1u64 << take) - 1)) as u8) << self.bit;
            v >>= take;
            self.bit = (self.bit + take) % 8;
            remaining -= take;
        }
        Ok(())
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        if self.bit == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.bit as usize
        }
    }

    /// Finish, returning the byte buffer (final partial byte zero-padded).
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Bit stream reader, LSB-first (inverse of [`BitWriter`]).
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `width` bits (1..=32).
    pub fn get(&mut self, width: u32) -> Result<u32> {
        if width == 0 || width > 32 {
            return Err(Error::codec(format!("BitReader: bad width {width}")));
        }
        if self.pos + width as usize > self.buf.len() * 8 {
            return Err(Error::codec("BitReader: out of bits".to_string()));
        }
        let mut out: u64 = 0;
        let mut got = 0u32;
        while got < width {
            let byte = self.buf[self.pos / 8] as u64;
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = (width - got).min(avail);
            let bits = (byte >> off) & ((1u64 << take) - 1);
            out |= bits << got;
            got += take;
            self.pos += take as usize;
        }
        Ok(out as u32)
    }

    pub fn bits_left(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

/// Pack a slice of codes with uniform `width` into bytes.
pub fn pack(codes: &[u32], width: u32) -> Result<Vec<u8>> {
    let mut w = BitWriter::new();
    for &c in codes {
        w.put(c, width)?;
    }
    Ok(w.finish())
}

/// Unpack `n` codes of uniform `width` from bytes.
pub fn unpack(bytes: &[u8], n: usize, width: u32) -> Result<Vec<u32>> {
    let mut r = BitReader::new(bytes);
    (0..n).map(|_| r.get(width)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_4b() {
        let codes = vec![0, 1, 15, 7, 8, 3];
        let bytes = pack(&codes, 4).unwrap();
        assert_eq!(bytes.len(), 3); // 6 codes * 4b = 24b
        assert_eq!(unpack(&bytes, 6, 4).unwrap(), codes);
    }

    #[test]
    fn pack_unpack_5b_6b_unaligned() {
        let codes5 = vec![31, 0, 17, 5, 22, 1, 30];
        let b5 = pack(&codes5, 5).unwrap();
        assert_eq!(b5.len(), 5); // 35 bits → 5 bytes
        assert_eq!(unpack(&b5, 7, 5).unwrap(), codes5);

        let codes6 = vec![63, 0, 42, 13];
        let b6 = pack(&codes6, 6).unwrap();
        assert_eq!(b6.len(), 3); // 24 bits
        assert_eq!(unpack(&b6, 4, 6).unwrap(), codes6);
    }

    #[test]
    fn width_overflow_rejected() {
        let mut w = BitWriter::new();
        assert!(w.put(16, 4).is_err());
        assert!(w.put(1, 0).is_err());
        assert!(w.put(1, 33).is_err());
        w.put(15, 4).unwrap();
    }

    #[test]
    fn reader_exhaustion() {
        let bytes = pack(&[1, 2, 3], 4).unwrap(); // 12 bits in 2 bytes
        let mut r = BitReader::new(&bytes);
        r.get(12).unwrap();
        r.get(4).unwrap(); // padding bits still readable
        assert!(r.get(1).is_err());
    }

    #[test]
    fn property_roundtrip_mixed_widths() {
        // Generative: random width sequence, random values — write then read
        // back the identical sequence.
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let n = rng.range(1, 100);
            let items: Vec<(u32, u32)> = (0..n)
                .map(|_| {
                    let w = rng.range(1, 32) as u32;
                    let v = if w == 32 {
                        rng.next_u64() as u32
                    } else {
                        (rng.next_u64() as u32) & ((1u32 << w) - 1)
                    };
                    (v, w)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &items {
                w.put(v, width).unwrap();
            }
            let total: u32 = items.iter().map(|&(_, w)| w).sum();
            assert_eq!(w.bit_len(), total as usize);
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, width) in &items {
                assert_eq!(r.get(width).unwrap(), v);
            }
        }
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put(1, 3).unwrap();
        assert_eq!(w.bit_len(), 3);
        w.put(1, 8).unwrap();
        assert_eq!(w.bit_len(), 11);
    }
}
