//! Alternating-least-squares factorizer with fixed-NZ-per-column sparsity.
//!
//! Solves  min_{W_S, {W_D^l}}  Σ_l ‖W^l − W_S·W_D^l‖²_F  subject to every
//! column of every `W_D^l` having exactly `nnz_per_col` non-zeros — the
//! offline equivalent of the paper's regularized factorizing training
//! (which the paper runs as full model training; see DESIGN.md §2 for the
//! substitution argument). The shared `W_S` is fit **jointly across layers**,
//! which is the property that makes "load W_S once" possible.

use crate::error::Result;
use crate::factorize::linalg::{gram_t, lstsq, solve_mat};
use crate::factorize::sparse::CscFixed;
use crate::util::mat::Mat;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct FactorizeOptions {
    pub rank: usize,
    pub nnz_per_col: usize,
    pub iters: usize,
    /// Tikhonov damping for the normal equations.
    pub lambda: f32,
    pub seed: u64,
}

impl Default for FactorizeOptions {
    fn default() -> Self {
        FactorizeOptions { rank: 16, nnz_per_col: 4, iters: 12, lambda: 1e-4, seed: 0 }
    }
}

/// Result of a joint factorization.
#[derive(Debug, Clone)]
pub struct Factorized {
    pub ws: Mat,
    pub wds: Vec<CscFixed>,
    /// Per-layer relative reconstruction error after the final iteration.
    pub rel_err: Vec<f64>,
}

/// Jointly factorize `layers` of equally-shaped matrices into one shared
/// `W_S` plus per-layer sparse `W_D`s.
pub fn factorize_joint(layers: &[Mat], opts: FactorizeOptions) -> Result<Factorized> {
    assert!(!layers.is_empty());
    let d_in = layers[0].rows;
    let d_out = layers[0].cols;
    for w in layers {
        assert_eq!((w.rows, w.cols), (d_in, d_out), "layers must share shape");
    }
    let r = opts.rank;
    let mut rng = Rng::new(opts.seed ^ 0x5EED_FAC7);
    let mut ws = Mat::randn(d_in, r, &mut rng);
    let mut wds: Vec<Mat> = Vec::new();

    for it in 0..opts.iters {
        // --- W_D step: per layer, dense least squares then hard projection
        // onto the fixed-support set, then refit values on the support.
        wds.clear();
        for w in layers {
            let dense = lstsq(&ws, w, opts.lambda)?; // r × d_out
            let sp = CscFixed::from_dense_topk(&dense, opts.nnz_per_col)?;
            let refit = refit_on_support(&ws, w, &sp, opts.lambda)?;
            wds.push(refit.to_dense());
        }
        // --- W_S step (joint): W_S = (Σ W^l (W_D^l)ᵀ) (Σ W_D^l (W_D^l)ᵀ + λI)⁻¹
        let mut num = Mat::zeros(d_in, r);
        let mut den = Mat::zeros(r, r);
        for (w, wd) in layers.iter().zip(&wds) {
            num = num.add(&w.matmul(&wd.transpose())?)?;
            den = den.add(&gram_t(wd, 0.0))?;
        }
        for i in 0..r {
            *den.at_mut(i, i) += opts.lambda;
        }
        // Solve den · Wsᵀ = numᵀ  ⇒ Ws = (den⁻¹ numᵀ)ᵀ
        let wst = solve_mat(&den, &num.transpose())?;
        ws = wst.transpose();
        let _ = it;
    }

    // Final projection + error report.
    let mut out_wds = Vec::new();
    let mut rel_err = Vec::new();
    for w in layers {
        let dense = lstsq(&ws, w, opts.lambda)?;
        let sp = CscFixed::from_dense_topk(&dense, opts.nnz_per_col)?;
        let sp = refit_on_support(&ws, w, &sp, opts.lambda)?;
        let recon = ws.matmul(&sp.to_dense())?;
        rel_err.push(w.rel_err(&recon));
        out_wds.push(sp);
    }
    Ok(Factorized { ws, wds: out_wds, rel_err })
}

/// Given a support pattern, refit the non-zero values by least squares per
/// column: restrict `W_S` to the support columns and solve the small system.
fn refit_on_support(ws: &Mat, w: &Mat, sp: &CscFixed, lambda: f32) -> Result<CscFixed> {
    let mut out = sp.clone();
    let k = sp.nnz_per_col;
    for c in 0..sp.cols {
        let support: Vec<usize> = sp.col_entries(c).map(|(r, _)| r).collect();
        // A = ws[:, support] (d_in × k), b = w[:, c]
        let mut a = Mat::zeros(ws.rows, k);
        for (j, &s) in support.iter().enumerate() {
            for i in 0..ws.rows {
                *a.at_mut(i, j) = ws.at(i, s);
            }
        }
        let mut b = Mat::zeros(w.rows, 1);
        for i in 0..w.rows {
            *b.at_mut(i, 0) = w.at(i, c);
        }
        let x = lstsq(&a, &b, lambda)?;
        let s0 = c * k;
        for j in 0..k {
            out.val[s0 + j] = x.at(j, 0);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build synthetic layers that *are* low-rank+sparse so ALS can recover
    /// them: W^l = Ws_true · Wd_true^l.
    fn planted(rng: &mut Rng, d_in: usize, d_out: usize, r: usize, nnz: usize, layers: usize) -> Vec<Mat> {
        let ws = Mat::randn(d_in, r, rng);
        (0..layers)
            .map(|_| {
                let mut wd = Mat::zeros(r, d_out);
                for c in 0..d_out {
                    for row in rng.sample_distinct(r, nnz) {
                        *wd.at_mut(row, c) = rng.normal_f32();
                    }
                }
                ws.matmul(&wd).unwrap()
            })
            .collect()
    }

    #[test]
    fn recovers_planted_factorization() {
        let mut rng = Rng::new(41);
        let layers = planted(&mut rng, 24, 20, 8, 3, 3);
        let f = factorize_joint(
            &layers,
            FactorizeOptions { rank: 8, nnz_per_col: 3, iters: 20, lambda: 1e-5, seed: 1 },
        )
        .unwrap();
        for (l, e) in f.rel_err.iter().enumerate() {
            assert!(*e < 0.25, "layer {l} rel_err {e}");
        }
        for wd in &f.wds {
            wd.check_invariants().unwrap();
            assert_eq!(wd.nnz_per_col, 3);
        }
    }

    #[test]
    fn error_decreases_with_budget() {
        // More NZ per column ⇒ better reconstruction (monotone in capacity).
        let mut rng = Rng::new(42);
        let layers = planted(&mut rng, 20, 16, 10, 6, 2);
        let mut errs = Vec::new();
        for nnz in [2usize, 4, 8] {
            let f = factorize_joint(
                &layers,
                FactorizeOptions { rank: 10, nnz_per_col: nnz, iters: 10, lambda: 1e-5, seed: 2 },
            )
            .unwrap();
            errs.push(f.rel_err.iter().sum::<f64>() / f.rel_err.len() as f64);
        }
        assert!(errs[0] > errs[2], "errs {errs:?}");
    }

    #[test]
    fn shared_ws_is_single_matrix() {
        let mut rng = Rng::new(43);
        let layers = planted(&mut rng, 16, 12, 6, 2, 4);
        let f = factorize_joint(
            &layers,
            FactorizeOptions { rank: 6, nnz_per_col: 2, iters: 8, lambda: 1e-4, seed: 3 },
        )
        .unwrap();
        assert_eq!(f.wds.len(), 4);
        assert_eq!((f.ws.rows, f.ws.cols), (16, 6));
    }
}
