//! Factorized weight representation `W = W_S · W_D` (paper Fig. 23.1.3).
//!
//! `W_S` is dense and shared across layers; each layer's `W_D` is sparse with
//! a **fixed number of non-zeros per column** (the training regularizer
//! enforces this; here the invariant is structural). The main operation is
//! the *sequential* matmul `(X·W_S)·W_D` — chosen over `X·(W_S·W_D)` because
//! the rank `r` is much smaller than the output dim, cutting MACs 1–2.14×
//! versus the unfactorized `X·W`.
//!
//! [`als`] provides a Rust-side alternating-least-squares factorizer (used by
//! tests and `examples/train_factorized.rs`); the production factorizer that
//! feeds the AOT artifacts lives in `python/compile/factorize.py`.

pub mod als;
pub mod linalg;
pub mod sparse;

pub use als::{factorize_joint, FactorizeOptions};
pub use sparse::CscFixed;

use crate::error::Result;
use crate::util::mat::Mat;

/// One factorized weight: shared dense `W_S` (by reference — it belongs to
/// the group) and this layer's sparse `W_D`.
#[derive(Debug, Clone)]
pub struct FactorizedWeight {
    /// Index of the shared group this weight uses.
    pub group: usize,
    pub wd: CscFixed,
}

/// A group of layers sharing one `W_S`.
#[derive(Debug, Clone)]
pub struct SharedWs {
    pub name: String,
    pub ws: Mat, // d_in × r
}

/// MAC counts of the three computing orders for an `m×k` input against a
/// `k×n` weight factorized at rank `r` with `nnz` non-zeros per column.
/// Returns `(seq_macs, fused_macs, dense_macs)` for `(X·W_S)·W_D`,
/// `X·(W_S·W_D)` and `X·W` respectively — the paper's Fig. 23.1.3 argument.
pub fn mac_counts(m: usize, k: usize, n: usize, r: usize, nnz: usize) -> (usize, usize, usize) {
    let seq = m * k * r + m * nnz * n; // X·Ws (dense) then Y·Wd (NZ only)
    let fused = k * r * n + m * k * n; // materialize Ws·Wd, then dense MM
    let dense = m * k * n;
    (seq, fused, dense)
}

/// Verify the factorization reconstructs `w` to within `tol` relative error.
pub fn verify(w: &Mat, ws: &Mat, wd: &CscFixed, tol: f64) -> Result<f64> {
    let recon = ws.matmul(&wd.to_dense())?;
    let err = w.rel_err(&recon);
    if err > tol {
        return Err(crate::error::Error::shape(format!(
            "factorization rel_err {err:.4} > tol {tol}"
        )));
    }
    Ok(err)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_counts_favor_sequential() {
        // BERT-Large FFN-up: X 128×1024, W 1024×4096, r=256, nnz=24.
        let (seq, fused, dense) = mac_counts(128, 1024, 4096, 256, 24);
        assert!(seq < dense, "seq {seq} dense {dense}");
        assert!(seq < fused);
        let ratio = dense as f64 / seq as f64;
        // Paper: 1–2.14× fewer MACs than X·W across models.
        assert!(ratio > 1.0 && ratio < 16.0, "ratio {ratio}");
    }

    #[test]
    fn mac_count_formula() {
        let (seq, fused, dense) = mac_counts(2, 3, 5, 4, 1);
        assert_eq!(dense, 2 * 3 * 5);
        assert_eq!(seq, 2 * 3 * 4 + 2 * 1 * 5);
        assert_eq!(fused, 3 * 4 * 5 + 2 * 3 * 5);
    }
}
