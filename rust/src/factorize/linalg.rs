//! Small dense linear-algebra kernels for the ALS factorizer: Gaussian
//! elimination with partial pivoting and least-squares via normal equations.
//! Sizes here are `rank × rank` (≤ 256), so cubic algorithms are fine.

use crate::error::{Error, Result};
use crate::util::mat::Mat;

/// Solve `A x = b` for square `A` (destructive copy), partial pivoting.
pub fn solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows;
    if a.cols != n || b.len() != n {
        return Err(Error::shape("solve: dimension mismatch".to_string()));
    }
    // Work in f64 for stability.
    let mut m = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            m[i * n + j] = a.at(i, j) as f64;
        }
    }
    let mut x = b.to_vec();
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = m[col * n + col].abs();
        for r in col + 1..n {
            let v = m[r * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return Err(Error::shape(format!("solve: singular at column {col}")));
        }
        if piv != col {
            for j in 0..n {
                m.swap(col * n + j, piv * n + j);
            }
            x.swap(col, piv);
        }
        let d = m[col * n + col];
        for r in col + 1..n {
            let f = m[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                m[r * n + j] -= f * m[col * n + j];
            }
            x[r] -= f * x[col];
        }
    }
    // back substitution
    for col in (0..n).rev() {
        let mut v = x[col];
        for j in col + 1..n {
            v -= m[col * n + j] * x[j];
        }
        x[col] = v / m[col * n + col];
    }
    Ok(x)
}

/// Solve `A X = B` column-wise for square `A`, `B` given as `Mat`.
pub fn solve_mat(a: &Mat, b: &Mat) -> Result<Mat> {
    if a.rows != b.rows {
        return Err(Error::shape("solve_mat: dimension mismatch".to_string()));
    }
    let mut out = Mat::zeros(a.rows, b.cols);
    for c in 0..b.cols {
        let col: Vec<f64> = (0..b.rows).map(|r| b.at(r, c) as f64).collect();
        let x = solve(a, &col)?;
        for r in 0..a.rows {
            *out.at_mut(r, c) = x[r] as f32;
        }
    }
    Ok(out)
}

/// `A·Aᵀ` (Gram matrix over rows), with Tikhonov damping `λI`.
pub fn gram_t(a: &Mat, lambda: f32) -> Mat {
    let mut g = Mat::zeros(a.rows, a.rows);
    for i in 0..a.rows {
        for j in i..a.rows {
            let mut s = 0.0f64;
            for k in 0..a.cols {
                s += a.at(i, k) as f64 * a.at(j, k) as f64;
            }
            *g.at_mut(i, j) = s as f32;
            *g.at_mut(j, i) = s as f32;
        }
        *g.at_mut(i, i) += lambda;
    }
    g
}

/// `AᵀA` (Gram over columns) with damping.
pub fn gram(a: &Mat, lambda: f32) -> Mat {
    let mut g = Mat::zeros(a.cols, a.cols);
    for i in 0..a.cols {
        for j in i..a.cols {
            let mut s = 0.0f64;
            for k in 0..a.rows {
                s += a.at(k, i) as f64 * a.at(k, j) as f64;
            }
            *g.at_mut(i, j) = s as f32;
            *g.at_mut(j, i) = s as f32;
        }
        *g.at_mut(i, i) += lambda;
    }
    g
}

/// Least squares `min_X ‖A X − B‖` via normal equations `(AᵀA)X = AᵀB`.
pub fn lstsq(a: &Mat, b: &Mat, lambda: f32) -> Result<Mat> {
    let ata = gram(a, lambda);
    let atb = a.transpose().matmul(b)?;
    solve_mat(&ata, &atb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solve_identity() {
        let a = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let x = solve(&a, &[3.0, 4.0]).unwrap();
        assert_eq!(x, vec![3.0, 4.0]);
    }

    #[test]
    fn solve_random_consistency() {
        let mut rng = Rng::new(31);
        for _ in 0..20 {
            let n = rng.range(2, 12);
            let a = Mat::randn(n, n, &mut rng);
            let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            // b = A x
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a.at(i, j) as f64 * xs[j]).sum())
                .collect();
            let got = solve(&a, &b).unwrap();
            for (g, e) in got.iter().zip(&xs) {
                assert!((g - e).abs() < 1e-3, "got {g} expect {e}");
            }
        }
    }

    #[test]
    fn solve_rejects_singular() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]).unwrap();
        assert!(solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn lstsq_exact_when_consistent() {
        let mut rng = Rng::new(32);
        let a = Mat::randn(20, 6, &mut rng);
        let x_true = Mat::randn(6, 3, &mut rng);
        let b = a.matmul(&x_true).unwrap();
        let x = lstsq(&a, &b, 0.0).unwrap();
        assert!(x.rel_err(&x_true) < 1e-3, "err {}", x.rel_err(&x_true));
    }

    #[test]
    fn gram_symmetry() {
        let mut rng = Rng::new(33);
        let a = Mat::randn(7, 5, &mut rng);
        let g = gram(&a, 0.1);
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
        let gt = gram_t(&a, 0.0);
        assert_eq!(gt.rows, 7);
    }
}
