//! Pointer-free fixed-NZ-per-column sparse format (paper Fig. 23.1.3).
//!
//! Because the training regularizer fixes the number of non-zeros in every
//! column of `W_D`, the column-pointer array of standard CSC is redundant:
//! column `c`'s entries live at `[c·nnz, (c+1)·nnz)`. Only row indices and
//! values are stored — the "compressed format similar to CSC that does not
//! require storing the column pointer".

use crate::error::{Error, Result};
use crate::util::mat::Mat;

/// Fixed-NZ-per-column sparse matrix, column-major storage.
#[derive(Debug, Clone, PartialEq)]
pub struct CscFixed {
    pub rows: usize,
    pub cols: usize,
    pub nnz_per_col: usize,
    /// Row indices, `cols × nnz_per_col`, ascending within each column.
    pub idx: Vec<u16>,
    /// Values, parallel to `idx`.
    pub val: Vec<f32>,
}

impl CscFixed {
    /// Build from dense by keeping the top-`nnz` magnitude entries per column.
    pub fn from_dense_topk(w: &Mat, nnz_per_col: usize) -> Result<Self> {
        if nnz_per_col == 0 || nnz_per_col > w.rows {
            return Err(Error::shape(format!(
                "nnz_per_col {} not in 1..={}",
                nnz_per_col, w.rows
            )));
        }
        if w.rows > u16::MAX as usize + 1 {
            return Err(Error::shape("CscFixed: rows exceed u16 index range".to_string()));
        }
        let mut idx = Vec::with_capacity(w.cols * nnz_per_col);
        let mut val = Vec::with_capacity(w.cols * nnz_per_col);
        let mut order: Vec<usize> = Vec::with_capacity(w.rows);
        for c in 0..w.cols {
            order.clear();
            order.extend(0..w.rows);
            order.sort_by(|&a, &b| {
                w.at(b, c)
                    .abs()
                    .partial_cmp(&w.at(a, c).abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            let mut keep: Vec<usize> = order[..nnz_per_col].to_vec();
            keep.sort_unstable();
            for &r in &keep {
                idx.push(r as u16);
                val.push(w.at(r, c));
            }
        }
        Ok(CscFixed { rows: w.rows, cols: w.cols, nnz_per_col, idx, val })
    }

    /// Entries of column `c` as `(row, value)` pairs.
    pub fn col_entries(&self, c: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let s = c * self.nnz_per_col;
        self.idx[s..s + self.nnz_per_col]
            .iter()
            .zip(&self.val[s..s + self.nnz_per_col])
            .map(|(&i, &v)| (i as usize, v))
    }

    pub fn nnz(&self) -> usize {
        self.cols * self.nnz_per_col
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for c in 0..self.cols {
            for (r, v) in self.col_entries(c) {
                *m.at_mut(r, c) = v;
            }
        }
        m
    }

    /// `Y · self` where `Y` is `m × rows` dense — the SMM column-product:
    /// for each output column, gather the `nnz` referenced columns of `Y`
    /// and accumulate. This is exactly the chip's relative-addressed load.
    pub fn left_mul(&self, y: &Mat) -> Result<Mat> {
        if y.cols != self.rows {
            return Err(Error::shape(format!(
                "left_mul: {}x{} · sparse {}x{}",
                y.rows, y.cols, self.rows, self.cols
            )));
        }
        let mut out = Mat::zeros(y.rows, self.cols);
        for c in 0..self.cols {
            for (k, v) in self.col_entries(c) {
                for r in 0..y.rows {
                    *out.at_mut(r, c) += y.at(r, k) * v;
                }
            }
        }
        Ok(out)
    }

    /// Apply a row permutation (`new_row = perm_inv[old_row]` given
    /// `perm[new] = old`), keeping columns sorted. Used by the delta-encoding
    /// rearrangement: permuting W_D's rows together with W_S's columns leaves
    /// the product unchanged.
    pub fn permute_rows(&self, perm: &[usize]) -> Result<CscFixed> {
        if perm.len() != self.rows {
            return Err(Error::shape("permute_rows: bad perm length".to_string()));
        }
        // perm[new] = old ⇒ need old→new map.
        let mut old_to_new = vec![usize::MAX; self.rows];
        for (new, &old) in perm.iter().enumerate() {
            if old >= self.rows || old_to_new[old] != usize::MAX {
                return Err(Error::shape("permute_rows: not a permutation".to_string()));
            }
            old_to_new[old] = new;
        }
        let mut out = self.clone();
        let mut scratch: Vec<(u16, f32)> = Vec::with_capacity(self.nnz_per_col);
        for c in 0..self.cols {
            let s = c * self.nnz_per_col;
            scratch.clear();
            for j in s..s + self.nnz_per_col {
                scratch.push((old_to_new[self.idx[j] as usize] as u16, self.val[j]));
            }
            scratch.sort_unstable_by_key(|&(i, _)| i);
            for (j, &(i, v)) in scratch.iter().enumerate() {
                out.idx[s + j] = i;
                out.val[s + j] = v;
            }
        }
        Ok(out)
    }

    /// Structural invariant check: fixed arity, ascending unique indices in
    /// range. Used by property tests and the artifact loader.
    pub fn check_invariants(&self) -> Result<()> {
        if self.idx.len() != self.nnz() || self.val.len() != self.nnz() {
            return Err(Error::shape("CscFixed: storage length mismatch".to_string()));
        }
        for c in 0..self.cols {
            let s = c * self.nnz_per_col;
            let col = &self.idx[s..s + self.nnz_per_col];
            for w in col.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::shape(format!(
                        "CscFixed: col {c} indices not strictly ascending"
                    )));
                }
            }
            if let Some(&last) = col.last() {
                if last as usize >= self.rows {
                    return Err(Error::shape(format!("CscFixed: col {c} index out of range")));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> CscFixed {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for _ in 0..cols {
            let mut rs = rng.sample_distinct(rows, nnz);
            rs.sort_unstable();
            for r in rs {
                idx.push(r as u16);
                val.push(rng.normal_f32());
            }
        }
        CscFixed { rows, cols, nnz_per_col: nnz, idx, val }
    }

    #[test]
    fn topk_keeps_largest() {
        let w = Mat::from_vec(4, 2, vec![0.1, 5.0, 3.0, -0.2, -4.0, 0.3, 0.05, 1.0]).unwrap();
        // col 0: [0.1, 3.0, -4.0, 0.05] → top2 = rows 1(3.0), 2(-4.0)
        let s = CscFixed::from_dense_topk(&w, 2).unwrap();
        s.check_invariants().unwrap();
        let c0: Vec<_> = s.col_entries(0).collect();
        assert_eq!(c0, vec![(1, 3.0), (2, -4.0)]);
        let c1: Vec<_> = s.col_entries(1).collect();
        assert_eq!(c1, vec![(0, 5.0), (3, 1.0)]);
    }

    #[test]
    fn left_mul_matches_dense() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let (m, r, n, nnz) = (
                rng.range(1, 8),
                rng.range(4, 24),
                rng.range(1, 16),
                0, // placeholder
            );
            let nnz = rng.range(1, r.min(8));
            let _ = nnz;
            let sp = random_sparse(&mut rng, r, n, nnz);
            sp.check_invariants().unwrap();
            let y = Mat::randn(m, r, &mut rng);
            let fast = sp.left_mul(&y).unwrap();
            let slow = y.matmul(&sp.to_dense()).unwrap();
            assert!(fast.rel_err(&slow) < 1e-5);
        }
    }

    #[test]
    fn permute_rows_preserves_product() {
        let mut rng = Rng::new(22);
        let r = 16;
        let sp = random_sparse(&mut rng, r, 12, 5);
        let ws = Mat::randn(10, r, &mut rng);
        let mut perm: Vec<usize> = (0..r).collect();
        rng.shuffle(&mut perm);
        // perm[new]=old for Wd rows ⇔ ws columns reordered as ws[:, perm]
        let sp_p = sp.permute_rows(&perm).unwrap();
        sp_p.check_invariants().unwrap();
        let ws_p = ws.permute_cols(&perm).unwrap();
        let a = ws.matmul(&sp.to_dense()).unwrap();
        let b = ws_p.matmul(&sp_p.to_dense()).unwrap();
        assert!(a.rel_err(&b) < 1e-6);
    }

    #[test]
    fn invariant_violations_detected() {
        let mut s = CscFixed {
            rows: 4,
            cols: 1,
            nnz_per_col: 2,
            idx: vec![2, 1],
            val: vec![1.0, 2.0],
        };
        assert!(s.check_invariants().is_err()); // descending
        s.idx = vec![1, 1];
        assert!(s.check_invariants().is_err()); // duplicate
        s.idx = vec![1, 9];
        assert!(s.check_invariants().is_err()); // out of range
        s.idx = vec![1, 3];
        s.check_invariants().unwrap();
    }

    #[test]
    fn density_and_nnz() {
        let mut rng = Rng::new(23);
        let s = random_sparse(&mut rng, 64, 100, 8);
        assert_eq!(s.nnz(), 800);
        assert!((s.density() - 0.125).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_arity() {
        let w = Mat::zeros(4, 4);
        assert!(CscFixed::from_dense_topk(&w, 0).is_err());
        assert!(CscFixed::from_dense_topk(&w, 5).is_err());
    }
}
