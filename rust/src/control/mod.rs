//! SLO-driven control plane: a per-chip runtime DVFS governor plus an
//! SLO-aware admission gate.
//!
//! The governor rides the pool's sampler thread. Each telemetry interval it
//! observes per-interval decode latency percentiles ([`crate::coordinator::
//! metrics::IntervalStats`]), real queue depth per chip, and KV-arena
//! occupancy, and re-points each chip's operating voltage within the fig7
//! table via [`crate::fleet::Chip::repoint`]:
//!
//! * **Boost** one point when a chip's queue is deep (a real, wall-clock
//!   burst signal) or the decode-p95 SLO is breached.
//! * **Drop** one point when the queue is shallow and KV occupancy is low —
//!   but, when an SLO is set, only if the *frequency-ratio projection* of
//!   the observed p95 at the lower point still clears the target with
//!   headroom. Modeled µs/token scales ~1/freq across fig7 points, so
//!   `p95 × (freq_now / freq_lower)` is the expected p95 after the drop;
//!   requiring it under `target × headroom` settles the chip at the
//!   *cheapest compliant* point instead of oscillating around the target.
//!
//! Every accepted re-point bumps the chip's operating-point epoch; the
//! bound worker engine re-costs its plan scope and sim caches before the
//! next priced step (plans are compiled per operating point, so a stale
//! plan would be a correctness bug, not just a perf bug — see
//! `Engine::sync_operating_point`).
//!
//! **Dwell/hysteresis**: a chip re-points at most once per
//! [`GovernorConfig::dwell_us`] window, so an oscillating load signal
//! cannot thrash the plan caches. The admission gate has its own
//! hysteresis: it latches shedding on a p95 breach and releases only once
//! p95 falls to 95% of the target.

use crate::fleet::{Fleet, Repoint};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Per-class service-level objectives the control plane steers against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTarget {
    /// Decode-latency target: interval p95 µs/token must stay at or under
    /// this. Drives both the governor's drop projection and the admission
    /// gate.
    pub decode_p95_us: f64,
    /// Optional prefill end-to-end p95 target, µs (reported, not yet
    /// steered — see ROADMAP follow-ups).
    pub prefill_p95_us: Option<f64>,
}

impl SloTarget {
    /// A decode-only SLO (the common case; `serve --slo-p95-us`).
    pub fn decode(decode_p95_us: f64) -> SloTarget {
        SloTarget { decode_p95_us, prefill_p95_us: None }
    }

    /// Admission-gate update for one telemetry interval: latch shedding on
    /// a p95 breach, release at 95% of the target (hysteresis so the door
    /// doesn't flap at the boundary). Empty intervals leave the gate
    /// unchanged — no tokens is no evidence either way.
    pub fn update_gate(&self, state: &ControlState, tokens: u64, us_p95: f64) {
        if tokens == 0 {
            return;
        }
        if us_p95 > self.decode_p95_us {
            state.set_shedding(true);
        } else if us_p95 <= self.decode_p95_us * 0.95 {
            state.set_shedding(false);
        }
    }
}

/// DVFS-governor tuning. Defaults are deliberately conservative: a 50 ms
/// dwell (≥ several telemetry intervals), boost on a 4-deep queue, drop
/// only when ≤1 request is waiting and the KV arena is under 90% full.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GovernorConfig {
    /// Minimum wall-clock µs between re-points of the same chip.
    pub dwell_us: f64,
    /// Queue depth (waiting prefill + parked + decode streams) at or above
    /// which a chip boosts one operating point.
    pub queue_high: usize,
    /// Queue depth at or below which a chip may drop one operating point.
    pub queue_low: usize,
    /// Drop only if the projected p95 at the lower point stays under
    /// `target × headroom` (fraction in (0, 1]).
    pub headroom: f64,
    /// Never drop while the chip's KV arena occupancy is at or above this
    /// fraction — a full arena means swap storms, not idle capacity.
    pub kv_high: f64,
}

impl Default for GovernorConfig {
    fn default() -> GovernorConfig {
        GovernorConfig {
            dwell_us: 50_000.0,
            queue_high: 4,
            queue_low: 1,
            headroom: 0.9,
            kv_high: 0.9,
        }
    }
}

/// One telemetry interval's worth of observations, as the sampler hands
/// them to [`DvfsGovernor::tick`].
#[derive(Debug, Clone, Copy)]
pub struct GovernorObs<'a> {
    /// Wall-clock µs (recorder/sampler clock) at the tick.
    pub t_us: f64,
    /// Decode tokens completed in the interval (0 ⇒ percentiles are
    /// meaningless and the interval is treated as idle).
    pub tokens: u64,
    /// Interval decode p50 µs/token.
    pub us_p50: f64,
    /// Interval decode p95 µs/token.
    pub us_p95: f64,
    /// Per-chip queue depth (waiting + parked + live decode streams).
    pub queue_depths: &'a [usize],
    /// Per-chip KV arena occupancy fraction in [0, 1].
    pub kv_frac: &'a [f64],
}

/// The per-pool DVFS governor: owns per-chip dwell state, decides at most
/// one single-step re-point per chip per tick, and applies it through
/// [`crate::fleet::Chip::repoint`].
#[derive(Debug)]
pub struct DvfsGovernor {
    cfg: GovernorConfig,
    slo: Option<SloTarget>,
    /// Last accepted re-point per chip, sampler-clock µs (`-inf` ⇒ never;
    /// the first tick may re-point immediately).
    last_repoint_us: Vec<f64>,
}

impl DvfsGovernor {
    pub fn new(cfg: GovernorConfig, slo: Option<SloTarget>, n_chips: usize) -> DvfsGovernor {
        DvfsGovernor { cfg, slo, last_repoint_us: vec![f64::NEG_INFINITY; n_chips] }
    }

    pub fn config(&self) -> &GovernorConfig {
        &self.cfg
    }

    /// One governor tick: for each chip (skipping any still in dwell),
    /// boost on burst/breach, else consider a projected-safe drop. Returns
    /// the accepted re-points; the caller records the spans and re-costing
    /// is the bound engine's obligation via the epoch bump.
    pub fn tick(&mut self, fleet: &Fleet, obs: &GovernorObs) -> Vec<(usize, Repoint)> {
        let mut out = Vec::new();
        for i in 0..fleet.n_chips() {
            if obs.t_us - self.last_repoint_us[i] < self.cfg.dwell_us {
                continue;
            }
            let chip = fleet.chip(i);
            let pts = chip.operating_points();
            let cur = chip.current_point();
            let queue = obs.queue_depths.get(i).copied().unwrap_or(0);
            let kv = obs.kv_frac.get(i).copied().unwrap_or(0.0);
            let breach = self
                .slo
                .map(|s| obs.tokens > 0 && obs.us_p95 > s.decode_p95_us)
                .unwrap_or(false);
            let target_vdd = if queue >= self.cfg.queue_high || breach {
                // Boost: first table point strictly above the current one.
                pts.iter().find(|p| p.vdd > cur.vdd + 1e-9).map(|p| p.vdd)
            } else if queue <= self.cfg.queue_low && kv < self.cfg.kv_high {
                // Drop: highest table point strictly below the current one,
                // if the frequency-ratio projection clears the SLO.
                pts.iter().rev().find(|p| p.vdd < cur.vdd - 1e-9).and_then(|lower| {
                    let safe = match self.slo {
                        None => true,
                        Some(s) => {
                            obs.tokens == 0
                                || obs.us_p95 * (cur.freq_mhz / lower.freq_mhz)
                                    < s.decode_p95_us * self.cfg.headroom
                        }
                    };
                    safe.then_some(lower.vdd)
                })
            } else {
                None
            };
            if let Some(vdd) = target_vdd {
                if let Some(rp) = chip.repoint(vdd) {
                    self.last_repoint_us[i] = obs.t_us;
                    out.push((i, rp));
                }
            }
        }
        out
    }
}

/// Shared control-plane state: the admission door reads the shed latch on
/// every generate submit; the sampler and report readers tally decisions.
#[derive(Debug, Default)]
pub struct ControlState {
    shed_generate: AtomicBool,
    slo_door_sheds: AtomicU64,
    dvfs_repoints: AtomicU64,
}

impl ControlState {
    pub fn new() -> ControlState {
        ControlState::default()
    }

    /// True while the door sheds generate traffic (SLO breach latched).
    pub fn shedding(&self) -> bool {
        self.shed_generate.load(Ordering::SeqCst)
    }

    pub fn set_shedding(&self, on: bool) {
        self.shed_generate.store(on, Ordering::SeqCst);
    }

    /// Generate requests rejected by the SLO gate.
    pub fn door_sheds(&self) -> u64 {
        self.slo_door_sheds.load(Ordering::SeqCst)
    }

    pub fn note_door_shed(&self) {
        self.slo_door_sheds.fetch_add(1, Ordering::SeqCst);
    }

    /// Accepted governor re-points, all chips.
    pub fn repoints(&self) -> u64 {
        self.dvfs_repoints.load(Ordering::SeqCst)
    }

    pub fn note_repoint(&self) {
        self.dvfs_repoints.fetch_add(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HwConfig, ModelConfig};
    use crate::fleet::ChipSpec;
    use crate::kv::KvQuant;

    fn fleet(n: usize, vdd: f64) -> Fleet {
        let specs = (0..n).map(|i| ChipSpec::general(format!("c{i}"), vdd)).collect();
        Fleet::build(specs, &HwConfig::default(), &ModelConfig::tiny(), KvQuant::Fp16).unwrap()
    }

    fn obs<'a>(
        t_us: f64,
        tokens: u64,
        us_p95: f64,
        queues: &'a [usize],
        kv: &'a [f64],
    ) -> GovernorObs<'a> {
        GovernorObs { t_us, tokens, us_p50: us_p95, us_p95, queue_depths: queues, kv_frac: kv }
    }

    #[test]
    fn boosts_one_point_on_queue_burst() {
        let f = fleet(1, 0.65);
        let mut gov = DvfsGovernor::new(GovernorConfig::default(), None, 1);
        let reps = gov.tick(&f, &obs(0.0, 0, 0.0, &[8], &[0.1]));
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].0, 0);
        assert_eq!(reps[0].1.to_vdd, 0.75, "one step up the fig7 table");
        assert_eq!(f.chip(0).current_vdd(), 0.75);
        assert_eq!(f.chip(0).op_epoch(), 1);
    }

    #[test]
    fn drops_one_point_when_idle_and_kv_is_cool() {
        let f = fleet(1, 0.65);
        let mut gov = DvfsGovernor::new(GovernorConfig::default(), None, 1);
        let reps = gov.tick(&f, &obs(0.0, 0, 0.0, &[0], &[0.1]));
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].1.to_vdd, 0.55);
        // High KV occupancy blocks the drop even when the queue is empty.
        let f2 = fleet(1, 0.65);
        let mut gov2 = DvfsGovernor::new(GovernorConfig::default(), None, 1);
        assert!(gov2.tick(&f2, &obs(0.0, 0, 0.0, &[0], &[0.95])).is_empty());
    }

    #[test]
    fn slo_projection_gates_the_drop() {
        // At 0.65 V (250 MHz) with p95 = 100 µs, the 0.55 V (150 MHz)
        // projection is 100 × 250/150 ≈ 167 µs. A 200 µs target with 0.9
        // headroom (threshold 180) accepts the drop; a 170 µs target
        // (threshold 153) rejects it and the chip holds its point.
        let f = fleet(1, 0.65);
        let mut loose =
            DvfsGovernor::new(GovernorConfig::default(), Some(SloTarget::decode(200.0)), 1);
        let reps = loose.tick(&f, &obs(0.0, 50, 100.0, &[0], &[0.0]));
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].1.to_vdd, 0.55);

        let f2 = fleet(1, 0.65);
        let mut tight =
            DvfsGovernor::new(GovernorConfig::default(), Some(SloTarget::decode(170.0)), 1);
        assert!(tight.tick(&f2, &obs(0.0, 50, 100.0, &[0], &[0.0])).is_empty());
        assert_eq!(f2.chip(0).op_epoch(), 0, "no re-point, no re-cost obligation");
    }

    #[test]
    fn slo_breach_boosts_even_with_shallow_queue() {
        let f = fleet(1, 0.65);
        let mut gov =
            DvfsGovernor::new(GovernorConfig::default(), Some(SloTarget::decode(50.0)), 1);
        let reps = gov.tick(&f, &obs(0.0, 50, 80.0, &[0], &[0.0]));
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].1.to_vdd, 0.75);
    }

    #[test]
    fn dwell_caps_repoints_at_one_per_window_under_oscillating_load() {
        // Alternate burst/idle observations every 1 ms against a 50 ms
        // dwell: without hysteresis the chip would flap every tick; with
        // it, each 50 ms window admits at most one re-point.
        let f = fleet(1, 0.65);
        let mut gov = DvfsGovernor::new(GovernorConfig::default(), None, 1);
        let mut repoints_at = Vec::new();
        for tick in 0..200u64 {
            let t_us = tick as f64 * 1_000.0;
            let (q, kv) = if tick % 2 == 0 { (8, 0.1) } else { (0, 0.1) };
            for (chip, rp) in gov.tick(&f, &obs(t_us, 0, 0.0, &[q], &[kv])) {
                assert_eq!(chip, 0);
                assert!(!rp.clamped);
                repoints_at.push(t_us);
            }
        }
        assert!(!repoints_at.is_empty());
        for w in repoints_at.windows(2) {
            assert!(
                w[1] - w[0] >= 50_000.0,
                "re-points {} µs apart violate the 50 ms dwell",
                w[1] - w[0]
            );
        }
        // Epoch count equals accepted re-points: every one obligates
        // exactly one plan-scope re-cost.
        assert_eq!(f.chip(0).op_epoch(), repoints_at.len() as u64);
    }

    #[test]
    fn edge_points_saturate() {
        let f = fleet(1, 0.85);
        let mut gov = DvfsGovernor::new(GovernorConfig::default(), None, 1);
        assert!(gov.tick(&f, &obs(0.0, 0, 0.0, &[8], &[0.1])).is_empty(), "no point above max");
        let f2 = fleet(1, 0.45);
        let mut gov2 = DvfsGovernor::new(GovernorConfig::default(), None, 1);
        assert!(gov2.tick(&f2, &obs(0.0, 0, 0.0, &[0], &[0.1])).is_empty(), "no point below min");
    }

    #[test]
    fn gate_latches_on_breach_and_releases_with_hysteresis() {
        let slo = SloTarget::decode(100.0);
        let st = ControlState::new();
        assert!(!st.shedding());
        slo.update_gate(&st, 10, 150.0);
        assert!(st.shedding(), "breach latches the gate");
        // In the hysteresis band (95..=100): stays latched.
        slo.update_gate(&st, 10, 98.0);
        assert!(st.shedding());
        // Empty interval: no evidence, no change.
        slo.update_gate(&st, 0, 0.0);
        assert!(st.shedding());
        slo.update_gate(&st, 10, 90.0);
        assert!(!st.shedding(), "releases at 95% of target");
        st.note_door_shed();
        st.note_repoint();
        assert_eq!(st.door_sheds(), 1);
        assert_eq!(st.repoints(), 1);
    }
}
