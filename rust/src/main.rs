//! `trex` — CLI for the T-REX serving stack and simulator.
//!
//! Subcommands (hand-rolled parsing; clap is not vendored offline):
//!   trex sim   --model <preset> [--seq N] [--batch N] [--vdd V] [--no-trf]
//!   trex serve --requests N [--workers N] [--queue-depth N] [--max-inflight N]
//!              [--no-affinity] [--artifacts DIR] [--perf-model <preset>]
//!              [--fleet FILE]            # heterogeneous chip catalog (JSON); one worker per chip
//!              [--generate N]            # decode N tokens per request
//!              [--kv-quant fp16|int8|int4] [--kv-pages N] [--kv-bucket N]
//!              [--prefill-chunk N]       # phases per prefill chunk (0 = whole pass)
//!              [--decode-max-wait-us N]  # decode coalescing window
//!              [--decode-priority]       # near-done streams drain first
//!              [--trace FILE] [--speed F]  # open-loop replay of a request trace
//!              [--trace-out FILE]        # Chrome trace_event export (Perfetto)
//!              [--spans-out FILE]        # span JSONL export
//!              [--telemetry-out FILE]    # time-series snapshot JSONL
//!              [--shed-storm-threshold N] # anomaly-dump on shed storms
//!              [--slo-p95-us F]          # decode-p95 SLO target (gates generate admission)
//!              [--dvfs]                  # runtime DVFS governor (requires --fleet)
//!              [--dvfs-dwell-ms N]       # min ms between re-points of one chip (default 50)
//!   trex fuzz  [--iters N] [--seed S] [--progress-every N] [--dump-dir DIR]
//!                                        # seeded scenario fuzzer (scheduler invariants)
//!   trex inspect --trace FILE [--top N] [--json]
//!                                        # per-phase µs/µJ breakdown of an exported trace
//!   trex report --model <preset>         # compression report (Fig 23.1.3)
//!   trex selftest [--artifacts DIR]      # PJRT vs jax check vectors
//!   trex workloads                       # list presets

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;
use trex::config::{HwConfig, ModelConfig, WORKLOADS};
use trex::control::{GovernorConfig, SloTarget};
use trex::coordinator::{
    default_workers, BatcherConfig, DecodePolicy, Engine, EngineConfig, PoolConfig, Server,
    TraceGenerator,
};
use trex::fleet::{ChipSpec, Fleet};
use trex::kv::{KvArenaConfig, KvManager, KvQuant};
use trex::model::build_program;
use trex::obs::{
    chrome_trace, dump_anomaly, parse_trace, render_summary, spans_jsonl, summarize,
    FlightRecorder, TelemetryConfig, DEFAULT_LANE_CAPACITY,
};
use trex::runtime::{artifacts, ArtifactSet, PjrtRuntime};
use trex::sim::{batch_class, simulate, SimOptions};
use trex::workload::{replay, run_fuzz, FuzzConfig, ReplayConfig, Trace};

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> CliResult {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "sim" => cmd_sim(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "fuzz" => cmd_fuzz(&args[1..]),
        "inspect" => cmd_inspect(&args[1..]),
        "report" => cmd_report(&args[1..]),
        "selftest" => cmd_selftest(&args[1..]),
        "workloads" => {
            for w in WORKLOADS {
                let m = ModelConfig::preset(w)?;
                println!(
                    "{w:12} {} enc={} dec={} d={} ff={} r={} nz/col={}",
                    m.arch.name(),
                    m.enc_layers,
                    m.dec_layers,
                    m.d_model,
                    m.d_ff,
                    m.rank,
                    m.nnz_per_col
                );
            }
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: trex <sim|serve|fuzz|inspect|report|selftest|workloads> [options]\n\
                 \n  sim      --model <preset> [--seq N] [--batch 1|2|4] [--vdd V] [--no-trf] [--no-prefetch]\
                 \n  serve    --requests N [--workers N] [--queue-depth N] [--max-inflight N]\
                 \n           [--no-affinity] [--artifacts DIR] [--perf-model <preset>]\
                 \n           [--fleet FILE]  (heterogeneous chip catalog, JSON:\
                 \n            {{\"chips\":[{{\"id\":\"d0\",\"role\":\"decode\",\"vdd\":0.45}},...]}};\
                 \n            binds one worker per chip, per-chip KV arenas + placement)\
                 \n           [--generate N]  (decode N tokens per request; perf-model defaults to s2t-small)\
                 \n           [--kv-quant fp16|int8|int4] [--kv-pages N]  (KV arena precision / page budget)\
                 \n           [--kv-bucket N]  (depth-bucketed decode grouping, 0 = greedy)\
                 \n           [--prefill-chunk N]  (phases per prefill chunk, 0 = monolithic)\
                 \n           [--decode-max-wait-us N] [--decode-priority]  (coalescing / near-done-first)\
                 \n           [--trace FILE] [--speed F]  (open-loop replay of a request-trace file;\
                 \n            submits on the trace clock — rejections shed, no retry; --speed 2 = 2x faster)\
                 \n           [--trace-out FILE]  (flight-recorder export, Chrome trace_event / Perfetto)\
                 \n           [--spans-out FILE]  (flight-recorder export, span JSONL)\
                 \n           [--telemetry-out FILE]  (time-series snapshot JSONL, 10ms sampling)\
                 \n           [--shed-storm-threshold N]  (dump the recorder when N sheds hit one interval)\
                 \n           [--slo-p95-us F]  (decode-p95 SLO target, µs/token: the door sheds\
                 \n            generate traffic while the interval p95 breaches it)\
                 \n           [--dvfs] [--dvfs-dwell-ms N]  (runtime DVFS governor, requires --fleet:\
                 \n            re-points each chip within the fig7 table — boost on bursts/breach,\
                 \n            drop to the cheapest SLO-compliant point when queues are shallow)\
                 \n  fuzz     [--iters N] [--seed S] [--progress-every N] [--dump-dir DIR]\
                 \n           (seeded scenario fuzzer: random pool configs x request schedules,\
                 \n            checks conservation / kv-leak / token-ordering invariants;\
                 \n            a failure prints the seed — replay: fuzz --seed S --iters 1 —\
                 \n            and writes a flight-recorder dump next to it)\
                 \n  inspect  --trace FILE [--top N] [--json]\
                 \n           (summarize an exported trace: per-phase µs/µJ/EMA breakdown,\
                 \n            top-K slowest requests, shed timeline)\
                 \n  report   --model <preset>\
                 \n  selftest [--artifacts DIR]"
            );
            Ok(())
        }
    }
}

fn cmd_sim(args: &[String]) -> CliResult {
    let hw = HwConfig::default();
    let name = arg_value(args, "--model").unwrap_or_else(|| "bert-large".to_string());
    let m = ModelConfig::preset(&name)?;
    let seq: usize = arg_value(args, "--seq")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(m.max_seq.min(m.mean_input_len as usize));
    let batch: usize = arg_value(args, "--batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| batch_class(seq, hw.max_seq).map(|c| c.batch()).unwrap_or(1));
    let mut opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
    if let Some(v) = arg_value(args, "--vdd") {
        opts.point = hw.point_at_vdd(v.parse()?);
    }
    if args.iter().any(|a| a == "--no-trf") {
        opts.trf = false;
    }
    if args.iter().any(|a| a == "--no-prefetch") {
        opts.prefetch = false;
    }
    let prog = build_program(&m, seq, batch);
    let stats = simulate(&hw, &prog, &opts);
    println!("{}", stats.to_json(&hw).to_string_pretty());
    Ok(())
}

fn cmd_serve(args: &[String]) -> CliResult {
    let n: usize = arg_value(args, "--requests").map(|s| s.parse()).transpose()?.unwrap_or(100);
    let workers: usize = arg_value(args, "--workers")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(default_workers);
    let queue_depth: usize =
        arg_value(args, "--queue-depth").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let max_inflight: usize =
        arg_value(args, "--max-inflight").map(|s| s.parse()).transpose()?.unwrap_or(4096);
    let affinity = !args.iter().any(|a| a == "--no-affinity");
    let generate: usize =
        arg_value(args, "--generate").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let kv_quant =
        KvQuant::parse(&arg_value(args, "--kv-quant").unwrap_or_else(|| "fp16".to_string()))?;
    let kv_pages: Option<usize> =
        arg_value(args, "--kv-pages").map(|s| s.parse()).transpose()?;
    let kv_bucket: usize =
        arg_value(args, "--kv-bucket").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let decode_policy = if kv_bucket > 0 {
        DecodePolicy::DepthBucketed { bucket: kv_bucket }
    } else {
        DecodePolicy::Greedy
    };
    // Scheduler knobs: chunked prefill (phases per chunk; 0 = monolithic),
    // decode coalescing window, near-done-first decode priority.
    let prefill_chunk: usize =
        arg_value(args, "--prefill-chunk").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let decode_max_wait_us: u64 =
        arg_value(args, "--decode-max-wait-us").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let decode_priority = args.iter().any(|a| a == "--decode-priority");
    // Open-loop trace replay: parse up front so a malformed file fails
    // with its line-numbered error before any pool spins up.
    let trace = match arg_value(args, "--trace") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("reading trace {path}: {e}"))?;
            Some(Trace::parse(&text)?)
        }
        None => None,
    };
    let speed: f64 = arg_value(args, "--speed").map(|s| s.parse()).transpose()?.unwrap_or(1.0);
    // Observability: span tracing (flight recorder + exporters) and the
    // time-series sampler. Both off unless asked for — the disabled hot
    // path is a branch on `None` (gated by the hotpath_micro bench).
    let trace_out = arg_value(args, "--trace-out").map(std::path::PathBuf::from);
    let spans_out = arg_value(args, "--spans-out").map(std::path::PathBuf::from);
    let telemetry_out = arg_value(args, "--telemetry-out").map(std::path::PathBuf::from);
    let shed_storm: u64 = arg_value(args, "--shed-storm-threshold")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    // SLO-driven control plane: a decode-p95 target gates generate
    // admission; --dvfs turns on the runtime governor (per-chip operating
    // points — meaningless without a fleet, rejected below).
    let slo_p95_us: Option<f64> =
        arg_value(args, "--slo-p95-us").map(|s| s.parse()).transpose()?;
    let dvfs = args.iter().any(|a| a == "--dvfs");
    let dvfs_dwell_ms: u64 =
        arg_value(args, "--dvfs-dwell-ms").map(|s| s.parse()).transpose()?.unwrap_or(50);
    let dir = arg_value(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_dir);
    // Decode mode defaults to the paper's autoregressive workload (fairseq-
    // S2T): the fat encoder-only presets can't keep a useful KV prefix
    // resident in the 4 MiB GB, so their decode caps clamp generation hard.
    let trace_generates =
        trace.as_ref().is_some_and(|t| t.records.iter().any(|r| r.gen_len > 0));
    let default_perf =
        if generate > 0 || trace_generates { "s2t-small" } else { "bert-large" };
    let perf_name = arg_value(args, "--perf-model").unwrap_or_else(|| default_perf.to_string());
    let perf_model = ModelConfig::preset(&perf_name)?;

    // Geometry from the AOT manifest when it exists (PJRT numerics), else
    // the dependency-free deterministic reference backend on the tiny plane.
    let manifest = trex::util::json::Json::from_file(dir.join("manifest.json")).ok();
    let use_pjrt = manifest.is_some() && cfg!(feature = "pjrt");
    let hw = HwConfig::default();
    // Heterogeneous fleet: a JSON chip catalog binds each worker to its own
    // modeled chip (role + operating point + GB/KV budget). Parsed up front
    // so a malformed catalog fails with its chip-indexed error before any
    // pool spins up; the fleet overrides --workers (one worker per chip)
    // and the pool-wide KV arena (one arena per chip).
    let fleet = match arg_value(args, "--fleet") {
        Some(path) => {
            let specs = ChipSpec::catalog_from_file(&path)?;
            Some(Arc::new(Fleet::build(specs, &hw, &perf_model, kv_quant)?))
        }
        None => None,
    };
    let workers = match &fleet {
        Some(f) => f.n_chips(),
        None => workers,
    };
    if dvfs && fleet.is_none() {
        return Err("--dvfs requires --fleet: the governor re-points per-chip operating \
                    points, and only a fleet carries runtime-re-pointable chips"
            .into());
    }
    if (generate > 0 || trace_generates) && use_pjrt {
        // Decode steps run 1–4-row planes; the AOT executables are
        // fixed-shape, so every step would fail and shed its group. Refuse
        // up front instead of timing out mid-run (AOT decode artifacts are
        // a ROADMAP item).
        return Err("serve --generate requires the reference backend: fixed-shape AOT \
                    artifacts cannot run single-token decode planes yet"
            .into());
    }
    let (d_model, max_seq) = match &manifest {
        Some(m) => (
            m.get("model")?.get("d_model")?.as_usize()?,
            m.get("model")?.get("max_seq")?.as_usize()?,
        ),
        None => (artifacts::TINY_D_MODEL, artifacts::TINY_MAX_SEQ),
    };
    println!(
        "serving with {workers} workers over the {} backend (plane {max_seq}×{d_model})",
        if use_pjrt { "PJRT" } else { "reference" }
    );
    if let Some(f) = &fleet {
        let chips: Vec<String> = f
            .chips
            .iter()
            .map(|c| format!("{}:{}@{:.2}V", c.spec.id, c.spec.role.name(), c.spec.vdd))
            .collect();
        println!("fleet: {} chips [{}]", f.n_chips(), chips.join(", "));
    }

    let dir2 = dir.clone();
    let pm = perf_model.clone();
    // Pool-wide KV arena: admission bounds concurrent generate streams by
    // projected arena bytes, and every worker's engine shares the manager
    // (residency, eviction and swap-in charging are aggregate). A fleet
    // run carries one arena per chip instead (built inside the Fleet).
    let kv_mgr = match &fleet {
        Some(_) => None,
        None => Some(Arc::new(KvManager::new(
            &hw,
            &perf_model,
            KvArenaConfig::for_pool(&hw, &perf_model, kv_quant, kv_pages),
        ))),
    };
    let recorder = if trace_out.is_some() || spans_out.is_some() {
        Some(Arc::new(FlightRecorder::for_pool(workers, DEFAULT_LANE_CAPACITY)))
    } else {
        None
    };
    // Anomaly dumps land next to whichever export the run asked for.
    let anomaly_dump = trace_out
        .as_ref()
        .or(spans_out.as_ref())
        .map(|p| p.with_extension("anomaly.jsonl"));
    let telemetry_cfg = if telemetry_out.is_some() || shed_storm > 0 {
        Some(TelemetryConfig {
            out: telemetry_out.clone(),
            shed_storm_threshold: shed_storm,
            anomaly_dump: anomaly_dump.clone(),
            ..TelemetryConfig::default()
        })
    } else {
        None
    };
    let pool = PoolConfig {
        workers,
        queue_depth,
        max_inflight,
        affinity,
        decode: decode_policy,
        decode_max_wait: Duration::from_micros(decode_max_wait_us),
        decode_priority,
        prefill_chunk,
        kv: kv_mgr,
        fleet: fleet.clone(),
        // Replays audit conservation after the drain; the steady closed-loop
        // path keeps the ledger (unbounded per-request memory) off.
        lifecycle_ledger: trace.is_some(),
        recorder: recorder.clone(),
        telemetry: telemetry_cfg,
        slo: slo_p95_us.map(SloTarget::decode),
        governor: dvfs.then(|| GovernorConfig {
            dwell_us: dvfs_dwell_ms as f64 * 1e3,
            ..GovernorConfig::default()
        }),
        batcher: BatcherConfig { max_seq, max_wait: Duration::from_millis(2) },
    };
    let handle = Server::start_pool(
        move |ctx| {
            let set = if use_pjrt {
                let rt = PjrtRuntime::cpu()?;
                ArtifactSet::load(&rt, &dir2)?
            } else {
                ArtifactSet::reference(artifacts::TINY_MODEL, d_model, max_seq)?
            };
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw.clone(),
                    perf_model: pm.clone(),
                    self_test: ctx.worker == 0,
                    kv_quant,
                    kv_pages,
                },
                ctx,
            )
        },
        pool,
    );

    if let Some(trace) = trace {
        // Open-loop replay: submit on the trace clock, no retries — the
        // pool's overload machinery (door shedding, bounded queues) is the
        // thing under measurement.
        println!(
            "replaying {} requests over {:.1} ms of trace clock at {speed}x",
            trace.len(),
            trace.span_us() as f64 / 1e3
        );
        let stats = replay(&handle, &trace, &ReplayConfig::new(d_model).at_speed(speed));
        println!("{}", stats.to_json().to_string_pretty());
        // WHEN the sheds happened, not just how many: door sheds bucketed
        // over the run next to the post-admission ones.
        let timeline = stats.shed_timeline(20);
        if !timeline.is_empty() {
            println!(
                "shed timeline ({} at the door, {} post-admission):",
                timeline.total_door(),
                timeline.total_late()
            );
            print!("{}", timeline.render());
        }
        // Audit AFTER shutdown: its drain finishes whatever the replay's
        // settle window left in flight, so "open" means lost, not late.
        let metrics = Arc::clone(&handle.metrics);
        let report = handle.shutdown()?;
        if let Some(audit) = metrics.ledger_audit() {
            println!(
                "conservation: admitted={} completed={} shed={} open={} conserved={}",
                audit.admitted,
                audit.completed,
                audit.shed,
                audit.open.len(),
                audit.conserved()
            );
            if !audit.conserved() {
                // A conservation violation is exactly what the flight
                // recorder exists for: dump its final events next to the
                // trace export.
                if let (Some(rec), Some(path)) = (&recorder, &anomaly_dump) {
                    let mut details = audit.violations.clone();
                    if !audit.open.is_empty() {
                        details.push(format!("open (never-terminal) requests: {:?}", audit.open));
                    }
                    let n = dump_anomaly(rec, path, &details)?;
                    println!("anomaly dump: {n} events -> {}", path.display());
                }
            }
        }
        println!("{}", report.json().to_string_pretty());
        export_traces(&recorder, workers, fleet.as_deref(), &trace_out, &spans_out)?;
        return Ok(());
    }

    let mut gen =
        TraceGenerator::for_model(&perf_model, max_seq, d_model, 1).with_generate(generate);
    let mut got = 0usize;
    for _ in 0..n {
        let mut req = gen.next();
        // Backpressure-aware client: on rejection, drain a response, retry.
        // A disconnected response channel means every worker died — bail
        // instead of spinning on a dead pool.
        loop {
            match handle.try_submit(req) {
                Ok(()) => break,
                Err((r, e)) => {
                    req = r;
                    match handle.responses.recv_timeout(Duration::from_millis(50)) {
                        Ok(_) => got += 1,
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => return Err(e.into()),
                    }
                }
            }
        }
    }
    while got < n {
        handle.responses.recv_timeout(Duration::from_secs(30))?;
        got += 1;
    }
    if generate > 0 {
        // Every token streamed before its request's final response; the
        // channel already holds them all.
        let streamed = handle.tokens.try_iter().count();
        println!("streamed {streamed} decode tokens across {n} requests");
    }
    let report = handle.shutdown()?;
    println!("{}", report.json().to_string_pretty());
    export_traces(&recorder, workers, fleet.as_deref(), &trace_out, &spans_out)?;
    Ok(())
}

/// Write the flight recorder's snapshot to whichever export formats the
/// run asked for (no-op when tracing was off). Fleet runs export the
/// per-chip process-group layout (one Perfetto process per chip).
fn export_traces(
    recorder: &Option<Arc<FlightRecorder>>,
    workers: usize,
    fleet: Option<&Fleet>,
    trace_out: &Option<std::path::PathBuf>,
    spans_out: &Option<std::path::PathBuf>,
) -> CliResult {
    let Some(rec) = recorder else {
        return Ok(());
    };
    let events = rec.snapshot();
    if let Some(p) = trace_out {
        let doc = match fleet {
            Some(f) => {
                let ids: Vec<String> = f.chips.iter().map(|c| c.spec.id.clone()).collect();
                trex::obs::chrome_trace_fleet(&events, &ids)
            }
            None => chrome_trace(&events, workers),
        };
        doc.to_file(p)?;
        println!(
            "wrote Chrome trace ({} events, open in Perfetto / chrome://tracing): {}",
            events.len(),
            p.display()
        );
    }
    if let Some(p) = spans_out {
        std::fs::write(p, spans_jsonl(&events))?;
        println!("wrote span JSONL ({} events): {}", events.len(), p.display());
    }
    Ok(())
}

/// Seeded scenario fuzzer (see `trex::workload::fuzz`). Exit code is the
/// CI contract: 0 with every invariant held, nonzero with the failing
/// scenario seed printed — locally reproducible with
/// `cargo run --release -- fuzz --seed <seed> --iters 1`.
fn cmd_fuzz(args: &[String]) -> CliResult {
    let iters: u64 = arg_value(args, "--iters").map(|s| s.parse()).transpose()?.unwrap_or(200);
    let seed: u64 =
        arg_value(args, "--seed").map(|s| s.parse()).transpose()?.unwrap_or(0xC0FFEE);
    let progress_every: u64 =
        arg_value(args, "--progress-every").map(|s| s.parse()).transpose()?.unwrap_or(50);
    let dump_dir = arg_value(args, "--dump-dir").map(std::path::PathBuf::from);
    let summary = run_fuzz(&FuzzConfig { seed, iters, progress_every, dump_dir });
    match summary.failure {
        None => {
            println!(
                "fuzz ok: {} scenarios from base seed {seed}, scheduler invariants held \
                 (conservation, kv residual, token ordering)",
                summary.iters_run
            );
            Ok(())
        }
        Some(f) => {
            // GitHub Actions annotation: the failing seed lands on the run
            // summary so any CI failure replays locally with one command.
            if std::env::var_os("GITHUB_ACTIONS").is_some() {
                println!(
                    "::error::fuzz seed {} violated scheduler invariants — reproduce: \
                     cargo run --release -- fuzz --seed {} --iters 1",
                    f.seed, f.seed
                );
            }
            eprint!("{}", f.render());
            Err(format!("fuzz failed: scenario seed {}", f.seed).into())
        }
    }
}

/// Summarize an exported trace (Chrome trace_event or span JSONL):
/// per-phase µs/µJ/EMA breakdown, top-K slowest requests by e2e latency,
/// and the shed timeline.
fn cmd_inspect(args: &[String]) -> CliResult {
    let path = arg_value(args, "--trace")
        .ok_or("inspect requires --trace FILE (a --trace-out or --spans-out export)")?;
    let topk: usize = arg_value(args, "--top").map(|s| s.parse()).transpose()?.unwrap_or(5);
    let text = std::fs::read_to_string(&path).map_err(|e| format!("reading {path}: {e}"))?;
    let events = parse_trace(&text).map_err(|e| format!("parsing {path}: {e}"))?;
    let summary = summarize(&events, topk);
    if args.iter().any(|a| a == "--json") {
        println!("{}", summary.to_string_pretty());
    } else {
        print!("{}", render_summary(&summary));
    }
    Ok(())
}

fn cmd_report(args: &[String]) -> CliResult {
    let name = arg_value(args, "--model").unwrap_or_else(|| "bert-large".to_string());
    let m = ModelConfig::preset(&name)?;
    let r = trex::compress::CompressionReport::analytic(&m);
    println!("{}", r.to_json().to_string_pretty());
    Ok(())
}

fn cmd_selftest(args: &[String]) -> CliResult {
    let dir = arg_value(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_dir);
    if !dir.join("manifest.json").exists() {
        let set = ArtifactSet::reference_tiny()?;
        set.self_test()?;
        println!(
            "no artifacts at {} — reference backend self-test OK ({} classes)",
            dir.display(),
            set.entries.len()
        );
        return Ok(());
    }
    let rt = PjrtRuntime::cpu()?;
    let set = ArtifactSet::load(&rt, &dir)?;
    set.self_test()?;
    println!("self-test OK: {} artifacts verified against jax check vectors", set.entries.len());
    Ok(())
}
