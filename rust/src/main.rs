//! `trex` — CLI for the T-REX serving stack and simulator.
//!
//! Subcommands (hand-rolled parsing; clap is not vendored offline):
//!   trex sim   --model <preset> [--seq N] [--batch N] [--vdd V] [--no-trf]
//!   trex serve --requests N [--artifacts DIR] [--perf-model <preset>]
//!   trex report --model <preset>         # compression report (Fig 23.1.3)
//!   trex selftest [--artifacts DIR]      # PJRT vs jax check vectors
//!   trex workloads                       # list presets

use std::time::Duration;
use trex::config::{HwConfig, ModelConfig, WORKLOADS};
use trex::coordinator::{BatcherConfig, Engine, EngineConfig, Server, TraceGenerator};
use trex::model::build_program;
use trex::runtime::{artifacts, ArtifactSet, PjrtRuntime};
use trex::sim::{batch_class, simulate, SimOptions};

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "sim" => cmd_sim(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "report" => cmd_report(&args[1..]),
        "selftest" => cmd_selftest(&args[1..]),
        "workloads" => {
            for w in WORKLOADS {
                let m = ModelConfig::preset(w)?;
                println!(
                    "{w:12} {} enc={} dec={} d={} ff={} r={} nz/col={}",
                    m.arch.name(),
                    m.enc_layers,
                    m.dec_layers,
                    m.d_model,
                    m.d_ff,
                    m.rank,
                    m.nnz_per_col
                );
            }
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: trex <sim|serve|report|selftest|workloads> [options]\n\
                 \n  sim      --model <preset> [--seq N] [--batch 1|2|4] [--vdd V] [--no-trf] [--no-prefetch]\
                 \n  serve    --requests N [--artifacts DIR] [--perf-model <preset>]\
                 \n  report   --model <preset>\
                 \n  selftest [--artifacts DIR]"
            );
            Ok(())
        }
    }
}

fn cmd_sim(args: &[String]) -> anyhow::Result<()> {
    let hw = HwConfig::default();
    let name = arg_value(args, "--model").unwrap_or_else(|| "bert-large".to_string());
    let m = ModelConfig::preset(&name)?;
    let seq: usize = arg_value(args, "--seq")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(m.max_seq.min(m.mean_input_len as usize));
    let batch: usize = arg_value(args, "--batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| batch_class(seq, hw.max_seq).map(|c| c.batch()).unwrap_or(1));
    let mut opts = SimOptions { act_bits: m.act_bits, ..SimOptions::paper(&hw) };
    if let Some(v) = arg_value(args, "--vdd") {
        opts.point = hw.point_at_vdd(v.parse()?);
    }
    if args.iter().any(|a| a == "--no-trf") {
        opts.trf = false;
    }
    if args.iter().any(|a| a == "--no-prefetch") {
        opts.prefetch = false;
    }
    let prog = build_program(&m, seq, batch);
    let stats = simulate(&hw, &prog, &opts);
    println!("{}", stats.to_json(&hw).to_string_pretty());
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let n: usize = arg_value(args, "--requests").map(|s| s.parse()).transpose()?.unwrap_or(100);
    let dir = arg_value(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_dir);
    let perf_name = arg_value(args, "--perf-model").unwrap_or_else(|| "bert-large".to_string());
    let perf_model = ModelConfig::preset(&perf_name)?;

    let manifest = trex::util::json::Json::from_file(dir.join("manifest.json"))
        .map_err(|e| anyhow::anyhow!("{e} — run `make artifacts`"))?;
    let d_model = manifest.get("model")?.get("d_model")?.as_usize()?;
    let max_seq = manifest.get("model")?.get("max_seq")?.as_usize()?;

    let hw = HwConfig::default();
    let dir2 = dir.clone();
    let pm = perf_model.clone();
    let handle = Server::start(
        move || {
            let rt = PjrtRuntime::cpu()?;
            let set = ArtifactSet::load(&rt, &dir2)?;
            Engine::new(set, EngineConfig { hw, perf_model: pm, self_test: true })
        },
        BatcherConfig { max_seq, max_wait: Duration::from_millis(2) },
    );
    let mut gen = TraceGenerator::for_model(&perf_model, max_seq, d_model, 1);
    for _ in 0..n {
        handle.submit(gen.next())?;
    }
    let mut got = 0;
    while got < n {
        handle.responses.recv_timeout(Duration::from_secs(30))?;
        got += 1;
    }
    let report = handle.shutdown()?;
    println!("{}", report.json().to_string_pretty());
    Ok(())
}

fn cmd_report(args: &[String]) -> anyhow::Result<()> {
    let name = arg_value(args, "--model").unwrap_or_else(|| "bert-large".to_string());
    let m = ModelConfig::preset(&name)?;
    let r = trex::compress::CompressionReport::analytic(&m);
    println!("{}", r.to_json().to_string_pretty());
    Ok(())
}

fn cmd_selftest(args: &[String]) -> anyhow::Result<()> {
    let dir = arg_value(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts::default_dir);
    let rt = PjrtRuntime::cpu()?;
    let set = ArtifactSet::load(&rt, &dir)?;
    set.self_test()?;
    println!("self-test OK: {} artifacts verified against jax check vectors", set.entries.len());
    Ok(())
}
