//! Layer-graph builder: turns a [`ModelConfig`] into the op stream the chip
//! executes for one (possibly dynamically-batched) inference pass.
//!
//! The op IR carries *shapes*, not tensors — it is the schedule the RISC-V
//! top controller would issue. Functional numerics run through the PJRT
//! runtime; the simulator maps this stream to cycles, bytes and joules.
//!
//! Programs are split into [`Phase`]s — contiguous per-layer spans of the op
//! stream — so the executor's [`crate::sim::Stepper`] can run one phase at a
//! time against persistent state. Two builders exist:
//!
//! * [`build_program`] — one whole-sequence (prefill / scoring) pass;
//! * [`build_decode_step`] — ONE autoregressive decode step: a single new
//!   token per input attending over a `past_len`-deep KV cache resident in
//!   the GB. Stepping this program repeatedly (with growing `past_len`) is
//!   the paper's µs/token decode workload.

pub mod ops;

pub use ops::{Op, OpKind};

use crate::config::{ArchKind, ModelConfig};

/// One schedulable phase: a contiguous span of a program's op stream at
/// per-layer granularity. Phases always tile the op stream exactly (no gaps,
/// no overlap) so "step every phase" is identical to "run every op".
#[derive(Debug, Clone)]
pub struct Phase {
    /// Human label: "input", "enc_layer 3", "decode_layer 0", "output", …
    pub label: String,
    /// Global transformer layer this phase covers (None for model-level DMA).
    pub layer: Option<usize>,
    /// Span `[start, end)` into [`Program::ops`].
    pub start: usize,
    pub end: usize,
}

/// A compiled op program for one forward pass (or one decode step).
#[derive(Debug, Clone)]
pub struct Program {
    pub model: String,
    /// Dynamic batch size (1, 2 or 4 — the paper's dataflow classes).
    pub batch: usize,
    /// Per-input sequence length this program was built for (1 for a decode
    /// step: one new token per input).
    pub seq: usize,
    /// KV prefix length a decode step attends over (0 for prefill passes).
    pub past_len: usize,
    pub ops: Vec<Op>,
    /// Per-layer execution phases tiling `ops` (see [`Phase`]).
    pub phases: Vec<Phase>,
}

impl Program {
    /// Wrap a raw op stream as a single-phase program (baseline comparators
    /// that don't need per-layer stepping).
    pub fn from_ops(model: String, batch: usize, seq: usize, ops: Vec<Op>) -> Program {
        let all = Phase { label: "all".to_string(), layer: None, start: 0, end: ops.len() };
        Program { model, batch, seq, past_len: 0, ops, phases: vec![all] }
    }

    /// The ops of one phase.
    pub fn phase_ops(&self, phase: &Phase) -> &[Op] {
        &self.ops[phase.start..phase.end]
    }

    /// Total MAC operations across DMM+SMM ops.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs()).sum()
    }
    /// Total AFU element-operations.
    pub fn total_afu_elems(&self) -> u64 {
        self.ops.iter().map(|o| o.afu_elems()).sum()
    }
    /// Total weight bytes streamed from DRAM (compressed W_D plane).
    pub fn weight_ema_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter_map(|o| match o.kind {
                OpKind::LoadWd { bytes_val, bytes_idx, bytes_meta } => {
                    Some(bytes_val + bytes_idx + bytes_meta)
                }
                _ => None,
            })
            .sum()
    }
}

/// Build the op program for `batch` inputs of length `seq` each.
///
/// `batch` follows the paper's dynamic-batching classes: the caller passes
/// the class the batcher chose (1, 2 or 4); total tokens = `batch × seq`
/// must fit the chip's 128-token plane.
pub fn build_program(m: &ModelConfig, seq: usize, batch: usize) -> Program {
    let mut b = Builder::new(m, seq, batch);
    b.phase("input", None, |b| b.input_load());
    for l in 0..m.enc_layers {
        b.phase(&format!("enc_layer {l}"), Some(l), |b| b.encoder_layer(l));
    }
    if m.arch == ArchKind::EncoderDecoder {
        // Non-autoregressive single decode pass over `seq` target positions
        // (scoring mode): the chip's decode workloads are measured per-token;
        // per-token cost is derived by the simulator from this pass.
        for l in 0..m.dec_layers {
            let g = m.enc_layers + l;
            b.phase(&format!("dec_layer {l}"), Some(g), |b| b.decoder_layer(l));
        }
    }
    b.phase("output", None, |b| b.output_store());
    Program { model: m.name.clone(), batch, seq, past_len: 0, ops: b.ops, phases: b.phases }
}

/// Build ONE autoregressive decode step: `batch` streams each produce one
/// new token attending over a `past_len`-deep KV cache (kept resident in the
/// GB — see [`crate::sim::GbBudget::kv_cache_bytes`]; no EMA is charged for
/// KV reads). Per step the chip still streams every decode layer's W_D —
/// that weight traffic is the dominant per-token EMA the paper's batching
/// amortizes.
///
/// The decode stack is the decoder for encoder-decoder models (self-attention
/// over the cache plus cross-attention whose K/V were projected once at
/// prefill) and the full encoder stack run LM-style for encoder-only models.
/// Cross-attention length uses the workload's `mean_input_len` (the builder
/// is keyed by `past_len` alone so decode-step simulations stay cacheable).
pub fn build_decode_step(m: &ModelConfig, past_len: usize, batch: usize) -> Program {
    build_decode_step_impl(m, past_len, batch, false).0
}

/// Role a `past_len`-dependent op plays in a decode step's self-attention —
/// the ONLY ops of a decode-step program whose shapes vary with the KV
/// depth (every projection, the cross-attention core, and all DMA ops are
/// fixed by `(model, batch)` alone).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvRole {
    /// `attn_scores` Dmm: `n` = kv length.
    Scores,
    /// Attention `softmax`: `cols` = kv length.
    Softmax,
    /// `attn_context` Dmm: `k` = kv length.
    Context,
}

/// One kv-dependent op site in a decode-step program.
#[derive(Debug, Clone, Copy)]
pub struct KvSite {
    /// Index into [`Program::ops`].
    pub op: usize,
    pub role: KvRole,
}

/// A decode-step program with its `past_len`-dependent op sites marked:
/// the parametric emission the step-plan compiler
/// ([`crate::sim::StepPlan`]) consumes. The program is built at
/// `past_len = 0` (kv = 1); every op NOT listed in `kv_sites` is invariant
/// in `past_len` for this `(model, batch)` pair, so its cost can be priced
/// once ahead of time.
#[derive(Debug, Clone)]
pub struct DecodeStepTemplate {
    pub prog: Program,
    /// Kv-dependent op sites, in op order (three per decode layer:
    /// self-attention scores, softmax, context).
    pub kv_sites: Vec<KvSite>,
}

/// Build the decode-step template for `(m, batch)` — see
/// [`DecodeStepTemplate`].
pub fn build_decode_template(m: &ModelConfig, batch: usize) -> DecodeStepTemplate {
    let (prog, kv_sites) = build_decode_step_impl(m, 0, batch, true);
    DecodeStepTemplate { prog, kv_sites }
}

fn build_decode_step_impl(
    m: &ModelConfig,
    past_len: usize,
    batch: usize,
    track_kv: bool,
) -> (Program, Vec<KvSite>) {
    let mut b = Builder::new(m, 1, batch); // seq = 1: one new token per input
    b.track_kv = track_kv;
    let kv = past_len + 1; // the new token attends over past + itself
    b.phase("input", None, |b| b.input_load());
    if m.arch == ArchKind::EncoderDecoder {
        let cross = (m.mean_input_len as usize).clamp(1, m.max_seq);
        for l in 0..m.dec_layers {
            let g = m.enc_layers + l;
            b.phase(&format!("decode_layer {l}"), Some(g), |b| {
                b.decode_layer(g, kv, Some(cross))
            });
        }
    } else {
        for l in 0..m.enc_layers {
            b.phase(&format!("decode_layer {l}"), Some(l), |b| b.decode_layer(l, kv, None));
        }
    }
    b.phase("output", None, |b| b.output_store());
    let prog =
        Program { model: m.name.clone(), batch, seq: 1, past_len, ops: b.ops, phases: b.phases };
    (prog, b.kv_sites)
}

struct Builder<'a> {
    m: &'a ModelConfig,
    seq: usize,
    batch: usize,
    ops: Vec<Op>,
    phases: Vec<Phase>,
    /// Record kv-dependent op sites (decode-step templates only).
    track_kv: bool,
    kv_sites: Vec<KvSite>,
}

impl<'a> Builder<'a> {
    fn new(m: &'a ModelConfig, seq: usize, batch: usize) -> Self {
        Builder {
            m,
            seq,
            batch,
            ops: Vec::new(),
            phases: Vec::new(),
            track_kv: false,
            kv_sites: Vec::new(),
        }
    }

    /// Run `f` and record the ops it emitted as one phase.
    fn phase(&mut self, label: &str, layer: Option<usize>, f: impl FnOnce(&mut Self)) {
        let start = self.ops.len();
        f(self);
        self.phases.push(Phase { label: label.to_string(), layer, start, end: self.ops.len() });
    }

    /// Rows of the token-parallel activation matrix.
    fn rows(&self) -> usize {
        self.batch * self.seq
    }

    fn act_bytes(&self, elems: usize) -> u64 {
        (elems * self.m.act_bits as usize / 8) as u64
    }

    fn input_load(&mut self) {
        let bytes = self.act_bytes(self.rows() * self.m.d_model);
        self.ops.push(Op::load_input(bytes));
    }

    fn output_store(&mut self) {
        let bytes = self.act_bytes(self.rows() * self.m.d_model);
        self.ops.push(Op::store_output(bytes));
    }

    /// Compressed W_D bytes for `cols` columns (6b values + ~5b delta
    /// indices + scale/offset), matching `CompressionReport`.
    fn wd_bytes(&self, cols: usize) -> (u64, u64, u64) {
        let nz = (cols * self.m.nnz_per_col) as u64;
        let val = (nz * 6).div_ceil(8);
        let idx = (nz * 5).div_ceil(8);
        (val, idx, 4)
    }

    /// One factorized projection: Dmm (X·W_S) then Smm (Y·W_D).
    fn projection(&mut self, layer: usize, name: &'static str, d_in: usize, d_out: usize) {
        let (bytes_val, bytes_idx, bytes_meta) = self.wd_bytes(d_out);
        self.ops.push(Op::load_wd(layer, name, bytes_val, bytes_idx, bytes_meta));
        self.ops.push(Op::dmm(layer, name, self.rows(), d_in, self.m.rank));
        self.ops.push(Op::smm(layer, name, self.rows(), self.m.rank, d_out, self.m.nnz_per_col));
    }

    /// [`Builder::attention_core`] over the decode step's *growing* self-
    /// attention KV — records the three kv-dependent op sites when the
    /// builder is assembling a [`DecodeStepTemplate`].
    fn attention_core_kv(&mut self, layer: usize, q_seq: usize, kv_seq: usize) {
        let base = self.ops.len();
        self.attention_core(layer, q_seq, kv_seq);
        if self.track_kv {
            self.kv_sites.push(KvSite { op: base, role: KvRole::Scores });
            self.kv_sites.push(KvSite { op: base + 1, role: KvRole::Softmax });
            self.kv_sites.push(KvSite { op: base + 2, role: KvRole::Context });
        }
    }

    /// Multi-head attention core: scores, softmax, context. `kv_seq` differs
    /// from `q_seq` for cross-attention.
    fn attention_core(&mut self, layer: usize, q_seq: usize, kv_seq: usize) {
        let h = self.m.heads;
        let dh = self.m.d_model / h;
        let bh = self.batch * h;
        // Q·Kᵀ for every (batch, head): bh independent q_seq×dh · dh×kv_seq MMs.
        self.ops.push(Op::dmm_batched(layer, "attn_scores", bh, q_seq, dh, kv_seq));
        self.ops.push(Op::softmax(layer, bh * q_seq, kv_seq));
        // A·V: bh independent q_seq×kv_seq · kv_seq×dh MMs.
        self.ops.push(Op::dmm_batched(layer, "attn_context", bh, q_seq, kv_seq, dh));
    }

    fn encoder_layer(&mut self, layer: usize) {
        let d = self.m.d_model;
        let ff = self.m.d_ff;
        // Self-attention: Q, K, V projections.
        for name in ["wq", "wk", "wv"] {
            self.projection(layer, name, d, d);
        }
        self.attention_core(layer, self.seq, self.seq);
        self.projection(layer, "wo", d, d);
        self.ops.push(Op::residual(layer, self.rows(), d));
        self.ops.push(Op::layernorm(layer, self.rows(), d));
        // FFN.
        self.projection(layer, "ffn_up", d, ff);
        self.ops.push(Op::gelu(layer, self.rows(), ff));
        self.projection(layer, "ffn_down", ff, d);
        self.ops.push(Op::residual(layer, self.rows(), d));
        self.ops.push(Op::layernorm(layer, self.rows(), d));
    }

    fn decoder_layer(&mut self, layer: usize) {
        let l = self.m.enc_layers + layer; // global layer index
        let d = self.m.d_model;
        let ff = self.m.d_ff;
        // Masked self-attention.
        for name in ["dec_wq", "dec_wk", "dec_wv"] {
            self.projection(l, name, d, d);
        }
        self.attention_core(l, self.seq, self.seq);
        self.projection(l, "dec_wo", d, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
        // Cross-attention over encoder memory.
        for name in ["x_wq", "x_wk", "x_wv"] {
            self.projection(l, name, d, d);
        }
        self.attention_core(l, self.seq, self.seq);
        self.projection(l, "x_wo", d, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
        // FFN.
        self.projection(l, "dec_ffn_up", d, ff);
        self.ops.push(Op::gelu(l, self.rows(), ff));
        self.projection(l, "dec_ffn_down", ff, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
    }

    /// One decode-step layer: single-token self-attention over `kv_self`
    /// cached positions; for encoder-decoder stacks (`cross = Some(len)`)
    /// also single-token cross-attention over the encoder memory — whose K/V
    /// were projected once at prefill, so only the Q (and output) projections
    /// run per step.
    fn decode_layer(&mut self, l: usize, kv_self: usize, cross: Option<usize>) {
        let d = self.m.d_model;
        let ff = self.m.d_ff;
        // Self-attention: project Q/K/V for the new token (K/V rows are
        // appended to the GB-resident cache), attend over the whole cache.
        for name in ["wq", "wk", "wv"] {
            self.projection(l, name, d, d);
        }
        self.attention_core_kv(l, 1, kv_self);
        self.projection(l, "wo", d, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
        if let Some(cross_len) = cross {
            // Cross-attention: encoder-memory K/V are already cached, so the
            // step only projects Q and the attention output.
            self.projection(l, "x_wq", d, d);
            self.attention_core(l, 1, cross_len);
            self.projection(l, "x_wo", d, d);
            self.ops.push(Op::residual(l, self.rows(), d));
            self.ops.push(Op::layernorm(l, self.rows(), d));
        }
        self.projection(l, "ffn_up", d, ff);
        self.ops.push(Op::gelu(l, self.rows(), ff));
        self.projection(l, "ffn_down", ff, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressionReport;
    use crate::config::ModelConfig;

    #[test]
    fn tiny_program_structure() {
        let m = ModelConfig::tiny();
        let p = build_program(&m, 16, 1);
        assert_eq!(p.batch, 1);
        // 2 layers × (4 proj×3 ops + 2 attn MM + softmax + gelu + 2 res +
        // 2 ln + 2 ffn proj...) + in/out
        assert!(p.ops.len() > 20);
        assert_eq!(p.ops.first().unwrap().name, "load_input");
        assert_eq!(p.ops.last().unwrap().name, "store_output");
        assert!(p.total_macs() > 0);
    }

    #[test]
    fn weight_ema_matches_report() {
        // The dynamic program's weight bytes must agree with the static
        // CompressionReport (minus W_S, which is preloaded, and using the
        // same nominal 5-bit indices).
        for name in ["tiny", "bert-large", "s2t-small"] {
            let m = ModelConfig::preset(name).unwrap();
            let p = build_program(&m, m.max_seq, 1);
            let report = CompressionReport::analytic(&m);
            let dynamic = p.weight_ema_bytes() as f64;
            let statically =
                (report.compressed_bytes - report.ws_compressed_bytes) as f64;
            let rel = (dynamic - statically).abs() / statically;
            assert!(rel < 0.02, "{name}: dynamic {dynamic} vs static {statically} ({rel:.3})");
        }
    }

    #[test]
    fn batch_scales_activations_not_weights() {
        let m = ModelConfig::tiny();
        let p1 = build_program(&m, 16, 1);
        let p4 = build_program(&m, 16, 4);
        // Same weight traffic per pass…
        assert_eq!(p1.weight_ema_bytes(), p4.weight_ema_bytes());
        // …but 4× the MACs (4 inputs of work).
        let r = p4.total_macs() as f64 / p1.total_macs() as f64;
        assert!((3.2..4.2).contains(&r), "mac ratio {r}");
    }

    #[test]
    fn decoder_adds_cross_attention() {
        let m = ModelConfig::s2t_small();
        let p = build_program(&m, 32, 1);
        let has_cross = p.ops.iter().any(|o| o.name == "x_wq");
        assert!(has_cross);
    }

    #[test]
    fn macs_scale_with_seq() {
        let m = ModelConfig::tiny();
        let a = build_program(&m, 8, 1).total_macs();
        let b = build_program(&m, 32, 1).total_macs();
        assert!(b > 3 * a, "quadratic attention + linear projections");
    }

    #[test]
    fn phases_tile_the_op_stream_exactly() {
        for prog in [
            build_program(&ModelConfig::tiny(), 16, 2),
            build_program(&ModelConfig::s2t_small(), 32, 1),
            build_decode_step(&ModelConfig::s2t_small(), 17, 4),
            build_decode_step(&ModelConfig::tiny(), 0, 1),
        ] {
            assert!(!prog.phases.is_empty());
            let mut cursor = 0;
            for p in &prog.phases {
                assert_eq!(p.start, cursor, "{}: gap/overlap at {}", prog.model, p.label);
                assert!(p.end >= p.start);
                cursor = p.end;
            }
            assert_eq!(cursor, prog.ops.len(), "{}: phases must cover all ops", prog.model);
            // Layer phases carry their layer; DMA phases don't.
            assert!(prog.phases.first().unwrap().layer.is_none());
            assert!(prog.phases.last().unwrap().layer.is_none());
            assert!(prog.phases.iter().any(|p| p.layer.is_some()));
        }
    }

    #[test]
    fn decode_step_is_single_token() {
        let m = ModelConfig::tiny();
        let p = build_decode_step(&m, 10, 4);
        assert_eq!((p.seq, p.batch, p.past_len), (1, 4, 10));
        // One new token per input: tokens = batch × 1.
        assert_eq!(p.batch * p.seq, 4);
        // Attention score MM attends over past_len + 1 keys.
        let scores = p.ops.iter().find(|o| o.name == "attn_scores").unwrap();
        match scores.kind {
            OpKind::Dmm { count, m: q, k: _, n: kv, .. } => {
                assert_eq!(q, 1, "one query row per (batch, head)");
                assert_eq!(kv, 11, "kv length = past_len + 1");
                assert_eq!(count, 4 * m.heads);
            }
            _ => panic!("attn_scores must be a Dmm"),
        }
    }

    #[test]
    fn decode_step_streams_full_wd_each_step() {
        // Per decode step the chip re-streams every decode layer's W_D —
        // the per-token EMA cost the paper's batching divides by `batch`.
        let m = ModelConfig::tiny();
        let step = build_decode_step(&m, 16, 1);
        let prefill = build_program(&m, 16, 1);
        assert_eq!(
            step.weight_ema_bytes(),
            prefill.weight_ema_bytes(),
            "encoder-only decode streams the same per-layer W_D as a pass"
        );
        // And the weight bytes are batch-invariant (amortized per token).
        let b4 = build_decode_step(&m, 16, 4);
        assert_eq!(step.weight_ema_bytes(), b4.weight_ema_bytes());
    }

    #[test]
    fn decode_step_macs_grow_with_past_len() {
        let m = ModelConfig::s2t_small();
        let near = build_decode_step(&m, 4, 1).total_macs();
        let far = build_decode_step(&m, 100, 1).total_macs();
        assert!(far > near, "attention MACs scale with the KV prefix");
        // Decoder-only stack: cheaper than a full prefill pass per token.
        let prefill = build_program(&m, 64, 1);
        assert!(far < prefill.total_macs());
    }

    #[test]
    fn decode_template_marks_exactly_the_kv_dependent_ops() {
        for name in ["tiny", "s2t-small", "nmt-rdrop"] {
            let m = ModelConfig::preset(name).unwrap();
            for batch in [1usize, 4] {
                let tpl = build_decode_template(&m, batch);
                let stack = if m.dec_layers > 0 { m.dec_layers } else { m.enc_layers };
                assert_eq!(tpl.kv_sites.len(), 3 * stack, "{name}: 3 sites per decode layer");
                for site in tpl.kv_sites.chunks(3) {
                    assert_eq!(site[0].role, KvRole::Scores);
                    assert_eq!(site[1].role, KvRole::Softmax);
                    assert_eq!(site[2].role, KvRole::Context);
                    assert_eq!(tpl.prog.ops[site[0].op].name, "attn_scores");
                    assert_eq!(tpl.prog.ops[site[1].op].name, "softmax");
                    assert_eq!(tpl.prog.ops[site[2].op].name, "attn_context");
                }
                // The marked sites are EXACTLY the ops whose shapes change
                // with past_len: diff the template (past 0) vs a deep step.
                let deep = build_decode_step(&m, 57, batch);
                assert_eq!(deep.ops.len(), tpl.prog.ops.len());
                let marked: std::collections::BTreeSet<usize> =
                    tpl.kv_sites.iter().map(|s| s.op).collect();
                for (i, (a, b)) in tpl.prog.ops.iter().zip(deep.ops.iter()).enumerate() {
                    let changed = a.kind != b.kind;
                    assert_eq!(changed, marked.contains(&i), "{name} op {i} ({})", a.name);
                }
                // And build_decode_step itself never records sites.
                assert!(!deep.phases.is_empty());
            }
        }
    }

    #[test]
    fn enc_dec_decode_skips_cross_kv_projections() {
        // Cross-attention K/V are projected once at prefill; a decode step
        // must only project x_wq / x_wo.
        let m = ModelConfig::nmt_rdrop();
        let p = build_decode_step(&m, 8, 2);
        assert!(p.ops.iter().any(|o| o.name == "x_wq"));
        assert!(p.ops.iter().any(|o| o.name == "x_wo"));
        assert!(!p.ops.iter().any(|o| o.name == "x_wk" || o.name == "x_wv"));
        // Decode runs the decoder stack only — one phase per decoder layer.
        let layer_phases = p.phases.iter().filter(|ph| ph.layer.is_some()).count();
        assert_eq!(layer_phases, m.dec_layers);
    }
}
