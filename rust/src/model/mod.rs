//! Layer-graph builder: turns a [`ModelConfig`] into the op stream the chip
//! executes for one (possibly dynamically-batched) inference pass.
//!
//! The op IR carries *shapes*, not tensors — it is the schedule the RISC-V
//! top controller would issue. Functional numerics run through the PJRT
//! runtime; the simulator maps this stream to cycles, bytes and joules.

pub mod ops;

pub use ops::{Op, OpKind};

use crate::config::{ArchKind, ModelConfig};

/// A compiled op program for one forward pass.
#[derive(Debug, Clone)]
pub struct Program {
    pub model: String,
    /// Dynamic batch size (1, 2 or 4 — the paper's dataflow classes).
    pub batch: usize,
    /// Per-input sequence length this program was built for.
    pub seq: usize,
    pub ops: Vec<Op>,
}

impl Program {
    /// Total MAC operations across DMM+SMM ops.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs()).sum()
    }
    /// Total AFU element-operations.
    pub fn total_afu_elems(&self) -> u64 {
        self.ops.iter().map(|o| o.afu_elems()).sum()
    }
    /// Total weight bytes streamed from DRAM (compressed W_D plane).
    pub fn weight_ema_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter_map(|o| match o.kind {
                OpKind::LoadWd { bytes_val, bytes_idx, bytes_meta } => {
                    Some(bytes_val + bytes_idx + bytes_meta)
                }
                _ => None,
            })
            .sum()
    }
}

/// Build the op program for `batch` inputs of length `seq` each.
///
/// `batch` follows the paper's dynamic-batching classes: the caller passes
/// the class the batcher chose (1, 2 or 4); total tokens = `batch × seq`
/// must fit the chip's 128-token plane.
pub fn build_program(m: &ModelConfig, seq: usize, batch: usize) -> Program {
    let mut b = Builder::new(m, seq, batch);
    b.input_load();
    for l in 0..m.enc_layers {
        b.encoder_layer(l);
    }
    if m.arch == ArchKind::EncoderDecoder {
        // Non-autoregressive single decode pass over `seq` target positions
        // (scoring mode): the chip's decode workloads are measured per-token;
        // per-token cost is derived by the simulator from this pass.
        for l in 0..m.dec_layers {
            b.decoder_layer(l);
        }
    }
    b.output_store();
    Program { model: m.name.clone(), batch, seq, ops: b.ops }
}

struct Builder<'a> {
    m: &'a ModelConfig,
    seq: usize,
    batch: usize,
    ops: Vec<Op>,
}

impl<'a> Builder<'a> {
    fn new(m: &'a ModelConfig, seq: usize, batch: usize) -> Self {
        Builder { m, seq, batch, ops: Vec::new() }
    }

    /// Rows of the token-parallel activation matrix.
    fn rows(&self) -> usize {
        self.batch * self.seq
    }

    fn act_bytes(&self, elems: usize) -> u64 {
        (elems * self.m.act_bits as usize / 8) as u64
    }

    fn input_load(&mut self) {
        let bytes = self.act_bytes(self.rows() * self.m.d_model);
        self.ops.push(Op::load_input(bytes));
    }

    fn output_store(&mut self) {
        let bytes = self.act_bytes(self.rows() * self.m.d_model);
        self.ops.push(Op::store_output(bytes));
    }

    /// Compressed W_D bytes for `cols` columns (6b values + ~5b delta
    /// indices + scale/offset), matching `CompressionReport`.
    fn wd_bytes(&self, cols: usize) -> (u64, u64, u64) {
        let nz = (cols * self.m.nnz_per_col) as u64;
        let val = (nz * 6).div_ceil(8);
        let idx = (nz * 5).div_ceil(8);
        (val, idx, 4)
    }

    /// One factorized projection: Dmm (X·W_S) then Smm (Y·W_D).
    fn projection(&mut self, layer: usize, name: &'static str, d_in: usize, d_out: usize) {
        let (bytes_val, bytes_idx, bytes_meta) = self.wd_bytes(d_out);
        self.ops.push(Op::load_wd(layer, name, bytes_val, bytes_idx, bytes_meta));
        self.ops.push(Op::dmm(layer, name, self.rows(), d_in, self.m.rank));
        self.ops.push(Op::smm(layer, name, self.rows(), self.m.rank, d_out, self.m.nnz_per_col));
    }

    /// Multi-head attention core: scores, softmax, context. `kv_seq` differs
    /// from `q_seq` for cross-attention.
    fn attention_core(&mut self, layer: usize, q_seq: usize, kv_seq: usize) {
        let h = self.m.heads;
        let dh = self.m.d_model / h;
        let bh = self.batch * h;
        // Q·Kᵀ for every (batch, head): bh independent q_seq×dh · dh×kv_seq MMs.
        self.ops.push(Op::dmm_batched(layer, "attn_scores", bh, q_seq, dh, kv_seq));
        self.ops.push(Op::softmax(layer, bh * q_seq, kv_seq));
        // A·V: bh independent q_seq×kv_seq · kv_seq×dh MMs.
        self.ops.push(Op::dmm_batched(layer, "attn_context", bh, q_seq, kv_seq, dh));
    }

    fn encoder_layer(&mut self, layer: usize) {
        let d = self.m.d_model;
        let ff = self.m.d_ff;
        // Self-attention: Q, K, V projections.
        for name in ["wq", "wk", "wv"] {
            self.projection(layer, name, d, d);
        }
        self.attention_core(layer, self.seq, self.seq);
        self.projection(layer, "wo", d, d);
        self.ops.push(Op::residual(layer, self.rows(), d));
        self.ops.push(Op::layernorm(layer, self.rows(), d));
        // FFN.
        self.projection(layer, "ffn_up", d, ff);
        self.ops.push(Op::gelu(layer, self.rows(), ff));
        self.projection(layer, "ffn_down", ff, d);
        self.ops.push(Op::residual(layer, self.rows(), d));
        self.ops.push(Op::layernorm(layer, self.rows(), d));
    }

    fn decoder_layer(&mut self, layer: usize) {
        let l = self.m.enc_layers + layer; // global layer index
        let d = self.m.d_model;
        let ff = self.m.d_ff;
        // Masked self-attention.
        for name in ["dec_wq", "dec_wk", "dec_wv"] {
            self.projection(l, name, d, d);
        }
        self.attention_core(l, self.seq, self.seq);
        self.projection(l, "dec_wo", d, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
        // Cross-attention over encoder memory.
        for name in ["x_wq", "x_wk", "x_wv"] {
            self.projection(l, name, d, d);
        }
        self.attention_core(l, self.seq, self.seq);
        self.projection(l, "x_wo", d, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
        // FFN.
        self.projection(l, "dec_ffn_up", d, ff);
        self.ops.push(Op::gelu(l, self.rows(), ff));
        self.projection(l, "dec_ffn_down", ff, d);
        self.ops.push(Op::residual(l, self.rows(), d));
        self.ops.push(Op::layernorm(l, self.rows(), d));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressionReport;
    use crate::config::ModelConfig;

    #[test]
    fn tiny_program_structure() {
        let m = ModelConfig::tiny();
        let p = build_program(&m, 16, 1);
        assert_eq!(p.batch, 1);
        // 2 layers × (4 proj×3 ops + 2 attn MM + softmax + gelu + 2 res +
        // 2 ln + 2 ffn proj...) + in/out
        assert!(p.ops.len() > 20);
        assert_eq!(p.ops.first().unwrap().name, "load_input");
        assert_eq!(p.ops.last().unwrap().name, "store_output");
        assert!(p.total_macs() > 0);
    }

    #[test]
    fn weight_ema_matches_report() {
        // The dynamic program's weight bytes must agree with the static
        // CompressionReport (minus W_S, which is preloaded, and using the
        // same nominal 5-bit indices).
        for name in ["tiny", "bert-large", "s2t-small"] {
            let m = ModelConfig::preset(name).unwrap();
            let p = build_program(&m, m.max_seq, 1);
            let report = CompressionReport::analytic(&m);
            let dynamic = p.weight_ema_bytes() as f64;
            let statically =
                (report.compressed_bytes - report.ws_compressed_bytes) as f64;
            let rel = (dynamic - statically).abs() / statically;
            assert!(rel < 0.02, "{name}: dynamic {dynamic} vs static {statically} ({rel:.3})");
        }
    }

    #[test]
    fn batch_scales_activations_not_weights() {
        let m = ModelConfig::tiny();
        let p1 = build_program(&m, 16, 1);
        let p4 = build_program(&m, 16, 4);
        // Same weight traffic per pass…
        assert_eq!(p1.weight_ema_bytes(), p4.weight_ema_bytes());
        // …but 4× the MACs (4 inputs of work).
        let r = p4.total_macs() as f64 / p1.total_macs() as f64;
        assert!((3.2..4.2).contains(&r), "mac ratio {r}");
    }

    #[test]
    fn decoder_adds_cross_attention() {
        let m = ModelConfig::s2t_small();
        let p = build_program(&m, 32, 1);
        let has_cross = p.ops.iter().any(|o| o.name == "x_wq");
        assert!(has_cross);
    }

    #[test]
    fn macs_scale_with_seq() {
        let m = ModelConfig::tiny();
        let a = build_program(&m, 8, 1).total_macs();
        let b = build_program(&m, 32, 1).total_macs();
        assert!(b > 3 * a, "quadratic attention + linear projections");
    }
}
