//! The op IR: shape-carrying instructions the top controller issues.

/// What kind of hardware block executes the op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// DMA: stream one layer's compressed W_D from DRAM.
    LoadWd { bytes_val: u64, bytes_idx: u64, bytes_meta: u64 },
    /// DMA: stream a dense 16b weight matrix (unfactorized baseline only).
    LoadDenseWeights { bytes: u64 },
    /// DMA: input activations in.
    LoadInput { bytes: u64 },
    /// DMA: output activations out.
    StoreOutput { bytes: u64 },
    /// Dense MM on the DMM cores: `count` independent `m×k · k×n` products.
    /// `w_bits` is the stored bit-width of the stationary operand (4 for the
    /// LUT-coded W_S, act_bits for activation·activation attention MMs).
    Dmm { count: usize, m: usize, k: usize, n: usize, w_bits: u32 },
    /// Sparse MM on the SMM cores: `m×r` · fixed-NZ `r×n` (values at 6b,
    /// processed by the bit-serial MAC in 8b lanes).
    Smm { m: usize, r: usize, n: usize, nnz_per_col: usize, w_bits: u32 },
    /// AFU element-wise / reduction ops over an `rows×cols` activation.
    Softmax { rows: usize, cols: usize },
    LayerNorm { rows: usize, cols: usize },
    Gelu { rows: usize, cols: usize },
    Residual { rows: usize, cols: usize },
}

/// One scheduled op.
#[derive(Debug, Clone)]
pub struct Op {
    /// Global layer index (usize::MAX for model-level DMA).
    pub layer: usize,
    /// Human-readable site name ("wq", "ffn_up", "attn_scores", …).
    pub name: &'static str,
    pub kind: OpKind,
}

impl Op {
    pub fn load_wd(layer: usize, name: &'static str, bytes_val: u64, bytes_idx: u64, bytes_meta: u64) -> Op {
        Op { layer, name, kind: OpKind::LoadWd { bytes_val, bytes_idx, bytes_meta } }
    }
    pub fn load_input(bytes: u64) -> Op {
        Op { layer: usize::MAX, name: "load_input", kind: OpKind::LoadInput { bytes } }
    }
    pub fn store_output(bytes: u64) -> Op {
        Op { layer: usize::MAX, name: "store_output", kind: OpKind::StoreOutput { bytes } }
    }
    pub fn load_dense_weights(layer: usize, name: &'static str, bytes: u64) -> Op {
        let _ = name;
        Op { layer, name: "load_dense_weights", kind: OpKind::LoadDenseWeights { bytes } }
    }
    /// Dense-baseline DMM: 16b weights (no factorization, no LUT codes).
    pub fn dmm_dense16(layer: usize, name: &'static str, m: usize, k: usize, n: usize) -> Op {
        Op { layer, name, kind: OpKind::Dmm { count: 1, m, k, n, w_bits: 16 } }
    }
    /// Projection DMM: weights are 4b LUT codes.
    pub fn dmm(layer: usize, name: &'static str, m: usize, k: usize, n: usize) -> Op {
        Op { layer, name, kind: OpKind::Dmm { count: 1, m, k, n, w_bits: 4 } }
    }
    /// Attention DMM: both operands are activations (8b).
    pub fn dmm_batched(layer: usize, name: &'static str, count: usize, m: usize, k: usize, n: usize) -> Op {
        Op { layer, name, kind: OpKind::Dmm { count, m, k, n, w_bits: 8 } }
    }
    /// SMM: 6b uniform-quantized values ride the 8b bit-serial lane.
    pub fn smm(layer: usize, name: &'static str, m: usize, r: usize, n: usize, nnz_per_col: usize) -> Op {
        Op { layer, name, kind: OpKind::Smm { m, r, n, nnz_per_col, w_bits: 8 } }
    }
    pub fn softmax(layer: usize, rows: usize, cols: usize) -> Op {
        Op { layer, name: "softmax", kind: OpKind::Softmax { rows, cols } }
    }
    pub fn layernorm(layer: usize, rows: usize, cols: usize) -> Op {
        Op { layer, name: "layernorm", kind: OpKind::LayerNorm { rows, cols } }
    }
    pub fn gelu(layer: usize, rows: usize, cols: usize) -> Op {
        Op { layer, name: "gelu", kind: OpKind::Gelu { rows, cols } }
    }
    pub fn residual(layer: usize, rows: usize, cols: usize) -> Op {
        Op { layer, name: "residual", kind: OpKind::Residual { rows, cols } }
    }

    /// MAC count of the op (0 for DMA/AFU ops).
    pub fn macs(&self) -> u64 {
        match self.kind {
            OpKind::Dmm { count, m, k, n, .. } => (count * m * k * n) as u64,
            OpKind::Smm { m, n, nnz_per_col, .. } => (m * n * nnz_per_col) as u64,
            _ => 0,
        }
    }

    /// AFU element-op count (rough IAU/FAU op count per element):
    /// softmax ≈ 4 ops/elem (exp LUT, sum, div, scale), layernorm ≈ 4,
    /// gelu ≈ 2 (LUT + mul), residual ≈ 1.
    pub fn afu_elems(&self) -> u64 {
        match self.kind {
            OpKind::Softmax { rows, cols } => (rows * cols * 4) as u64,
            OpKind::LayerNorm { rows, cols } => (rows * cols * 4) as u64,
            OpKind::Gelu { rows, cols } => (rows * cols * 2) as u64,
            OpKind::Residual { rows, cols } => (rows * cols) as u64,
            _ => 0,
        }
    }

    /// DMA bytes moved (0 for compute ops).
    pub fn dma_bytes(&self) -> u64 {
        match self.kind {
            OpKind::LoadWd { bytes_val, bytes_idx, bytes_meta } => bytes_val + bytes_idx + bytes_meta,
            OpKind::LoadDenseWeights { bytes }
            | OpKind::LoadInput { bytes }
            | OpKind::StoreOutput { bytes } => bytes,
            _ => 0,
        }
    }

    pub fn is_compute(&self) -> bool {
        matches!(self.kind, OpKind::Dmm { .. } | OpKind::Smm { .. })
    }
    pub fn is_afu(&self) -> bool {
        matches!(
            self.kind,
            OpKind::Softmax { .. } | OpKind::LayerNorm { .. } | OpKind::Gelu { .. } | OpKind::Residual { .. }
        )
    }
    pub fn is_dma(&self) -> bool {
        matches!(
            self.kind,
            OpKind::LoadWd { .. }
                | OpKind::LoadDenseWeights { .. }
                | OpKind::LoadInput { .. }
                | OpKind::StoreOutput { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_counts() {
        assert_eq!(Op::dmm(0, "x", 4, 8, 16).macs(), 512);
        assert_eq!(Op::dmm_batched(0, "x", 3, 4, 8, 16).macs(), 1536);
        assert_eq!(Op::smm(0, "x", 4, 32, 16, 5).macs(), 320); // m·n·nnz
        assert_eq!(Op::softmax(0, 4, 4).macs(), 0);
    }

    #[test]
    fn categories_partition() {
        let ops = [
            Op::load_wd(0, "w", 1, 1, 1),
            Op::dmm(0, "x", 1, 1, 1),
            Op::smm(0, "x", 1, 1, 1, 1),
            Op::softmax(0, 1, 1),
            Op::load_input(1),
        ];
        for o in &ops {
            let cats = [o.is_compute(), o.is_afu(), o.is_dma()];
            assert_eq!(cats.iter().filter(|&&c| c).count(), 1, "{o:?}");
        }
    }

    #[test]
    fn dma_bytes_sum_components() {
        assert_eq!(Op::load_wd(0, "w", 10, 5, 4).dma_bytes(), 19);
        assert_eq!(Op::load_input(7).dma_bytes(), 7);
        assert_eq!(Op::dmm(0, "x", 2, 2, 2).dma_bytes(), 0);
    }
}
