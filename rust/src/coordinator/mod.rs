//! Serving coordinator: the production-shaped L3 plane.
//!
//! A [`server::Server`] owns one engine thread per model. Requests enter
//! through a channel, the [`batcher::DynamicBatcher`] groups them into the
//! paper's batch classes (Fig. 23.1.4), and the [`engine::Engine`] executes
//! each batch: numerics through the PJRT artifacts, latency/energy/EMA
//! through the cycle-level simulator. `std::thread` + mpsc channels (tokio
//! is not vendored offline — DESIGN.md §2).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod server;
pub mod trace;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use engine::{Engine, EngineConfig};
pub use metrics::ServerMetrics;
pub use request::{Request, RequestId, Response};
pub use server::{Server, ServerHandle};
pub use trace::TraceGenerator;
