//! Serving coordinator: the production-shaped L3 plane.
//!
//! A [`server::Server`] runs a multi-worker pool: one admission/ingest
//! thread feeds the [`batcher::DynamicBatcher`], which groups requests into
//! the paper's batch classes (Fig. 23.1.4); formed batches land on a shared
//! class-affinity work queue, and N [`engine::Engine`] workers execute them
//! — numerics through the runtime backend, latency/energy/EMA through the
//! cycle-level simulator via a process-wide shared [`sim_cache::SimCache`].
//! Generate requests continue past prefill as [`engine::DecodeState`]
//! streams with token-level continuous batching: they re-enter the queue
//! after every decode step, regrouping under the pool's
//! [`batcher::DecodePolicy`] (greedy FIFO or depth-bucketed to bound pad
//! waste), and stream [`request::TokenEvent`]s back while in flight.
//! The scheduler adds chunked prefill (long passes park between phase
//! chunks as [`engine::PrefillState`]s so decode steps interleave
//! mid-prefill), a decode coalescing window, and near-done-first priority
//! — see [`batcher::DecodePool`] and `PoolConfig`. Their
//! KV lives in the pool-wide paged arena of [`crate::kv::KvManager`]:
//! admission bounds aggregate decode state, parked streams keep their
//! pages, and evicted streams pay swap-in EMA on rejoin. Admission applies
//! bounded-queue backpressure (reject/shed when saturated). `std::thread`
//! + mpsc channels (tokio is not vendored offline — DESIGN.md §2).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod server;
pub mod sim_cache;
pub mod trace;

pub use batcher::{
    form_decode_group, BatcherConfig, DecodeEntry, DecodePolicy, DecodePool, DynamicBatcher,
    FormedBatch,
};
pub use engine::{
    DecodeOutcome, DecodeState, Engine, EngineConfig, ExecOutcome, PrefillProgress, PrefillState,
    MAX_DECODE_GROUP,
};
pub use metrics::{
    IntervalStats, LedgerAudit, Lifecycle, MetricsSample, ServerMetrics, REPORT_SCHEMA_VERSION,
};
pub use request::{Request, RequestId, Response, TokenEvent};
pub use server::{
    default_workers, PoolConfig, Server, ServerHandle, ServerReport, Submitter, WorkerCtx,
};
pub use sim_cache::{CacheStats, CachedPass, ChunkClaim, PassKey, SimCache};
pub use trace::TraceGenerator;
