//! Dynamic batcher: groups requests into the chip's batch classes.
//!
//! Policy (mirrors the chip's dataflow admission, Fig. 23.1.4):
//! * classify each request by length → B1 / B2 / B4;
//! * a class queue flushes when it holds `class.batch()` requests (a full
//!   reconfigured pass) or when its oldest request exceeds `max_wait`;
//! * B1 flushes immediately (batch of one).
//!
//! The batcher is pure data structure (no threads) so it can be driven by
//! the server loop and tested deterministically.
//!
//! Decode-side grouping lives here too: [`form_decode_group`] regroups the
//! pool's between-steps streams under a [`DecodePolicy`] — greedy FIFO (the
//! chip takes whatever waits) or depth-bucketed, which only groups streams
//! whose `past_len` falls in the same bucket so the pad waste of a step
//! (each stream pads to the group's deepest member; ∝ max−min `past_len`)
//! stays bounded by the bucket width.

use crate::coordinator::engine::{DecodeState, MAX_DECODE_GROUP};
use crate::coordinator::request::Request;
use crate::error::Result;
use crate::sim::{batch_class, BatchClass};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Hardware token plane (128 on the chip; the tiny artifact model is 32).
    pub max_seq: usize,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_seq: 128, max_wait: Duration::from_millis(2) }
    }
}

/// A formed batch ready for the engine.
#[derive(Debug)]
pub struct FormedBatch {
    pub class: BatchClass,
    pub requests: Vec<Request>,
}

/// Per-class FIFO queues with deadline flushing.
#[derive(Debug)]
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    queues: [VecDeque<Request>; 3],
}

fn slot(class: BatchClass) -> usize {
    class.index()
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher { cfg, queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()] }
    }

    /// Admit a request; returns a batch if one is now full.
    pub fn push(&mut self, req: Request) -> Result<Option<FormedBatch>> {
        let class = batch_class(req.len, self.cfg.max_seq)?;
        let q = &mut self.queues[slot(class)];
        q.push_back(req);
        if q.len() >= class.batch() {
            let requests = q.drain(..class.batch()).collect();
            return Ok(Some(FormedBatch { class, requests }));
        }
        Ok(None)
    }

    /// Flush any queue whose head has waited past the deadline — emitted as
    /// a *partial* batch (padded by the engine; the chip runs the class
    /// configuration regardless, idle slots stay idle).
    pub fn poll_deadline(&mut self, now: Instant) -> Vec<FormedBatch> {
        let mut out = Vec::new();
        for class in BatchClass::ALL {
            let q = &mut self.queues[slot(class)];
            if let Some(head) = q.front() {
                if now.duration_since(head.arrival) >= self.cfg.max_wait {
                    let take = q.len().min(class.batch());
                    let requests: Vec<Request> = q.drain(..take).collect();
                    out.push(FormedBatch { class, requests });
                }
            }
        }
        out
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<FormedBatch> {
        let mut out = Vec::new();
        for class in BatchClass::ALL {
            let q = &mut self.queues[slot(class)];
            while !q.is_empty() {
                let take = q.len().min(class.batch());
                out.push(FormedBatch { class, requests: q.drain(..take).collect() });
            }
        }
        out
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Earliest deadline across queues (for the server's poll timeout).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|r| r.arrival + self.cfg.max_wait))
            .min()
    }
}

// ------------------------------------------------------- decode regrouping

/// How the pool regroups decode streams between steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodePolicy {
    /// FIFO greedy: group whatever sits at the queue front, up to the
    /// narrowest member's class width (the seed behavior). Mixed depths
    /// welcome — but the step pads to the deepest member, so a shallow
    /// stream riding with a deep one wastes `max − min` token-slots.
    #[default]
    Greedy,
    /// Only group streams whose `past_len` falls in the head stream's
    /// `past_len / bucket` bucket: pad waste per stream is bounded by
    /// `bucket − 1`. The head of the FIFO always leads its group, so no
    /// stream waits forever for bucket-mates.
    DepthBucketed {
        /// Bucket width in tokens (≥ 1).
        bucket: usize,
    },
}

/// Form one decode group from the between-steps pool under `policy`.
///
/// Both policies pop the FIFO head first (fairness) and never group wider
/// than the narrowest member's class width — each stream's decode budget
/// was cap-clamped against KV residency at its *class's* batch width, so
/// grouping it wider would overflow the GB the clamp promised to respect
/// (B1 streams decode solo, B2 pairs, B4 fours).
pub fn form_decode_group(
    pool: &mut VecDeque<DecodeState>,
    policy: DecodePolicy,
) -> Vec<DecodeState> {
    if pool.is_empty() {
        return Vec::new();
    }
    match policy {
        DecodePolicy::Greedy => {
            let mut limit = MAX_DECODE_GROUP;
            let mut take = 0;
            while take < pool.len() && take < limit {
                let width = pool[take].class.batch().min(MAX_DECODE_GROUP);
                if take + 1 > width {
                    break;
                }
                limit = limit.min(width);
                take += 1;
            }
            pool.drain(..take).collect()
        }
        DecodePolicy::DepthBucketed { bucket } => {
            let bucket = bucket.max(1);
            let head_bucket = pool[0].past_len / bucket;
            let mut limit = MAX_DECODE_GROUP;
            let mut picked: Vec<usize> = Vec::new();
            let mut i = 0;
            while i < pool.len() && picked.len() < limit {
                let s = &pool[i];
                if s.past_len / bucket == head_bucket {
                    let width = s.class.batch().min(MAX_DECODE_GROUP);
                    if picked.len() + 1 > width {
                        // A narrower bucket-mate can't ride this group;
                        // stop so it leads its own group soon (FIFO-ish).
                        break;
                    }
                    limit = limit.min(width);
                    picked.push(i);
                }
                i += 1;
            }
            let mut out = Vec::with_capacity(picked.len());
            for &idx in picked.iter().rev() {
                out.push(pool.remove(idx).expect("picked index valid"));
            }
            out.reverse();
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, len, vec![0.0; len * 4])
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig { max_seq: 128, max_wait: Duration::from_millis(5) }
    }

    #[test]
    fn b1_flushes_immediately() {
        let mut b = DynamicBatcher::new(cfg());
        let out = b.push(req(1, 100)).unwrap().expect("B1 should flush at once");
        assert_eq!(out.class, BatchClass::B1);
        assert_eq!(out.requests.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn b4_waits_for_four() {
        let mut b = DynamicBatcher::new(cfg());
        for i in 0..3 {
            assert!(b.push(req(i, 20)).unwrap().is_none());
        }
        assert_eq!(b.pending(), 3);
        let out = b.push(req(3, 20)).unwrap().expect("4th request completes the batch");
        assert_eq!(out.class, BatchClass::B4);
        assert_eq!(out.requests.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn classes_do_not_mix() {
        let mut b = DynamicBatcher::new(cfg());
        assert!(b.push(req(1, 20)).unwrap().is_none()); // B4
        assert!(b.push(req(2, 50)).unwrap().is_none()); // B2
        assert!(b.push(req(3, 20)).unwrap().is_none()); // B4
        let out = b.push(req(4, 50)).unwrap().expect("two B2s form a batch");
        assert_eq!(out.class, BatchClass::B2);
        assert_eq!(out.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert_eq!(b.pending(), 2); // the two B4s still queued
    }

    #[test]
    fn deadline_flushes_partial() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_seq: 128,
            max_wait: Duration::from_millis(0),
        });
        assert!(b.push(req(1, 20)).unwrap().is_none());
        let flushed = b.poll_deadline(Instant::now() + Duration::from_millis(1));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].requests.len(), 1); // partial B4
        assert_eq!(flushed[0].class, BatchClass::B4);
    }

    #[test]
    fn rejects_oversized() {
        let mut b = DynamicBatcher::new(cfg());
        assert!(b.push(req(1, 500)).is_err());
        assert!(b.push(req(1, 0)).is_err());
    }

    #[test]
    fn drain_empties_all() {
        let mut b = DynamicBatcher::new(cfg());
        b.push(req(1, 20)).unwrap();
        b.push(req(2, 50)).unwrap();
        b.push(req(3, 90)).unwrap(); // B1 flushes immediately
        let batches = b.drain();
        assert_eq!(batches.iter().map(|f| f.requests.len()).sum::<usize>(), 2);
        assert_eq!(b.pending(), 0);
    }

    fn stream(id: u64, class: BatchClass, past_len: usize) -> DecodeState {
        DecodeState::stub(id, class, past_len)
    }

    fn pool_of(streams: Vec<DecodeState>) -> VecDeque<DecodeState> {
        streams.into_iter().collect()
    }

    fn pad_waste(group: &[DecodeState]) -> usize {
        let max = group.iter().map(|s| s.past_len).max().unwrap_or(0);
        group.iter().map(|s| max - s.past_len).sum()
    }

    #[test]
    fn greedy_groups_fifo_up_to_narrowest_width() {
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 10),
            stream(1, BatchClass::B4, 50),
            stream(2, BatchClass::B4, 11),
            stream(3, BatchClass::B4, 12),
            stream(4, BatchClass::B4, 13),
        ]);
        let g = form_decode_group(&mut pool, DecodePolicy::Greedy);
        assert_eq!(g.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(pool.len(), 1);
        // A B1 head decodes solo; a B1 mid-queue stops the group before it.
        let mut pool = pool_of(vec![stream(0, BatchClass::B1, 5), stream(1, BatchClass::B4, 5)]);
        let g = form_decode_group(&mut pool, DecodePolicy::Greedy);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].id, 0);
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 5),
            stream(1, BatchClass::B1, 5),
            stream(2, BatchClass::B4, 5),
        ]);
        let g = form_decode_group(&mut pool, DecodePolicy::Greedy);
        assert_eq!(g.len(), 1, "B1 can't ride a pair — group stops at it");
    }

    #[test]
    fn depth_bucketed_bounds_pad_waste() {
        // Greedy over a mixed-depth pool pads shallow streams to the
        // deepest rider; bucketed grouping keeps the spread ≤ bucket−1.
        let streams = || {
            vec![
                stream(0, BatchClass::B4, 4),
                stream(1, BatchClass::B4, 64),
                stream(2, BatchClass::B4, 5),
                stream(3, BatchClass::B4, 6),
                stream(4, BatchClass::B4, 70),
            ]
        };
        let mut greedy_pool = pool_of(streams());
        let greedy = form_decode_group(&mut greedy_pool, DecodePolicy::Greedy);
        assert!(pad_waste(&greedy) >= 60, "greedy pads 4..64: {}", pad_waste(&greedy));

        let bucket = 8;
        let mut pool = pool_of(streams());
        let g1 = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket });
        assert_eq!(g1.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 2, 3]);
        assert!(pad_waste(&g1) <= (bucket - 1) * g1.len());
        // The deep streams lead the next group.
        let g2 = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket });
        assert_eq!(g2.iter().map(|s| s.id).collect::<Vec<_>>(), vec![1, 4]);
        assert!(pool.is_empty());
    }

    #[test]
    fn depth_bucketed_head_always_leads_and_pool_drains() {
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 100),
            stream(1, BatchClass::B4, 3),
            stream(2, BatchClass::B4, 101),
            stream(3, BatchClass::B1, 102),
        ]);
        let mut seen = Vec::new();
        let mut guard = 0;
        while !pool.is_empty() {
            let g = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket: 16 });
            assert!(!g.is_empty(), "progress on every call");
            seen.extend(g.iter().map(|s| s.id));
            guard += 1;
            assert!(guard < 10);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3], "every stream exits exactly once");
    }

    #[test]
    fn depth_bucketed_respects_class_width() {
        // Two streams in one bucket, but the second is B2: the group is
        // bounded by the narrowest member's width (2), and a third
        // bucket-mate can't join.
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 8),
            stream(1, BatchClass::B2, 9),
            stream(2, BatchClass::B4, 10),
        ]);
        let g = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket: 16 });
        assert_eq!(g.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn property_all_requests_exit_exactly_once() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut b = DynamicBatcher::new(cfg());
        let mut seen = std::collections::BTreeSet::new();
        let n = 300;
        for id in 0..n {
            let len = rng.range(1, 128);
            if let Some(f) = b.push(req(id, len)).unwrap() {
                for r in f.requests {
                    assert!(seen.insert(r.id), "duplicate {}", r.id);
                }
            }
        }
        for f in b.drain() {
            for r in f.requests {
                assert!(seen.insert(r.id), "duplicate {}", r.id);
            }
        }
        assert_eq!(seen.len(), n as usize);
    }
}
