//! Dynamic batcher: groups requests into the chip's batch classes.
//!
//! Policy (mirrors the chip's dataflow admission, Fig. 23.1.4):
//! * classify each request by length → B1 / B2 / B4;
//! * a class queue flushes when it holds `class.batch()` requests (a full
//!   reconfigured pass) or when its oldest request exceeds `max_wait`;
//! * B1 flushes immediately (batch of one).
//!
//! The batcher is pure data structure (no threads) so it can be driven by
//! the server loop and tested deterministically.

use crate::error::Result;
use crate::coordinator::request::Request;
use crate::sim::{batch_class, BatchClass};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Hardware token plane (128 on the chip; the tiny artifact model is 32).
    pub max_seq: usize,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_seq: 128, max_wait: Duration::from_millis(2) }
    }
}

/// A formed batch ready for the engine.
#[derive(Debug)]
pub struct FormedBatch {
    pub class: BatchClass,
    pub requests: Vec<Request>,
}

/// Per-class FIFO queues with deadline flushing.
#[derive(Debug)]
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    queues: [VecDeque<Request>; 3],
}

fn slot(class: BatchClass) -> usize {
    class.index()
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher { cfg, queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()] }
    }

    /// Admit a request; returns a batch if one is now full.
    pub fn push(&mut self, req: Request) -> Result<Option<FormedBatch>> {
        let class = batch_class(req.len, self.cfg.max_seq)?;
        let q = &mut self.queues[slot(class)];
        q.push_back(req);
        if q.len() >= class.batch() {
            let requests = q.drain(..class.batch()).collect();
            return Ok(Some(FormedBatch { class, requests }));
        }
        Ok(None)
    }

    /// Flush any queue whose head has waited past the deadline — emitted as
    /// a *partial* batch (padded by the engine; the chip runs the class
    /// configuration regardless, idle slots stay idle).
    pub fn poll_deadline(&mut self, now: Instant) -> Vec<FormedBatch> {
        let mut out = Vec::new();
        for class in BatchClass::ALL {
            let q = &mut self.queues[slot(class)];
            if let Some(head) = q.front() {
                if now.duration_since(head.arrival) >= self.cfg.max_wait {
                    let take = q.len().min(class.batch());
                    let requests: Vec<Request> = q.drain(..take).collect();
                    out.push(FormedBatch { class, requests });
                }
            }
        }
        out
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<FormedBatch> {
        let mut out = Vec::new();
        for class in BatchClass::ALL {
            let q = &mut self.queues[slot(class)];
            while !q.is_empty() {
                let take = q.len().min(class.batch());
                out.push(FormedBatch { class, requests: q.drain(..take).collect() });
            }
        }
        out
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Earliest deadline across queues (for the server's poll timeout).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|r| r.arrival + self.cfg.max_wait))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, len, vec![0.0; len * 4])
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig { max_seq: 128, max_wait: Duration::from_millis(5) }
    }

    #[test]
    fn b1_flushes_immediately() {
        let mut b = DynamicBatcher::new(cfg());
        let out = b.push(req(1, 100)).unwrap().expect("B1 should flush at once");
        assert_eq!(out.class, BatchClass::B1);
        assert_eq!(out.requests.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn b4_waits_for_four() {
        let mut b = DynamicBatcher::new(cfg());
        for i in 0..3 {
            assert!(b.push(req(i, 20)).unwrap().is_none());
        }
        assert_eq!(b.pending(), 3);
        let out = b.push(req(3, 20)).unwrap().expect("4th request completes the batch");
        assert_eq!(out.class, BatchClass::B4);
        assert_eq!(out.requests.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn classes_do_not_mix() {
        let mut b = DynamicBatcher::new(cfg());
        assert!(b.push(req(1, 20)).unwrap().is_none()); // B4
        assert!(b.push(req(2, 50)).unwrap().is_none()); // B2
        assert!(b.push(req(3, 20)).unwrap().is_none()); // B4
        let out = b.push(req(4, 50)).unwrap().expect("two B2s form a batch");
        assert_eq!(out.class, BatchClass::B2);
        assert_eq!(out.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert_eq!(b.pending(), 2); // the two B4s still queued
    }

    #[test]
    fn deadline_flushes_partial() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_seq: 128,
            max_wait: Duration::from_millis(0),
        });
        assert!(b.push(req(1, 20)).unwrap().is_none());
        let flushed = b.poll_deadline(Instant::now() + Duration::from_millis(1));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].requests.len(), 1); // partial B4
        assert_eq!(flushed[0].class, BatchClass::B4);
    }

    #[test]
    fn rejects_oversized() {
        let mut b = DynamicBatcher::new(cfg());
        assert!(b.push(req(1, 500)).is_err());
        assert!(b.push(req(1, 0)).is_err());
    }

    #[test]
    fn drain_empties_all() {
        let mut b = DynamicBatcher::new(cfg());
        b.push(req(1, 20)).unwrap();
        b.push(req(2, 50)).unwrap();
        b.push(req(3, 90)).unwrap(); // B1 flushes immediately
        let batches = b.drain();
        assert_eq!(batches.iter().map(|f| f.requests.len()).sum::<usize>(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn property_all_requests_exit_exactly_once() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut b = DynamicBatcher::new(cfg());
        let mut seen = std::collections::BTreeSet::new();
        let n = 300;
        for id in 0..n {
            let len = rng.range(1, 128);
            if let Some(f) = b.push(req(id, len)).unwrap() {
                for r in f.requests {
                    assert!(seen.insert(r.id), "duplicate {}", r.id);
                }
            }
        }
        for f in b.drain() {
            for r in f.requests {
                assert!(seen.insert(r.id), "duplicate {}", r.id);
            }
        }
        assert_eq!(seen.len(), n as usize);
    }
}
