//! Dynamic batcher: groups requests into the chip's batch classes.
//!
//! Policy (mirrors the chip's dataflow admission, Fig. 23.1.4):
//! * classify each request by length → B1 / B2 / B4;
//! * a class queue flushes when it holds `class.batch()` requests (a full
//!   reconfigured pass) or when its oldest request exceeds `max_wait`;
//! * B1 flushes immediately (batch of one).
//!
//! The batcher is pure data structure (no threads) so it can be driven by
//! the server loop and tested deterministically.
//!
//! Decode-side grouping lives here too: [`form_decode_group`] regroups the
//! pool's between-steps streams under a [`DecodePolicy`] — greedy FIFO (the
//! chip takes whatever waits) or depth-bucketed, which only groups streams
//! whose `past_len` falls in the same bucket so the pad waste of a step
//! (each stream pads to the group's deepest member; ∝ max−min `past_len`)
//! stays bounded by the bucket width.
//!
//! [`DecodePool`] is the scheduler's between-steps pool: it timestamps each
//! parked stream and adds two policies on top of the grouper —
//!
//! * a **coalescing window** (`decode_max_wait`): a partial group waits for
//!   bucket-mates until the pool's oldest entry expires, while a *full*
//!   group (at its effective class-width bound) dispatches immediately;
//! * **priority by remaining tokens**: near-done streams lead their groups
//!   and drain first, freeing KV pages and in-flight slots sooner.
//!
//! Its [`DecodePool::next_deadline`] feeds the server's worker poll timeout
//! the same way [`DynamicBatcher::next_deadline`] feeds the ingest loop.

use crate::coordinator::engine::{DecodeState, MAX_DECODE_GROUP};
use crate::coordinator::request::Request;
use crate::error::Result;
use crate::sim::{batch_class, BatchClass};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Hardware token plane (128 on the chip; the tiny artifact model is 32).
    pub max_seq: usize,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_seq: 128, max_wait: Duration::from_millis(2) }
    }
}

/// A formed batch ready for the engine.
#[derive(Debug)]
pub struct FormedBatch {
    pub class: BatchClass,
    pub requests: Vec<Request>,
}

/// Per-class FIFO queues with deadline flushing.
#[derive(Debug)]
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    queues: [VecDeque<Request>; 3],
}

fn slot(class: BatchClass) -> usize {
    class.index()
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher { cfg, queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()] }
    }

    /// Admit a request; returns a batch if one is now full.
    pub fn push(&mut self, req: Request) -> Result<Option<FormedBatch>> {
        let class = batch_class(req.len, self.cfg.max_seq)?;
        let q = &mut self.queues[slot(class)];
        q.push_back(req);
        if q.len() >= class.batch() {
            let requests = q.drain(..class.batch()).collect();
            return Ok(Some(FormedBatch { class, requests }));
        }
        Ok(None)
    }

    /// Flush any queue whose head has waited past the deadline — emitted as
    /// a *partial* batch (padded by the engine; the chip runs the class
    /// configuration regardless, idle slots stay idle). Drains EVERY
    /// expired width in one call: a burst that grew a queue past one batch
    /// width must not serialize through successive poll ticks, one batch
    /// per tick.
    pub fn poll_deadline(&mut self, now: Instant) -> Vec<FormedBatch> {
        let mut out = Vec::new();
        for class in BatchClass::ALL {
            let q = &mut self.queues[slot(class)];
            while let Some(head) = q.front() {
                if now.duration_since(head.arrival) < self.cfg.max_wait {
                    break;
                }
                let take = q.len().min(class.batch());
                let requests: Vec<Request> = q.drain(..take).collect();
                out.push(FormedBatch { class, requests });
            }
        }
        out
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<FormedBatch> {
        let mut out = Vec::new();
        for class in BatchClass::ALL {
            let q = &mut self.queues[slot(class)];
            while !q.is_empty() {
                let take = q.len().min(class.batch());
                out.push(FormedBatch { class, requests: q.drain(..take).collect() });
            }
        }
        out
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Earliest deadline across queues (for the server's poll timeout).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|r| r.arrival + self.cfg.max_wait))
            .min()
    }
}

// ------------------------------------------------------- decode regrouping

/// How the pool regroups decode streams between steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodePolicy {
    /// FIFO greedy: group whatever sits at the queue front, up to the
    /// narrowest member's class width (the seed behavior). Mixed depths
    /// welcome — but the step pads to the deepest member, so a shallow
    /// stream riding with a deep one wastes `max − min` token-slots.
    #[default]
    Greedy,
    /// Only group streams whose `past_len` falls in the head stream's
    /// `past_len / bucket` bucket: pad waste per stream is bounded by
    /// `bucket − 1`. The head of the FIFO always leads its group, so no
    /// stream waits forever for bucket-mates.
    DepthBucketed {
        /// Bucket width in tokens (≥ 1).
        bucket: usize,
    },
}

/// Plan one group over `streams` — `(class, past_len)` pairs in candidate
/// order — into `picked` (cleared first; indices into `streams`) and
/// report whether the group is **full**: at its effective width bound, so
/// waiting longer cannot grow it (either the limit is reached or a
/// narrower stream blocks the walk). Takes the output vector by reference
/// so the pool's hot path plans into a reused scratch buffer.
fn plan_group_into(
    streams: &[(BatchClass, usize)],
    policy: DecodePolicy,
    picked: &mut Vec<usize>,
) -> bool {
    picked.clear();
    if streams.is_empty() {
        return false;
    }
    let mut limit = MAX_DECODE_GROUP;
    let mut blocked = false;
    let bucket_of = |past: usize| match policy {
        DecodePolicy::Greedy => 0,
        DecodePolicy::DepthBucketed { bucket } => past / bucket.max(1),
    };
    let head_bucket = bucket_of(streams[0].1);
    for (i, &(class, past)) in streams.iter().enumerate() {
        if picked.len() >= limit {
            break;
        }
        if bucket_of(past) != head_bucket {
            // Not a bucket-mate of the head (DepthBucketed only) — skip,
            // it will lead its own group soon (FIFO-ish).
            continue;
        }
        let width = class.batch().min(MAX_DECODE_GROUP);
        if picked.len() + 1 > width {
            // A narrower mate can't ride this group; stop the walk.
            blocked = true;
            break;
        }
        limit = limit.min(width);
        picked.push(i);
    }
    blocked || picked.len() >= limit
}

/// Allocating convenience form of [`plan_group_into`].
fn plan_group(streams: &[(BatchClass, usize)], policy: DecodePolicy) -> (Vec<usize>, bool) {
    let mut picked = Vec::new();
    let full = plan_group_into(streams, policy, &mut picked);
    (picked, full)
}

/// Form one decode group from the between-steps pool under `policy`.
///
/// Both policies pop the FIFO head first (fairness) and never group wider
/// than the narrowest member's class width — each stream's decode budget
/// was cap-clamped against KV residency at its *class's* batch width, so
/// grouping it wider would overflow the GB the clamp promised to respect
/// (B1 streams decode solo, B2 pairs, B4 fours).
pub fn form_decode_group(
    pool: &mut VecDeque<DecodeState>,
    policy: DecodePolicy,
) -> Vec<DecodeState> {
    let view: Vec<(BatchClass, usize)> = pool.iter().map(|s| (s.class, s.past_len)).collect();
    let (picked, _) = plan_group(&view, policy);
    let mut out = Vec::with_capacity(picked.len());
    for &idx in picked.iter().rev() {
        out.push(pool.remove(idx).expect("picked index valid"));
    }
    out.reverse();
    out
}

// ------------------------------------------------- coalescing decode pool

/// One parked decode stream with the instant it (re-)entered the pool.
#[derive(Debug)]
pub struct DecodeEntry {
    pub entered: Instant,
    pub state: DecodeState,
}

/// Reused planning buffers: the pool plans a group on every pop/ready/
/// deadline query on the server's decode hot path, so the candidate
/// ordering, the `(class, past_len)` view and the picked indices live in
/// scratch vectors instead of fresh allocations per token-step.
#[derive(Debug, Default)]
struct PlanScratch {
    order: Vec<usize>,
    view: Vec<(BatchClass, usize)>,
    picked: Vec<usize>,
}

/// The scheduler's between-steps pool: timestamps parked streams so a
/// coalescing window (`decode_max_wait`) can hold partial groups back for
/// bucket-mates, and optionally orders candidates by remaining tokens so
/// near-done streams drain first. Pure data structure, like the batcher —
/// the server drives it under its queue lock.
///
/// Priority is deliberately unfair: a deep stream can wait indefinitely
/// while near-done streams keep arriving (each pop still shrinks the pool,
/// so it drains whenever arrivals pause). The window's expiry is judged on
/// the *planned group*, so such a waiter never voids coalescing for
/// everyone else.
#[derive(Debug, Default)]
pub struct DecodePool {
    entries: VecDeque<DecodeEntry>,
    scratch: PlanScratch,
}

impl DecodePool {
    pub fn new() -> Self {
        DecodePool::default()
    }

    /// Park streams (all stamped `now` — one step's survivors re-enter
    /// together).
    pub fn push(&mut self, now: Instant, states: impl IntoIterator<Item = DecodeState>) {
        for state in states {
            self.entries.push_back(DecodeEntry { entered: now, state });
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Earliest coalescing deadline across parked streams — the instant the
    /// oldest entry's window expires (feeds the worker poll timeout, like
    /// the batcher's `next_deadline` feeds the ingest loop).
    pub fn next_deadline(&self, max_wait: Duration) -> Option<Instant> {
        self.entries.iter().map(|e| e.entered + max_wait).min()
    }

    /// Plan the group a pop would take right now into the scratch buffers
    /// (`scratch.picked` holds pool indices afterwards); returns fullness.
    /// Zero allocations once the scratch vectors are warm.
    fn plan_into(&mut self, policy: DecodePolicy, priority: bool) -> bool {
        let DecodePool { entries, scratch } = self;
        scratch.order.clear();
        scratch.order.extend(0..entries.len());
        if priority {
            // Unstable sort with the pool index as tie-break: identical
            // order to a stable sort (FIFO breaks remaining-token ties)
            // without the merge buffer a stable sort heap-allocates on
            // larger pools — this runs on every pop/ready/deadline query.
            scratch.order.sort_unstable_by_key(|&i| (entries[i].state.remaining, i));
        }
        scratch.view.clear();
        scratch
            .view
            .extend(scratch.order.iter().map(|&i| {
                (entries[i].state.class, entries[i].state.past_len)
            }));
        let full = plan_group_into(&scratch.view, policy, &mut scratch.picked);
        // Map view positions back to pool indices.
        for p in scratch.picked.iter_mut() {
            *p = scratch.order[*p];
        }
        full
    }

    /// Expiry instant of the scratch-planned group: its oldest member's
    /// window end. Judged on the *group*, not the whole pool — a stream
    /// the policy never picks (e.g. a deep one under priority) must not
    /// void the window for every later-arriving partial group.
    fn planned_deadline(&self, max_wait: Duration) -> Option<Instant> {
        self.scratch.picked.iter().map(|&i| self.entries[i].entered + max_wait).min()
    }

    /// Deadline at which the group a pop would form right now stops
    /// waiting (feeds the worker poll timeout; `None` when empty). Always
    /// consistent with [`DecodePool::try_pop`]'s gate, so a worker that
    /// sleeps until this instant is guaranteed a dispatch on wake.
    pub fn pop_deadline(
        &mut self,
        policy: DecodePolicy,
        max_wait: Duration,
        priority: bool,
    ) -> Option<Instant> {
        self.plan_into(policy, priority);
        self.planned_deadline(max_wait)
    }

    /// Would a pop dispatch right now? Full groups (at their effective
    /// width bound) always; partial groups only once the group's oldest
    /// member has waited out the coalescing window.
    pub fn ready(
        &mut self,
        now: Instant,
        policy: DecodePolicy,
        max_wait: Duration,
        priority: bool,
    ) -> bool {
        if self.entries.is_empty() {
            return false;
        }
        if max_wait.is_zero() {
            return true;
        }
        let full = self.plan_into(policy, priority);
        full || self.planned_deadline(max_wait).map(|d| d <= now).unwrap_or(true)
    }

    /// Remove the scratch-planned group, appending its streams to `out` in
    /// candidate order. Returns the coalescing wait its oldest member spent
    /// parked, µs (the window cost the metrics plane reports against the
    /// grouping it bought).
    fn remove_planned_into(&mut self, now: Instant, out: &mut Vec<DecodeState>) -> f64 {
        let mut picked = std::mem::take(&mut self.scratch.picked);
        picked.sort_unstable();
        let mut wait_us: f64 = 0.0;
        let start = out.len();
        for &idx in picked.iter().rev() {
            let e = self.entries.remove(idx).expect("picked index valid");
            let waited = now.saturating_duration_since(e.entered).as_nanos() as f64 / 1e3;
            wait_us = wait_us.max(waited);
            out.push(e.state);
        }
        out[start..].reverse();
        // Hand the buffer back so the next plan reuses its capacity.
        self.scratch.picked = picked;
        wait_us
    }

    /// Form and remove one group unconditionally (window already decided —
    /// see [`DecodePool::try_pop`] for the gated form).
    pub fn pop_group(
        &mut self,
        now: Instant,
        policy: DecodePolicy,
        priority: bool,
    ) -> (Vec<DecodeState>, f64) {
        let mut out = Vec::new();
        let wait_us = self.pop_group_into(now, policy, priority, &mut out);
        (out, wait_us)
    }

    /// [`DecodePool::pop_group`] into a caller-reused buffer (the worker
    /// loop's per-thread group vector) — no per-step group allocation.
    pub fn pop_group_into(
        &mut self,
        now: Instant,
        policy: DecodePolicy,
        priority: bool,
        out: &mut Vec<DecodeState>,
    ) -> f64 {
        self.plan_into(policy, priority);
        self.remove_planned_into(now, out)
    }

    /// Pop a group if one would dispatch right now — [`DecodePool::ready`]
    /// and [`DecodePool::pop_group`] fused so the group is planned exactly
    /// once (this runs under the server's queue lock on the decode hot
    /// path). `None`: empty pool, or a partial group still coalescing.
    pub fn try_pop(
        &mut self,
        now: Instant,
        policy: DecodePolicy,
        max_wait: Duration,
        priority: bool,
    ) -> Option<(Vec<DecodeState>, f64)> {
        let mut out = Vec::new();
        self.try_pop_into(now, policy, max_wait, priority, &mut out).map(|w| (out, w))
    }

    /// [`DecodePool::try_pop`] into a caller-reused buffer: the gate and
    /// the removal share one scratch plan, and the popped group lands in
    /// `out` (appended) instead of a fresh vector per token-step.
    pub fn try_pop_into(
        &mut self,
        now: Instant,
        policy: DecodePolicy,
        max_wait: Duration,
        priority: bool,
        out: &mut Vec<DecodeState>,
    ) -> Option<f64> {
        if self.entries.is_empty() {
            return None;
        }
        let full = self.plan_into(policy, priority);
        if !max_wait.is_zero() && !full {
            let expired = self.planned_deadline(max_wait).map(|d| d <= now).unwrap_or(true);
            if !expired {
                return None;
            }
        }
        Some(self.remove_planned_into(now, out))
    }

    /// Drain everything as maximal groups, ignoring the window (shutdown).
    pub fn drain_groups(&mut self, policy: DecodePolicy, priority: bool) -> Vec<Vec<DecodeState>> {
        let mut out = Vec::new();
        while !self.entries.is_empty() {
            let (group, _) = self.pop_group(Instant::now(), policy, priority);
            debug_assert!(!group.is_empty(), "pop_group must make progress");
            out.push(group);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, len, vec![0.0; len * 4])
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig { max_seq: 128, max_wait: Duration::from_millis(5) }
    }

    #[test]
    fn b1_flushes_immediately() {
        let mut b = DynamicBatcher::new(cfg());
        let out = b.push(req(1, 100)).unwrap().expect("B1 should flush at once");
        assert_eq!(out.class, BatchClass::B1);
        assert_eq!(out.requests.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn b4_waits_for_four() {
        let mut b = DynamicBatcher::new(cfg());
        for i in 0..3 {
            assert!(b.push(req(i, 20)).unwrap().is_none());
        }
        assert_eq!(b.pending(), 3);
        let out = b.push(req(3, 20)).unwrap().expect("4th request completes the batch");
        assert_eq!(out.class, BatchClass::B4);
        assert_eq!(out.requests.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn classes_do_not_mix() {
        let mut b = DynamicBatcher::new(cfg());
        assert!(b.push(req(1, 20)).unwrap().is_none()); // B4
        assert!(b.push(req(2, 50)).unwrap().is_none()); // B2
        assert!(b.push(req(3, 20)).unwrap().is_none()); // B4
        let out = b.push(req(4, 50)).unwrap().expect("two B2s form a batch");
        assert_eq!(out.class, BatchClass::B2);
        assert_eq!(out.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert_eq!(b.pending(), 2); // the two B4s still queued
    }

    #[test]
    fn deadline_flushes_partial() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_seq: 128,
            max_wait: Duration::from_millis(0),
        });
        assert!(b.push(req(1, 20)).unwrap().is_none());
        let flushed = b.poll_deadline(Instant::now() + Duration::from_millis(1));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].requests.len(), 1); // partial B4
        assert_eq!(flushed[0].class, BatchClass::B4);
    }

    #[test]
    fn rejects_oversized() {
        let mut b = DynamicBatcher::new(cfg());
        assert!(b.push(req(1, 500)).is_err());
        assert!(b.push(req(1, 0)).is_err());
    }

    #[test]
    fn drain_empties_all() {
        let mut b = DynamicBatcher::new(cfg());
        b.push(req(1, 20)).unwrap();
        b.push(req(2, 50)).unwrap();
        b.push(req(3, 90)).unwrap(); // B1 flushes immediately
        let batches = b.drain();
        assert_eq!(batches.iter().map(|f| f.requests.len()).sum::<usize>(), 2);
        assert_eq!(b.pending(), 0);
    }

    fn stream(id: u64, class: BatchClass, past_len: usize) -> DecodeState {
        DecodeState::stub(id, class, past_len)
    }

    fn pool_of(streams: Vec<DecodeState>) -> VecDeque<DecodeState> {
        streams.into_iter().collect()
    }

    fn pad_waste(group: &[DecodeState]) -> usize {
        let max = group.iter().map(|s| s.past_len).max().unwrap_or(0);
        group.iter().map(|s| max - s.past_len).sum()
    }

    #[test]
    fn greedy_groups_fifo_up_to_narrowest_width() {
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 10),
            stream(1, BatchClass::B4, 50),
            stream(2, BatchClass::B4, 11),
            stream(3, BatchClass::B4, 12),
            stream(4, BatchClass::B4, 13),
        ]);
        let g = form_decode_group(&mut pool, DecodePolicy::Greedy);
        assert_eq!(g.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(pool.len(), 1);
        // A B1 head decodes solo; a B1 mid-queue stops the group before it.
        let mut pool = pool_of(vec![stream(0, BatchClass::B1, 5), stream(1, BatchClass::B4, 5)]);
        let g = form_decode_group(&mut pool, DecodePolicy::Greedy);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].id, 0);
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 5),
            stream(1, BatchClass::B1, 5),
            stream(2, BatchClass::B4, 5),
        ]);
        let g = form_decode_group(&mut pool, DecodePolicy::Greedy);
        assert_eq!(g.len(), 1, "B1 can't ride a pair — group stops at it");
    }

    #[test]
    fn depth_bucketed_bounds_pad_waste() {
        // Greedy over a mixed-depth pool pads shallow streams to the
        // deepest rider; bucketed grouping keeps the spread ≤ bucket−1.
        let streams = || {
            vec![
                stream(0, BatchClass::B4, 4),
                stream(1, BatchClass::B4, 64),
                stream(2, BatchClass::B4, 5),
                stream(3, BatchClass::B4, 6),
                stream(4, BatchClass::B4, 70),
            ]
        };
        let mut greedy_pool = pool_of(streams());
        let greedy = form_decode_group(&mut greedy_pool, DecodePolicy::Greedy);
        assert!(pad_waste(&greedy) >= 60, "greedy pads 4..64: {}", pad_waste(&greedy));

        let bucket = 8;
        let mut pool = pool_of(streams());
        let g1 = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket });
        assert_eq!(g1.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 2, 3]);
        assert!(pad_waste(&g1) <= (bucket - 1) * g1.len());
        // The deep streams lead the next group.
        let g2 = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket });
        assert_eq!(g2.iter().map(|s| s.id).collect::<Vec<_>>(), vec![1, 4]);
        assert!(pool.is_empty());
    }

    #[test]
    fn depth_bucketed_head_always_leads_and_pool_drains() {
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 100),
            stream(1, BatchClass::B4, 3),
            stream(2, BatchClass::B4, 101),
            stream(3, BatchClass::B1, 102),
        ]);
        let mut seen = Vec::new();
        let mut guard = 0;
        while !pool.is_empty() {
            let g = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket: 16 });
            assert!(!g.is_empty(), "progress on every call");
            seen.extend(g.iter().map(|s| s.id));
            guard += 1;
            assert!(guard < 10);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3], "every stream exits exactly once");
    }

    #[test]
    fn depth_bucketed_respects_class_width() {
        // Two streams in one bucket, but the second is B2: the group is
        // bounded by the narrowest member's width (2), and a third
        // bucket-mate can't join.
        let mut pool = pool_of(vec![
            stream(0, BatchClass::B4, 8),
            stream(1, BatchClass::B2, 9),
            stream(2, BatchClass::B4, 10),
        ]);
        let g = form_decode_group(&mut pool, DecodePolicy::DepthBucketed { bucket: 16 });
        assert_eq!(g.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn poll_deadline_drains_every_expired_width_in_one_call() {
        // Regression: poll_deadline emitted at most ONE partial batch per
        // class per call, so a burst that grew a queue past a batch width
        // serialized through poll ticks. Admission normally flushes full
        // widths eagerly; the queue state is stuffed directly here so the
        // poll path stays robust to any producer.
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_seq: 128,
            max_wait: Duration::from_millis(0),
        });
        for id in 0..9 {
            b.queues[slot(BatchClass::B4)].push_back(req(id, 20));
        }
        b.queues[slot(BatchClass::B2)].push_back(req(100, 50));
        let out = b.poll_deadline(Instant::now() + Duration::from_millis(1));
        // 9 B4 → 4 + 4 + 1, plus the B2 partial: four batches, one call.
        assert_eq!(out.len(), 4, "burst must drain in one poll: {out:?}");
        assert_eq!(out.iter().map(|f| f.requests.len()).sum::<usize>(), 10);
        assert_eq!(b.pending(), 0, "nothing left for a second tick");
    }

    #[test]
    fn decode_pool_full_groups_dispatch_immediately() {
        let mut p = DecodePool::new();
        let now = Instant::now();
        p.push(now, (0..4).map(|i| stream(i, BatchClass::B4, 5)));
        let window = Duration::from_secs(3600);
        assert!(p.ready(now, DecodePolicy::Greedy, window, false), "full group never waits");
        let (g, wait_us) = p.pop_group(now, DecodePolicy::Greedy, false);
        assert_eq!(g.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(wait_us < 1e3, "no coalescing wait was paid: {wait_us}");
        assert!(p.is_empty());
        // A solo B1 is full at width 1 — no pointless wait either.
        p.push(now, [stream(9, BatchClass::B1, 7)]);
        assert!(p.ready(now, DecodePolicy::Greedy, window, false));
    }

    #[test]
    fn decode_pool_coalescing_window_holds_partial_groups() {
        let mut p = DecodePool::new();
        let t0 = Instant::now();
        p.push(t0, (0..2).map(|i| stream(i, BatchClass::B4, 5)));
        let window = Duration::from_millis(50);
        assert!(!p.ready(t0, DecodePolicy::Greedy, window, false), "partial group waits");
        assert_eq!(p.next_deadline(window), Some(t0 + window));
        // Window expired: the partial group dispatches, wait recorded.
        let later = t0 + Duration::from_millis(60);
        assert!(p.ready(later, DecodePolicy::Greedy, window, false));
        let (g, wait_us) = p.pop_group(later, DecodePolicy::Greedy, false);
        assert_eq!(g.len(), 2);
        assert!(wait_us >= 50_000.0, "coalesce wait measured in µs: {wait_us}");
        // Window 0 is the seed behavior: dispatch whatever waits, at once.
        p.push(t0, [stream(7, BatchClass::B4, 5)]);
        assert!(p.ready(t0, DecodePolicy::Greedy, Duration::ZERO, false));
        // try_pop fuses gate + pop: None while the window holds, the group
        // once it expires (or with the window off).
        assert!(p.try_pop(t0, DecodePolicy::Greedy, window, false).is_none());
        assert_eq!(p.len(), 1, "a held pop removes nothing");
        let (g, _) = p.try_pop(t0, DecodePolicy::Greedy, Duration::ZERO, false).unwrap();
        assert_eq!(g.len(), 1);
        assert!(p.is_empty());
        assert!(p.try_pop(t0, DecodePolicy::Greedy, Duration::ZERO, false).is_none());
        // Shutdown ignores the window entirely.
        p.push(t0, [stream(8, BatchClass::B4, 5)]);
        let groups = p.drain_groups(DecodePolicy::Greedy, false);
        assert_eq!(groups.len(), 1);
        assert!(p.is_empty());
    }

    #[test]
    fn pop_into_reuses_the_caller_buffer() {
        // Satellite acceptance: the worker's group buffer is refilled in
        // place — no reallocation once its capacity covers a group.
        let now = Instant::now();
        let mut p = DecodePool::new();
        let mut buf: Vec<DecodeState> = Vec::with_capacity(MAX_DECODE_GROUP);
        p.push(now, (0..4).map(|i| stream(i, BatchClass::B4, 5)));
        let w = p.try_pop_into(now, DecodePolicy::Greedy, Duration::ZERO, false, &mut buf);
        assert!(w.is_some());
        assert_eq!(buf.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let cap = buf.capacity();
        buf.clear();
        p.push(now, (4..6).map(|i| stream(i, BatchClass::B2, 5)));
        p.try_pop_into(now, DecodePolicy::Greedy, Duration::ZERO, false, &mut buf).unwrap();
        assert_eq!(buf.iter().map(|s| s.id).collect::<Vec<_>>(), vec![4, 5]);
        assert_eq!(buf.capacity(), cap, "buffer reused, not reallocated");
        // An empty pool is a clean None, buffer untouched.
        buf.clear();
        assert!(p
            .try_pop_into(now, DecodePolicy::Greedy, Duration::ZERO, false, &mut buf)
            .is_none());
        assert!(buf.is_empty());
    }

    fn stream_left(id: u64, class: BatchClass, past: usize, remaining: usize) -> DecodeState {
        let mut s = DecodeState::stub(id, class, past);
        s.remaining = remaining;
        s
    }

    #[test]
    fn decode_pool_priority_drains_near_done_streams_first() {
        let now = Instant::now();
        let mut p = DecodePool::new();
        p.push(
            now,
            vec![
                stream_left(0, BatchClass::B4, 5, 9),
                stream_left(1, BatchClass::B4, 5, 3),
                stream_left(2, BatchClass::B4, 5, 1),
                stream_left(3, BatchClass::B4, 5, 7),
                stream_left(4, BatchClass::B4, 5, 2),
            ],
        );
        let (g, _) = p.pop_group(now, DecodePolicy::Greedy, true);
        let mut ids: Vec<_> = g.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3, 4], "the deepest stream (9 left) waits its turn");
        assert_eq!(p.len(), 1);
        // Without priority the pool is plain FIFO.
        p.push(now, vec![stream_left(9, BatchClass::B4, 5, 1)]);
        let (g, _) = p.pop_group(now, DecodePolicy::Greedy, false);
        assert_eq!(g.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 9]);
        // A near-done B1 leads — and decodes solo, class width intact.
        let mut p = DecodePool::new();
        p.push(
            now,
            vec![stream_left(0, BatchClass::B4, 5, 9), stream_left(1, BatchClass::B1, 20, 1)],
        );
        let (g, _) = p.pop_group(now, DecodePolicy::Greedy, true);
        assert_eq!(g.iter().map(|s| s.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn property_all_requests_exit_exactly_once() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut b = DynamicBatcher::new(cfg());
        let mut seen = std::collections::BTreeSet::new();
        let n = 300;
        for id in 0..n {
            let len = rng.range(1, 128);
            if let Some(f) = b.push(req(id, len)).unwrap() {
                for r in f.requests {
                    assert!(seen.insert(r.id), "duplicate {}", r.id);
                }
            }
        }
        for f in b.drain() {
            for r in f.requests {
                assert!(seen.insert(r.id), "duplicate {}", r.id);
            }
        }
        assert_eq!(seen.len(), n as usize);
    }
}
