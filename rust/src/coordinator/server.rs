//! Multi-worker serving pool: ingest → dynamic batch → shared work queue →
//! N engine workers → respond.
//!
//! One **admission/ingest thread** owns the [`DynamicBatcher`]: clients
//! submit through an mpsc channel, the ingest thread classifies and groups
//! requests, and every formed batch lands on a shared bounded work queue.
//! **N engine workers** (configurable; defaults to an
//! `available_parallelism` heuristic) each construct their own [`Engine`]
//! (executables are not `Send`) and pull batches with **class-affinity
//! scheduling**: a worker that just ran a class prefers the next batch of
//! the same class — its reconfigured plane and parameters are warm, the
//! paper's B4 reuse argument — bounded by an aging window so FIFO order and
//! deadlines are never starved. All engines share one [`SimCache`] so every
//! pass is simulated exactly once process-wide.
//!
//! **Token-level continuous batching**: a generate request's prefill turns
//! it into a [`DecodeState`] that re-enters the shared queue after *every*
//! decode step. Workers pull decode groups of up to
//! [`crate::coordinator::engine::MAX_DECODE_GROUP`] streams under the
//! pool's [`DecodePolicy`] — greedy FIFO at whatever KV depths, or
//! depth-bucketed to bound pad waste — always bounded by the narrowest
//! member's class width so the per-class KV-residency cap each stream was
//! admitted under keeps holding. Streams join and leave batches between
//! steps and freshly-prefilled requests merge into in-flight generations.
//! Per-token results stream on a dedicated channel
//! ([`ServerHandle::tokens`]) while the final response still arrives on
//! `responses`. A worker with both kinds of work alternates prefill/decode
//! so neither side starves.
//!
//! **Chunked prefill + priority scheduling** (`PoolConfig::prefill_chunk`,
//! `decode_max_wait`, `decode_priority`): with chunking on, a prefill runs
//! `prefill_chunk` phases at a time and parks as a
//! [`crate::coordinator::engine::PrefillState`] in the shared pool between
//! chunks, so one long pass never monopolizes a worker — decode steps
//! interleave mid-prefill (the T-REX utilization argument applied to the
//! serving plane). The worker's pop order is a priority policy: decode
//! groups that are *ready* (full at their class-width bound, or oldest
//! member past the coalescing window) go first, near-done streams drain
//! before deep ones (`decode_priority`), and parked prefill chunks fill
//! the gaps ahead of fresh batches. Workers waiting on a coalescing
//! window sleep until the pool's next deadline
//! ([`crate::coordinator::batcher::DecodePool::next_deadline`]) — the
//! decode-side analogue of the ingest loop's batcher deadline. A prefill
//! shed mid-chunk releases its first-chunk KV registrations.
//!
//! **Aggregate KV residency**: with a [`KvManager`] configured
//! ([`PoolConfig::kv`]), generate admissions are additionally bounded by
//! projected KV-arena bytes, and the engines (sharing the same manager via
//! [`WorkerCtx::kv`]) charge swap-in EMA whenever an evicted stream
//! rejoins a step — parked KV is never free.
//!
//! **Backpressure**: admission rejects (`Error::Serve`) once the in-flight
//! request count or the work-queue depth crosses the configured bound, so
//! saturated traffic sheds at the door instead of growing queues without
//! limit. Generate requests hold their in-flight slot until the final
//! response. (std threads + mpsc — tokio is not vendored offline,
//! DESIGN.md §2.)

use crate::coordinator::batcher::{
    BatcherConfig, DecodePolicy, DecodePool, DynamicBatcher, FormedBatch,
};
use crate::coordinator::engine::{
    DecodeState, Engine, PrefillProgress, PrefillState, MAX_DECODE_GROUP,
};
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::request::{Request, Response, TokenEvent};
use crate::coordinator::sim_cache::{CacheStats, SimCache};
use crate::control::{ControlState, DvfsGovernor, GovernorConfig, GovernorObs, SloTarget};
use crate::error::{Error, Result};
use crate::fleet::Fleet;
use crate::kv::KvManager;
use crate::obs::{
    dump_anomaly, FlightRecorder, Snapshot, SpanEvent, SpanKind, SpanWriter, Telemetry,
    TelemetryConfig,
};
use crate::sim::{batch_class, BatchClass, PlanRegistry};
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

enum Msg {
    Req(Request),
    Shutdown,
}

/// One unit of worker work.
enum WorkItem {
    /// A formed prefill batch from the ingest thread.
    Prefill(FormedBatch),
    /// A chunked prefill parked between chunks, ready to resume (boxed —
    /// it carries a suspended simulation).
    PrefillChunk(Box<PrefillState>),
    /// A decode group regrouped from the between-steps pool — the streams
    /// were popped into the calling worker's reusable group buffer (no
    /// per-step group allocation).
    Decode {
        /// A prefill was parked mid-flight when this group dispatched —
        /// the step interleaves with it.
        interleaved: bool,
        /// Coalescing wait the group's oldest member paid, µs.
        coalesce_wait_us: f64,
    },
}

/// A worker may jump the global FIFO for a warm same-class batch only if
/// that batch is within this many admissions of the oldest waiting batch.
const AFFINITY_WINDOW: u64 = 8;

/// Heuristic worker count: one per available core, capped — engine work is
/// compute-bound, extra workers past the core count only add contention.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(1, 16)
}

/// Pool sizing and admission policy.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Engine workers to spawn.
    pub workers: usize,
    /// Max formed batches waiting in the shared work queue before admission
    /// rejects (0 = unbounded).
    pub queue_depth: usize,
    /// Max requests admitted but not yet responded before admission rejects
    /// (0 = unbounded).
    pub max_inflight: usize,
    /// Warm-worker class-affinity scheduling (see module docs).
    pub affinity: bool,
    /// How decode streams regroup between steps (greedy FIFO or
    /// depth-bucketed — see [`DecodePolicy`]).
    pub decode: DecodePolicy,
    /// Decode coalescing window: a *partial* group may wait this long for
    /// mates before stepping, so steps run fuller and the per-token share
    /// of the step's weight stream drops. Full-width groups never wait.
    /// `Duration::ZERO` (default) steps whatever waits — the seed behavior.
    pub decode_max_wait: Duration,
    /// Near-done-first priority: order the between-steps pool by remaining
    /// tokens so short streams drain (and free KV pages + in-flight slots)
    /// before deep ones. Off by default (FIFO).
    pub decode_priority: bool,
    /// Chunked prefill: phases per chunk (0 = monolithic, the seed
    /// behavior). With chunking on, long prefills park between chunks so
    /// decode steps interleave mid-prefill instead of stalling behind the
    /// whole pass.
    pub prefill_chunk: usize,
    /// Pool-wide KV-cache manager: when set, admission bounds generate
    /// requests by projected arena bytes ([`KvManager::try_admit`]), and
    /// the same `Arc` reaches every worker's engine factory through
    /// [`WorkerCtx::kv`] (use [`Engine::for_worker`]) so residency,
    /// eviction and swap-in charging are pool-wide. `None`: each engine
    /// keeps a private manager and admission skips the KV bound.
    pub kv: Option<Arc<KvManager>>,
    /// Disaggregated heterogeneous fleet ([`crate::fleet`]): when set, the
    /// pool binds worker *i* to chip *i* (forcing `workers ==
    /// fleet.n_chips()`), the work queue keeps per-chip lanes, prefill
    /// batches round-robin over prefill-capable chips, decode streams hash
    /// to decode-capable chips by prefix group, admission projects KV
    /// bytes against the *decode-target* chip's arena, and a stream that
    /// prefills on one chip and decodes on another pays a priced KV
    /// migration. Overrides `workers` and `kv` (each chip carries its own
    /// manager). `None` (default): the single-chip pool, byte-identical to
    /// the pre-fleet behavior.
    pub fleet: Option<Arc<Fleet>>,
    /// Per-request lifecycle ledger on the pooled metrics sink: every
    /// admission is tracked to exactly one terminal (completed or shed),
    /// auditable via [`ServerMetrics::ledger_audit`]. Off by default — the
    /// ledger keeps one entry per request ever admitted, which is unbounded
    /// memory under sustained traffic; the replay driver, the fuzzer, and
    /// conservation tests turn it on.
    pub lifecycle_ledger: bool,
    /// Flight recorder for span tracing: when set, the door, every worker
    /// engine, and the KV arena record lifecycle spans into its lanes
    /// (see [`crate::obs`]). `None` (default): tracing off — every record
    /// site reduces to a branch on `None`, no locks, no allocation (gated
    /// by the `hotpath_micro` bench).
    pub recorder: Option<Arc<FlightRecorder>>,
    /// Time-series sampler: when set, a sampler thread captures one
    /// [`Snapshot`] per interval into a [`Telemetry`] ring (and optional
    /// JSONL stream), and watches for shed storms (see
    /// [`TelemetryConfig`]). A pool with a governor or SLO configured but
    /// no telemetry synthesizes a default config — the control plane rides
    /// the sampler thread, so it must exist.
    pub telemetry: Option<TelemetryConfig>,
    /// SLO targets for the control plane: the sampler gates generate
    /// admission on interval decode-p95 breaches (with hysteresis), and
    /// the DVFS governor (when on) uses the target to qualify drops.
    /// `None` (default): no gate, no SLO term in governor decisions.
    pub slo: Option<SloTarget>,
    /// Runtime DVFS governor ([`crate::control`]): rides the sampler
    /// thread, re-points each chip within its fig7 table from queue depth,
    /// KV occupancy and interval percentiles. Requires a `fleet` (the
    /// governor steers per-chip operating points) — ignored without one.
    /// `None` (default): chips hold their build-time points forever and
    /// the pool's behavior is identical to a governor-less build.
    pub governor: Option<GovernorConfig>,
    pub batcher: BatcherConfig,
}

impl PoolConfig {
    pub fn with_workers(workers: usize, batcher: BatcherConfig) -> Self {
        PoolConfig { workers: workers.max(1), batcher, ..PoolConfig::default() }
    }

    /// Single-worker pool (the pre-pool server shape: one engine thread,
    /// no admission bounds — the legacy `Server::start` contract where
    /// `submit` only fails when the server is down).
    pub fn single(batcher: BatcherConfig) -> Self {
        PoolConfig { workers: 1, queue_depth: 0, max_inflight: 0, batcher, ..PoolConfig::default() }
    }
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: default_workers(),
            queue_depth: 256,
            max_inflight: 4096,
            affinity: true,
            decode: DecodePolicy::Greedy,
            decode_max_wait: Duration::ZERO,
            decode_priority: false,
            prefill_chunk: 0,
            kv: None,
            fleet: None,
            lifecycle_ledger: false,
            recorder: None,
            telemetry: None,
            slo: None,
            governor: None,
            batcher: BatcherConfig::default(),
        }
    }
}

/// Everything a worker's engine factory gets handed: its index, the
/// pool-wide simulation cache, and the pool-wide KV manager when one was
/// configured (pass both through [`Engine::for_worker`]).
pub struct WorkerCtx {
    pub worker: usize,
    pub sim_cache: Arc<SimCache>,
    /// Pool-wide compiled decode step-plan registry: every `(group,
    /// quant)` plan is compiled once across all workers (pass through
    /// [`Engine::for_worker`], like the sim cache).
    pub plans: Arc<PlanRegistry>,
    /// The pool's shared KV-cache manager (`PoolConfig::kv`), if any.
    pub kv: Option<Arc<KvManager>>,
    /// Fallback shared slot when `kv` is `None`: the first engine built via
    /// [`Engine::for_worker`] installs its manager here and every later
    /// worker adopts it — decode streams hop workers through the shared
    /// queue, so per-worker private arenas would leak entries and miss
    /// eviction/swap charges. One pool, one arena.
    pub kv_shared: Arc<OnceLock<Arc<KvManager>>>,
    /// Span writer bound to this worker's flight-recorder lane (`None`
    /// when tracing is off). [`Engine::for_worker`] adopts it.
    pub obs: Option<SpanWriter>,
    /// The pool's fleet ([`PoolConfig::fleet`]), if any. This worker is
    /// bound to chip `worker`: [`Engine::for_worker`] adopts that chip's
    /// pinned [`crate::config::HwConfig`] (overriding the factory's) and
    /// compiles its step plans under a per-chip registry scope; `kv` is
    /// already that chip's manager.
    pub fleet: Option<Arc<Fleet>>,
}

// ---------------------------------------------------------------- work queue

/// One chip's work lanes. A single-chip pool has exactly one (index 0,
/// shared by every worker — the pre-fleet shape); a fleet pool has one per
/// chip, and worker *i* only ever pops lane *i* — placement is decided at
/// push time (ingest routes prefill, `route_decode` routes streams), not
/// by whichever worker wakes first.
#[derive(Default)]
struct ChipQueues {
    /// Per-class FIFO of `(admission seq, batch)`.
    queues: [VecDeque<(u64, FormedBatch)>; 3],
    /// Chunked prefills parked between chunks, FIFO.
    parked: VecDeque<Box<PrefillState>>,
    /// Decode streams waiting between steps — regrouped on every pop, so
    /// batch membership is continuous, not fixed at prefill time.
    decode: DecodePool,
}

impl ChipQueues {
    fn prefill_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }
}

struct QueueState {
    /// Per-chip lanes (always at least one).
    chips: Vec<ChipQueues>,
    next_seq: u64,
    /// Total queued prefill batches across all chips (admission bound).
    len: usize,
    closed: bool,
}

/// Shared work queue: per-class prefill subqueues + parked prefill chunks
/// + the decode pool under one lock so workers can apply class affinity
/// and the priority policy while preserving bounded-age FIFO fairness.
struct WorkQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
    /// Lock-free length mirror for the admission path (prefill batches).
    len_hint: AtomicUsize,
    /// Prefill chunks currently executing on some worker (between pop and
    /// park/complete). Parked chunks live in `QueueState::parked`; this
    /// covers the in-flight ones so multi-worker pools count a decode step
    /// as interleaved when the chunk runs on a *different* worker too.
    chunks_executing: AtomicUsize,
    affinity: bool,
    /// Decode regrouping policy ([`DecodePool`]).
    decode: DecodePolicy,
    /// Coalescing window for partial decode groups.
    decode_max_wait: Duration,
    /// Near-done-first decode ordering.
    decode_priority: bool,
    /// Chip lanes (1 without a fleet). With more than one, pushes wake
    /// every waiter — a single `notify_one` could land on a worker bound
    /// to a different chip and strand the work.
    n_chips: usize,
}

impl WorkQueue {
    fn new(
        n_chips: usize,
        affinity: bool,
        decode: DecodePolicy,
        decode_max_wait: Duration,
        decode_priority: bool,
    ) -> Self {
        let n_chips = n_chips.max(1);
        WorkQueue {
            state: Mutex::new(QueueState {
                chips: (0..n_chips).map(|_| ChipQueues::default()).collect(),
                next_seq: 0,
                len: 0,
                closed: false,
            }),
            ready: Condvar::new(),
            len_hint: AtomicUsize::new(0),
            chunks_executing: AtomicUsize::new(0),
            affinity,
            decode,
            decode_max_wait,
            decode_priority,
            n_chips,
        }
    }

    /// Wake waiters after a push: one suffices when every worker serves
    /// the same (only) lane; with per-chip lanes the push must reach the
    /// one worker bound to that chip, so wake everyone.
    fn notify_push(&self) {
        if self.n_chips > 1 {
            self.ready.notify_all();
        } else {
            self.ready.notify_one();
        }
    }

    /// A worker is about to execute one prefill chunk.
    fn chunk_started(&self) {
        self.chunks_executing.fetch_add(1, Ordering::AcqRel);
    }

    /// The chunk finished (parked again, completed, or shed).
    fn chunk_finished(&self) {
        self.chunks_executing.fetch_sub(1, Ordering::AcqRel);
    }

    fn push(&self, chip: usize, batch: FormedBatch) {
        let mut s = self.state.lock().unwrap();
        let seq = s.next_seq;
        s.next_seq += 1;
        s.chips[chip].queues[batch.class.index()].push_back((seq, batch));
        s.len += 1;
        self.len_hint.store(s.len, Ordering::Relaxed);
        self.notify_push();
    }

    /// Park a chunked prefill between chunks — any worker of its chip may
    /// resume it.
    fn push_parked(&self, chip: usize, state: Box<PrefillState>) {
        let mut s = self.state.lock().unwrap();
        s.chips[chip].parked.push_back(state);
        self.notify_push();
    }

    /// Return decode streams to the between-steps pool of `chip`. Called
    /// after every step (and after prefill for streams entering decode) —
    /// the next pop regroups whatever is waiting.
    fn push_decode(&self, chip: usize, states: Vec<DecodeState>) {
        if states.is_empty() {
            return;
        }
        let mut s = self.state.lock().unwrap();
        s.chips[chip].decode.push(Instant::now(), states);
        // One push can seed more than one group — wake everyone waiting.
        self.ready.notify_all();
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    fn len(&self) -> usize {
        self.len_hint.load(Ordering::Relaxed)
    }

    /// Per-chip work depth (queued prefill batches + parked chunks + decode
    /// streams between steps) — the governor's real, wall-clock burst
    /// signal. One lock acquisition for all lanes.
    fn depths(&self) -> Vec<usize> {
        let s = self.state.lock().unwrap();
        s.chips.iter().map(|c| c.prefill_len() + c.parked.len() + c.decode.len()).collect()
    }

    /// Block for the next work item; `None` once the queue is closed and
    /// drained. `warm` is the class the calling worker last executed;
    /// `prefer_prefill` breaks ties when both kinds of work wait (workers
    /// alternate so decode streams keep flowing *and* new requests keep
    /// prefilled streams joining them — with chunking on, the alternation
    /// is what interleaves decode steps between a prefill's chunks).
    /// `group_buf` is the worker's reusable decode-group buffer: a
    /// [`WorkItem::Decode`] return means the group was popped into it.
    ///
    /// Priority order: ready decode groups (full at their width bound, or
    /// past the coalescing window) → parked prefill chunks → fresh prefill
    /// batches. A worker whose only work is a still-coalescing partial
    /// group sleeps until the pool's next deadline. Work held by an
    /// executing worker (a decode group mid-step, a chunk mid-execution)
    /// is invisible here — that worker re-pushes and re-pops it, so a
    /// closed, momentarily-empty queue never strands work.
    fn pop(
        &self,
        chip: usize,
        warm: Option<BatchClass>,
        prefer_prefill: bool,
        group_buf: &mut Vec<DecodeState>,
    ) -> Option<WorkItem> {
        debug_assert!(group_buf.is_empty(), "caller must drain the group buffer between pops");
        let mut s = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            // A closed queue voids coalescing windows: drain everything.
            let max_wait = if s.closed { Duration::ZERO } else { self.decode_max_wait };
            let chunks_executing = self.chunks_executing.load(Ordering::Relaxed);
            {
                // Scoped: the chip-lane borrow must end before `choose`
                // and the condvar waits below re-borrow the whole state.
                let c = &mut s.chips[chip];
                let has_prefill = c.prefill_len() > 0 || !c.parked.is_empty();
                if !(prefer_prefill && has_prefill) {
                    let popped = c.decode.try_pop_into(
                        now,
                        self.decode,
                        max_wait,
                        self.decode_priority,
                        group_buf,
                    );
                    if let Some(coalesce_wait_us) = popped {
                        // A prefill is mid-flight: parked here, or a chunk
                        // executing on another worker right now.
                        let interleaved = !c.parked.is_empty() || chunks_executing > 0;
                        return Some(WorkItem::Decode { interleaved, coalesce_wait_us });
                    }
                }
                // Parked chunks resume before fresh batches start:
                // in-flight passes finish first, bounding parked state.
                if let Some(st) = c.parked.pop_front() {
                    return Some(WorkItem::PrefillChunk(st));
                }
            }
            if s.chips[chip].prefill_len() > 0 {
                let batch = self.choose(&mut s, chip, warm);
                self.len_hint.store(s.len, Ordering::Relaxed);
                return Some(WorkItem::Prefill(batch));
            }
            if !s.chips[chip].decode.is_empty() {
                // Only still-coalescing streams remain: sleep until the
                // would-be group's window expires (or new work notifies).
                // pop_deadline is consistent with try_pop's gate, so the
                // wake is guaranteed a dispatch — no spin.
                let deadline = s.chips[chip]
                    .decode
                    .pop_deadline(self.decode, self.decode_max_wait, self.decode_priority)
                    .expect("non-empty decode pool plans a group");
                let wait = deadline.saturating_duration_since(now);
                if wait.is_zero() {
                    continue;
                }
                let (guard, _timeout) = self.ready.wait_timeout(s, wait).unwrap();
                s = guard;
                continue;
            }
            if s.closed {
                // This chip's lanes are dry; other chips' lanes drain
                // through their own bound workers.
                return None;
            }
            s = self.ready.wait(s).unwrap();
        }
    }

    fn choose(&self, s: &mut QueueState, chip: usize, warm: Option<BatchClass>) -> FormedBatch {
        let queues = &mut s.chips[chip].queues;
        let oldest_idx = (0..3)
            .filter(|&i| !queues[i].is_empty())
            .min_by_key(|&i| queues[i].front().map(|(seq, _)| *seq).unwrap_or(u64::MAX))
            .expect("choose called on non-empty queue");
        let oldest_seq = queues[oldest_idx].front().expect("non-empty").0;
        let take = match warm {
            Some(class) if self.affinity => {
                let wi = class.index();
                match queues[wi].front() {
                    // Warm jump allowed only within the aging window.
                    Some(&(seq, _)) if seq <= oldest_seq + AFFINITY_WINDOW => wi,
                    _ => oldest_idx,
                }
            }
            _ => oldest_idx,
        };
        let (_, batch) = queues[take].pop_front().expect("selected queue non-empty");
        s.len -= 1;
        batch
    }
}

// -------------------------------------------------------------------- handle

/// Cloneable submit-side handle: each client thread takes its own clone
/// (via [`ServerHandle::submitter`]) and admits requests independently —
/// the admission counters and limits are shared across all clones.
#[derive(Clone)]
pub struct Submitter {
    tx: Sender<Msg>,
    metrics: Arc<ServerMetrics>,
    queue: Arc<WorkQueue>,
    inflight: Arc<AtomicUsize>,
    /// KV-arena admission for generate requests (None = unbounded).
    kv: Option<Arc<KvManager>>,
    /// Fleet placement: when set, a generate request's KV projection is
    /// charged against its *decode-target* chip's arena (the chip the
    /// prefix-group hash will decode it on), so each chip sheds at its own
    /// budget instead of one global bound.
    fleet: Option<Arc<Fleet>>,
    /// Admission-door span writer (admit/door-shed markers).
    obs: Option<SpanWriter>,
    /// Control-plane state: when the sampler's SLO gate latches shedding,
    /// generate admissions reject at the door until the breach clears.
    control: Option<Arc<ControlState>>,
    /// Send gate: submits hold the read side across the closed-check +
    /// send, shutdown takes the write side to flip it — so no send can be
    /// in flight when the pool closes, and a submit that returned `Ok` is
    /// always drained by the ingest thread.
    closed: Arc<RwLock<bool>>,
    queue_depth: usize,
    max_inflight: usize,
    max_seq: usize,
}

impl Submitter {
    /// Admit a request. Rejects with `Error::Serve` when the request is
    /// unservable (bad length) or the pool is saturated (in-flight or
    /// queue-depth bound hit) — the backpressure contract: callers retry,
    /// shed, or slow down.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.try_submit(req).map_err(|(_, e)| e)
    }

    /// Like [`Self::submit`], but hands the request back on rejection so a
    /// backpressure-aware client can drain responses and retry.
    pub fn try_submit(&self, req: Request) -> std::result::Result<(), (Request, Error)> {
        // Validate at the door: an unservable length must fail the caller,
        // not vanish in the ingest thread with no response ever coming.
        // (The class also fixes the width the KV projection clamps at.)
        let class = match batch_class(req.len, self.max_seq) {
            Ok(class) => class,
            Err(e) => {
                self.metrics.record_rejected();
                self.mark_door_shed(req.id);
                return Err((req, e));
            }
        };
        // Hold the gate's read side for the rest of admission: shutdown
        // can't flip `closed` (write side) until this send has completed.
        let gate = self.closed.read().unwrap();
        if *gate {
            return Err((req, Error::serve("server is shutting down".to_string())));
        }
        let inflight = self.inflight.fetch_add(1, Ordering::AcqRel);
        if self.max_inflight > 0 && inflight >= self.max_inflight {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.metrics.record_rejected();
            self.mark_door_shed(req.id);
            return Err((
                req,
                Error::serve(format!(
                    "overloaded: {inflight} requests in flight (max {})",
                    self.max_inflight
                )),
            ));
        }
        if self.queue_depth > 0 && self.queue.len() >= self.queue_depth {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.metrics.record_rejected();
            self.mark_door_shed(req.id);
            return Err((
                req,
                Error::serve(format!(
                    "overloaded: {} batches queued (depth {})",
                    self.queue.len(),
                    self.queue_depth
                )),
            ));
        }
        // SLO gate: while the sampler has a decode-p95 breach latched, the
        // door sheds generate traffic (new decode load is what digs the
        // breach deeper; encode-only requests pass — they hold no decode
        // residency). Checked before the KV projection so a shed request
        // never touches an arena.
        if req.generate > 0 {
            if let Some(ctl) = &self.control {
                if ctl.shedding() {
                    self.inflight.fetch_sub(1, Ordering::AcqRel);
                    self.metrics.record_rejected();
                    ctl.note_door_shed();
                    self.mark_door_shed(req.id);
                    return Err((
                        req,
                        Error::serve(
                            "slo breach: decode p95 over target, shedding generate traffic"
                                .to_string(),
                        ),
                    ));
                }
            }
        }
        // Generate requests are additionally bounded by the KV arena: the
        // pool won't accept more projected decode state than the arena's
        // oversubscription bound — per-class caps alone don't see the
        // *aggregate* across concurrent streams.
        if req.generate > 0 {
            // In a fleet, the budget that matters is the decode-target
            // chip's: that arena holds the stream's KV for its whole
            // decode life (the prefill chip only stages it briefly).
            let target_kv: Option<&Arc<KvManager>> = match &self.fleet {
                Some(fleet) => {
                    Some(&fleet.chips[fleet.decode_chip_index(req.prefix_group, req.id)].kv)
                }
                None => self.kv.as_ref(),
            };
            if let Some(kv) = target_kv {
                if !kv.try_admit(req.id, req.len, req.generate, class.batch(), req.prefix_group) {
                    self.inflight.fetch_sub(1, Ordering::AcqRel);
                    self.metrics.record_rejected();
                    self.mark_door_shed(req.id);
                    return Err((
                        req,
                        Error::serve(format!(
                            "kv arena full: {} live streams project past the residency bound",
                            kv.live_streams()
                        )),
                    ));
                }
            }
        }
        // Ledger-admit BEFORE the send: a worker may complete the request
        // before this thread runs again, and a terminal-before-admission
        // would be a false conservation violation. A failed send below
        // sheds the id right back, so the ledger still balances.
        self.metrics.ledger_admit(req.id);
        if let Some(w) = &self.obs {
            w.record(SpanEvent::marker(SpanKind::Admit, req.id, w.now_us()));
        }
        if let Err(send_err) = self.tx.send(Msg::Req(req)) {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            let Msg::Req(req) = send_err.0 else { unreachable!("we sent a request") };
            self.metrics.ledger_shed(req.id);
            if req.generate > 0 {
                // Undo the arena reservation — the stream never ran.
                match &self.fleet {
                    Some(fleet) => fleet.release_stream(req.id),
                    None => {
                        if let Some(kv) = &self.kv {
                            kv.release(req.id);
                        }
                    }
                }
            }
            return Err((req, Error::serve("server is down".to_string())));
        }
        Ok(())
    }

    /// Requests admitted and not yet responded.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Formed batches waiting for a worker.
    pub fn pending_batches(&self) -> usize {
        self.queue.len()
    }

    fn mark_door_shed(&self, id: crate::coordinator::request::RequestId) {
        if let Some(w) = &self.obs {
            w.record(SpanEvent::marker(SpanKind::DoorShed, id, w.now_us()));
        }
    }
}

/// Handle a client uses to talk to a running server pool.
pub struct ServerHandle {
    sub: Submitter,
    pub responses: Receiver<Response>,
    /// Per-token decode stream: one [`TokenEvent`] per generated token,
    /// emitted while its request is still in flight. Encode-only traffic
    /// never sends here; dropping the receiver is harmless.
    pub tokens: Receiver<TokenEvent>,
    /// Pooled metrics (every worker records into this sink too).
    pub metrics: Arc<ServerMetrics>,
    worker_metrics: Vec<Arc<ServerMetrics>>,
    /// One simulation cache per chip (exactly one without a fleet — the
    /// pool-wide shared cache). Per-chip because a `PassKey` does not
    /// carry the operating point: two chips at different frequencies
    /// produce different timings for the same key.
    sim_caches: Vec<Arc<SimCache>>,
    kv: Option<Arc<KvManager>>,
    fleet: Option<Arc<Fleet>>,
    recorder: Option<Arc<FlightRecorder>>,
    telemetry: Option<Arc<Telemetry>>,
    control: Option<Arc<ControlState>>,
    sampler: Option<JoinHandle<()>>,
    sampler_stop: Arc<AtomicBool>,
    ingest: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<Result<()>>>,
    started: Instant,
}

impl ServerHandle {
    /// A cloneable submit-side handle for concurrent client threads.
    pub fn submitter(&self) -> Submitter {
        self.sub.clone()
    }

    /// Take ownership of the response/token receivers, leaving dead ones
    /// behind. [`Self::shutdown`] consumes the handle, so a caller that
    /// wants to keep draining events *through and after* shutdown (the
    /// replay driver, the fuzzer's post-drain audit) detaches the streams
    /// first. Call at most once: a second call returns the dead stubs.
    pub fn detach_streams(&mut self) -> (Receiver<Response>, Receiver<TokenEvent>) {
        let (_dead_resp_tx, dead_resp) = channel::<Response>();
        let (_dead_tok_tx, dead_tok) = channel::<TokenEvent>();
        (
            std::mem::replace(&mut self.responses, dead_resp),
            std::mem::replace(&mut self.tokens, dead_tok),
        )
    }

    /// See [`Submitter::submit`].
    pub fn submit(&self, req: Request) -> Result<()> {
        self.sub.submit(req)
    }

    /// See [`Submitter::try_submit`].
    pub fn try_submit(&self, req: Request) -> std::result::Result<(), (Request, Error)> {
        self.sub.try_submit(req)
    }

    /// Requests admitted and not yet responded.
    pub fn inflight(&self) -> usize {
        self.sub.inflight()
    }

    /// Formed batches waiting for a worker.
    pub fn pending_batches(&self) -> usize {
        self.sub.pending_batches()
    }

    /// Live view of the shared simulation cache(s) — summed across chips
    /// in a fleet.
    pub fn cache_stats(&self) -> CacheStats {
        sum_cache_stats(&self.sim_caches)
    }

    /// The pool's flight recorder, when tracing is on.
    pub fn recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.recorder.as_ref()
    }

    /// The sampler's in-memory snapshot ring, when telemetry is on.
    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.telemetry.as_ref()
    }

    /// Shared control-plane state (SLO gate + governor counters), when the
    /// pool was started with an SLO or governor configured.
    pub fn control(&self) -> Option<&Arc<ControlState>> {
        self.control.as_ref()
    }

    /// Stop the pool: the ingest thread drains the batcher into the work
    /// queue and closes it, every worker drains the queue dry, then all
    /// threads join. In-flight batches are never dropped.
    pub fn shutdown(mut self) -> Result<ServerReport> {
        // Refuse new admissions first: taking the gate's write side waits
        // out any in-flight submit, so every request whose submit returned
        // Ok is already in the channel when Shutdown is enqueued behind it
        // — the ingest drain serves them all.
        *self.sub.closed.write().unwrap() = true;
        let _ = self.sub.tx.send(Msg::Shutdown);
        if let Some(j) = self.ingest.take() {
            j.join().map_err(|_| Error::serve("ingest thread panicked".to_string()))?;
        }
        let mut first_err: Option<Error> = None;
        for w in self.workers.drain(..) {
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(Error::serve("worker thread panicked".to_string()));
                    }
                }
            }
        }
        // Stop the sampler last so it records the drain; it takes one
        // closing snapshot on the way out.
        self.sampler_stop.store(true, Ordering::Release);
        if let Some(j) = self.sampler.take() {
            j.join().map_err(|_| Error::serve("sampler thread panicked".to_string()))?;
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(ServerReport {
            wall_seconds: self.started.elapsed().as_secs_f64(),
            metrics: Arc::clone(&self.metrics),
            workers: self.worker_metrics.clone(),
            cache: sum_cache_stats(&self.sim_caches),
            kv: self.kv.clone(),
            fleet: self.fleet.clone(),
            recorder: self.recorder.clone(),
            telemetry: self.telemetry.clone(),
            control: self.control.clone(),
        })
    }

    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }
}

/// Final report after shutdown: pooled metrics, per-worker metrics, and
/// shared-cache counters.
pub struct ServerReport {
    pub wall_seconds: f64,
    /// Pooled (all-worker) metrics.
    pub metrics: Arc<ServerMetrics>,
    /// Per-worker metrics, indexed by worker id.
    pub workers: Vec<Arc<ServerMetrics>>,
    pub cache: CacheStats,
    /// The pool's shared KV manager (when one was configured).
    pub kv: Option<Arc<KvManager>>,
    /// The fleet (when one was configured) — per-chip KV arenas and chip
    /// identity for the report's worker attribution.
    pub fleet: Option<Arc<Fleet>>,
    /// The flight recorder (when tracing was on) — export its snapshot
    /// with [`crate::obs::chrome_trace`] / [`crate::obs::spans_jsonl`].
    pub recorder: Option<Arc<FlightRecorder>>,
    /// The sampler's snapshot ring (when telemetry was on).
    pub telemetry: Option<Arc<Telemetry>>,
    /// Control-plane state (when an SLO or governor was configured) — the
    /// report's `control` JSON key exists only in this case, so static
    /// configs keep a bit-identical report shape.
    pub control: Option<Arc<ControlState>>,
}

impl ServerReport {
    pub fn json(&self) -> Json {
        let mut j = self.metrics.report(self.wall_seconds);
        if let Json::Obj(m) = &mut j {
            m.insert(
                "sim_cache".to_string(),
                Json::obj(vec![
                    ("hits", Json::num(self.cache.hits as f64)),
                    ("misses", Json::num(self.cache.misses as f64)),
                    ("entries", Json::num(self.cache.entries as f64)),
                    ("hit_rate", Json::num(self.cache.hit_rate())),
                ]),
            );
            if let Some(kv) = &self.kv {
                m.insert("kv_arena".to_string(), kv.to_json());
            }
            if let Some(fleet) = &self.fleet {
                m.insert(
                    "kv_arena_per_chip".to_string(),
                    Json::Arr(
                        fleet
                            .chips
                            .iter()
                            .map(|c| {
                                let mut cj = c.kv.to_json();
                                if let Json::Obj(cm) = &mut cj {
                                    cm.insert("chip_id".to_string(), Json::str(&*c.spec.id));
                                    cm.insert("chip_role".to_string(), Json::str(c.spec.role.name()));
                                }
                                cj
                            })
                            .collect(),
                    ),
                );
            }
            if let Some(rec) = &self.recorder {
                m.insert(
                    "trace_events_recorded".to_string(),
                    Json::num(rec.total_recorded() as f64),
                );
            }
            if let Some(t) = &self.telemetry {
                m.insert("telemetry_snapshots".to_string(), Json::num(t.taken() as f64));
            }
            if let Some(ctl) = &self.control {
                let chip_vdd: Vec<Json> = self
                    .fleet
                    .iter()
                    .flat_map(|f| f.chips.iter())
                    .map(|c| {
                        Json::obj(vec![
                            ("chip_id", Json::str(&*c.spec.id)),
                            ("vdd", Json::num(c.current_vdd())),
                            ("op_epoch", Json::num(c.op_epoch() as f64)),
                            ("stale_plan_hits", Json::num(c.stale_plan_hits() as f64)),
                        ])
                    })
                    .collect();
                m.insert(
                    "control".to_string(),
                    Json::obj(vec![
                        ("dvfs_repoints", Json::num(ctl.repoints() as f64)),
                        ("slo_door_sheds", Json::num(ctl.door_sheds() as f64)),
                        ("slo_shedding_now", Json::num(if ctl.shedding() { 1.0 } else { 0.0 })),
                        ("chip_vdd", Json::Arr(chip_vdd)),
                    ]),
                );
            }
            m.insert(
                "workers".to_string(),
                Json::Arr(
                    self.workers
                        .iter()
                        .enumerate()
                        .map(|(i, w)| {
                            let mut wj = w.report(self.wall_seconds);
                            if let Json::Obj(wm) = &mut wj {
                                // Worker→chip attribution (worker i is
                                // bound to chip i; a single-chip pool is
                                // all "chip0").
                                let chip_id = match &self.fleet {
                                    Some(f) => f.chips[i].spec.id.clone(),
                                    None => "chip0".to_string(),
                                };
                                wm.insert("chip_id".to_string(), Json::Str(chip_id));
                            }
                            wj
                        })
                        .collect(),
                ),
            );
        }
        j
    }
}

/// Sum per-chip cache counters into one pool-wide view (identity for the
/// single-cache pool).
fn sum_cache_stats(caches: &[Arc<SimCache>]) -> CacheStats {
    let mut total = CacheStats { hits: 0, misses: 0, entries: 0 };
    for c in caches {
        let s = c.stats();
        total.hits += s.hits;
        total.misses += s.misses;
        total.entries += s.entries;
    }
    total
}

// -------------------------------------------------------------------- server

/// The server: spawns the ingest thread and the engine worker pool.
pub struct Server;

impl Server {
    /// Start a single-worker pool (the original server shape). Executables
    /// are not `Send`, so each worker *constructs its engine inside its own
    /// thread* from the given factory (typically: create the runtime, load
    /// or synthesize artifacts, build `Engine` with the ctx's shared cache).
    /// `batcher_cfg.max_seq` must match the artifact model's token plane.
    pub fn start<F>(make_engine: F, batcher_cfg: BatcherConfig) -> ServerHandle
    where
        F: Fn(&WorkerCtx) -> Result<Engine> + Send + Sync + 'static,
    {
        Self::start_pool(make_engine, PoolConfig::single(batcher_cfg))
    }

    /// Start a pool of `cfg.workers` engine workers behind one ingest
    /// thread. The factory runs once per worker, inside that worker's
    /// thread.
    pub fn start_pool<F>(make_engine: F, cfg: PoolConfig) -> ServerHandle
    where
        F: Fn(&WorkerCtx) -> Result<Engine> + Send + Sync + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let (tok_tx, tok_rx) = channel::<TokenEvent>();
        let pooled = Arc::new(ServerMetrics::new());
        if cfg.lifecycle_ledger {
            pooled.enable_ledger();
        }
        // A fleet binds worker i to chip i: the pool runs exactly one
        // worker per chip (placement decides where work goes, not worker
        // count), and each chip gets its own simulation cache — a PassKey
        // doesn't carry the operating point, so chips at different
        // frequencies must not share simulated timings.
        let fleet = cfg.fleet.clone();
        let n_chips = fleet.as_ref().map(|f| f.n_chips()).unwrap_or(1);
        let n_workers = match &fleet {
            Some(f) => f.n_chips(),
            None => cfg.workers.max(1),
        };
        let sim_caches: Vec<Arc<SimCache>> =
            (0..n_chips).map(|_| Arc::new(SimCache::new())).collect();
        let queue = Arc::new(WorkQueue::new(
            n_chips,
            cfg.affinity,
            cfg.decode,
            cfg.decode_max_wait,
            cfg.decode_priority,
        ));
        let inflight = Arc::new(AtomicUsize::new(0));
        let factory = Arc::new(make_engine);
        let prefill_chunk = cfg.prefill_chunk;

        let recorder = cfg.recorder.clone();
        let kv_shared: Arc<OnceLock<Arc<KvManager>>> = Arc::new(OnceLock::new());
        let plans = Arc::new(PlanRegistry::new());
        let mut worker_metrics = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for worker in 0..n_workers {
            let own = Arc::new(ServerMetrics::new());
            worker_metrics.push(Arc::clone(&own));
            let ctx = WorkerCtx {
                worker,
                // Worker i serves chip i in a fleet; all workers share
                // cache 0 (the one chip) otherwise.
                sim_cache: Arc::clone(&sim_caches[if fleet.is_some() { worker } else { 0 }]),
                plans: Arc::clone(&plans),
                kv: match &fleet {
                    Some(f) => Some(Arc::clone(&f.chips[worker].kv)),
                    None => cfg.kv.clone(),
                },
                kv_shared: Arc::clone(&kv_shared),
                obs: recorder.as_ref().map(|r| SpanWriter::new(Arc::clone(r), worker)),
                fleet: fleet.clone(),
            };
            let factory = Arc::clone(&factory);
            let queue = Arc::clone(&queue);
            let pooled = Arc::clone(&pooled);
            let inflight = Arc::clone(&inflight);
            let resp_tx = resp_tx.clone();
            let tok_tx = tok_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("trex-worker-{worker}"))
                    .spawn(move || {
                        worker_loop(
                            &ctx,
                            factory.as_ref(),
                            queue,
                            resp_tx,
                            tok_tx,
                            pooled,
                            own,
                            inflight,
                            prefill_chunk,
                        )
                    })
                    .expect("spawn engine worker"),
            );
        }
        drop(resp_tx);
        drop(tok_tx);

        let ingest_metrics = Arc::clone(&pooled);
        let ingest_queue = Arc::clone(&queue);
        let ingest_inflight = Arc::clone(&inflight);
        let ingest_kv = cfg.kv.clone();
        let ingest_fleet = fleet.clone();
        let batcher_cfg = cfg.batcher;
        let ingest = std::thread::Builder::new()
            .name("trex-ingest".to_string())
            .spawn(move || {
                ingest_loop(
                    batcher_cfg,
                    rx,
                    ingest_queue,
                    ingest_metrics,
                    ingest_inflight,
                    ingest_kv,
                    ingest_fleet,
                )
            })
            .expect("spawn ingest thread");

        let sampler_stop = Arc::new(AtomicBool::new(false));
        let mut telemetry: Option<Arc<Telemetry>> = None;
        let mut sampler: Option<JoinHandle<()>> = None;
        // The control plane rides the sampler thread: an SLO or governor
        // without telemetry configured synthesizes a default sampler
        // config so the control loop always actually runs.
        let control: Option<Arc<ControlState>> =
            (cfg.slo.is_some() || cfg.governor.is_some()).then(|| Arc::new(ControlState::new()));
        let telemetry_cfg =
            cfg.telemetry.clone().or_else(|| control.is_some().then(TelemetryConfig::default));
        if let Some(tcfg) = telemetry_cfg {
            let ring = Arc::new(Telemetry::new(tcfg.capacity));
            telemetry = Some(Arc::clone(&ring));
            let stop = Arc::clone(&sampler_stop);
            let metrics = Arc::clone(&pooled);
            let queue = Arc::clone(&queue);
            let inflight = Arc::clone(&inflight);
            let kv = cfg.kv.clone();
            let kv_shared = Arc::clone(&kv_shared);
            let sampler_fleet = fleet.clone();
            let rec = recorder.clone();
            // The governor steers per-chip operating points — without a
            // fleet there is no chip to re-point, so it stays inert.
            let governor = match (cfg.governor, &fleet) {
                (Some(g), Some(_)) => Some(DvfsGovernor::new(g, cfg.slo, n_chips)),
                _ => None,
            };
            let slo = cfg.slo;
            let ctl = control.clone();
            sampler = Some(
                std::thread::Builder::new()
                    .name("trex-sampler".to_string())
                    .spawn(move || {
                        sampler_loop(
                            tcfg,
                            ring,
                            stop,
                            metrics,
                            queue,
                            inflight,
                            kv,
                            kv_shared,
                            sampler_fleet,
                            rec,
                            ctl,
                            governor,
                            slo,
                        )
                    })
                    .expect("spawn sampler thread"),
            );
        }

        ServerHandle {
            sub: Submitter {
                tx,
                metrics: Arc::clone(&pooled),
                queue,
                inflight,
                kv: cfg.kv.clone(),
                fleet: fleet.clone(),
                obs: recorder
                    .as_ref()
                    .map(|r| SpanWriter::new(Arc::clone(r), r.admit_lane())),
                control: control.clone(),
                closed: Arc::new(RwLock::new(false)),
                queue_depth: cfg.queue_depth,
                max_inflight: cfg.max_inflight,
                max_seq: cfg.batcher.max_seq,
            },
            responses: resp_rx,
            tokens: tok_rx,
            metrics: pooled,
            worker_metrics,
            sim_caches,
            kv: cfg.kv,
            fleet,
            recorder,
            telemetry,
            control,
            sampler,
            sampler_stop,
            ingest: Some(ingest),
            workers,
            started: Instant::now(),
        }
    }
}

/// Telemetry sampler thread: one [`Snapshot`] per interval into the ring
/// (and optional JSONL stream), plus shed-storm detection — a spike of
/// door-sheds + execute-errors within one interval at or above the
/// configured threshold drains the flight recorder to the anomaly-dump
/// path, exactly once per run. Takes one closing snapshot at shutdown so
/// even sub-interval runs record the final state.
///
/// The control plane rides here: each interval the sampler drains the
/// metrics sink's interval window, updates the SLO admission gate, and
/// runs one governor tick — every accepted re-point bumps the chip's
/// operating-point epoch (obligating the bound engine to re-cost its plan
/// scope and sim caches before its next priced step) and records a
/// [`SpanKind::DvfsRepoint`] marker on the admit lane.
#[allow(clippy::too_many_arguments)]
fn sampler_loop(
    cfg: TelemetryConfig,
    ring: Arc<Telemetry>,
    stop: Arc<AtomicBool>,
    metrics: Arc<ServerMetrics>,
    queue: Arc<WorkQueue>,
    inflight: Arc<AtomicUsize>,
    kv: Option<Arc<KvManager>>,
    kv_shared: Arc<OnceLock<Arc<KvManager>>>,
    fleet: Option<Arc<Fleet>>,
    recorder: Option<Arc<FlightRecorder>>,
    control: Option<Arc<ControlState>>,
    mut governor: Option<DvfsGovernor>,
    slo: Option<SloTarget>,
) {
    use std::io::Write;
    let started = Instant::now();
    let mut out = cfg.out.as_ref().and_then(|p| {
        std::fs::OpenOptions::new().create(true).append(true).open(p).ok()
    });
    let dump_once = crate::obs::DumpOnce::new();
    let mut last_shed: u64 = 0;
    let interval = cfg.interval.max(Duration::from_micros(100));
    // Governor-decision markers ride the admit lane: re-points gate what
    // the door and the workers will see next, and the lane exists whenever
    // tracing is on.
    let gov_span =
        recorder.as_ref().map(|r| SpanWriter::new(Arc::clone(r), r.admit_lane()));
    loop {
        let stopping = stop.load(Ordering::Acquire);
        let m = metrics.sample();
        // Drain this interval's latency window (exactly once per tick) and
        // run the control plane on it.
        let iv = metrics.take_interval();
        if let (Some(slo), Some(ctl)) = (&slo, &control) {
            slo.update_gate(ctl, iv.tokens, iv.us_per_token_p95);
        }
        if let (Some(gov), Some(f)) = (governor.as_mut(), &fleet) {
            let depths = queue.depths();
            let kv_frac: Vec<f64> = f
                .chips
                .iter()
                .map(|c| {
                    let cap = c.kv.capacity_pages();
                    if cap == 0 {
                        0.0
                    } else {
                        c.kv.used_pages() as f64 / cap as f64
                    }
                })
                .collect();
            let obs = GovernorObs {
                t_us: started.elapsed().as_secs_f64() * 1e6,
                tokens: iv.tokens,
                us_p50: iv.us_per_token_p50,
                us_p95: iv.us_per_token_p95,
                queue_depths: &depths,
                kv_frac: &kv_frac,
            };
            for (chip_idx, rp) in gov.tick(f, &obs) {
                if let Some(ctl) = &control {
                    ctl.note_repoint();
                }
                if let Some(w) = &gov_span {
                    let mut ev =
                        SpanEvent::marker(SpanKind::DvfsRepoint, chip_idx as u64, w.now_us());
                    ev.group = chip_idx as u32;
                    ev.chip_us = rp.from_vdd;
                    ev.chip_uj = rp.to_vdd;
                    w.record(ev);
                }
            }
        }
        // The pool's arena is either the configured one or the engines'
        // shared fallback (installed by the first worker); a fleet sums
        // its per-chip arenas into the pool-wide gauges.
        let (kv_used, kv_sh, kv_live) = match &fleet {
            Some(f) => f.chips.iter().fold((0, 0, 0), |a, c| {
                (
                    a.0 + c.kv.used_pages(),
                    a.1 + c.kv.shared_pages(),
                    a.2 + c.kv.live_streams(),
                )
            }),
            None => {
                let arena = kv.as_ref().or_else(|| kv_shared.get());
                (
                    arena.map(|k| k.used_pages()).unwrap_or(0),
                    arena.map(|k| k.shared_pages()).unwrap_or(0),
                    arena.map(|k| k.live_streams()).unwrap_or(0),
                )
            }
        };
        let snap = Snapshot {
            t_us: started.elapsed().as_secs_f64() * 1e6,
            queue_depth: queue.len(),
            inflight: inflight.load(Ordering::Acquire),
            kv_used_pages: kv_used,
            kv_shared_pages: kv_sh,
            kv_live_streams: kv_live,
            completed: m.completed,
            rejected: m.rejected,
            execute_errors: m.execute_errors,
            tokens_decoded: m.tokens_decoded,
            interleave_ratio: m.interleave_ratio,
            coalesce_wait_us_mean: m.coalesce_wait_us_mean,
            us_per_token_p50: m.us_per_token_p50,
            us_per_token_p95: m.us_per_token_p95,
            uj_per_token_p50: m.uj_per_token_p50,
            uj_per_token_p95: m.uj_per_token_p95,
            interval_tokens: iv.tokens,
            interval_us_p50: iv.us_per_token_p50,
            interval_us_p95: iv.us_per_token_p95,
            dvfs_repoints: control.as_ref().map(|c| c.repoints()).unwrap_or(0),
            slo_shedding: control.as_ref().map(|c| c.shedding()).unwrap_or(false),
            slo_door_sheds: control.as_ref().map(|c| c.door_sheds()).unwrap_or(0),
        };
        ring.push(snap);
        if let Some(f) = &mut out {
            let _ = f.write_all(snap.to_json().to_string().as_bytes());
            let _ = f.write_all(b"\n");
        }
        // Shed storm: too many new rejections/errors within one interval.
        let shed_now = m.rejected + m.execute_errors;
        if cfg.shed_storm_threshold > 0
            && shed_now.saturating_sub(last_shed) >= cfg.shed_storm_threshold
            && dump_once.arm()
        {
            if let (Some(rec), Some(path)) = (&recorder, &cfg.anomaly_dump) {
                let detail = format!(
                    "shed storm: {} door-sheds/errors within one {}us sampling interval \
                     (threshold {})",
                    shed_now - last_shed,
                    interval.as_micros(),
                    cfg.shed_storm_threshold
                );
                let _ = dump_anomaly(rec, path, &[detail]);
            }
        }
        last_shed = shed_now;
        if stopping {
            break;
        }
        std::thread::sleep(interval);
    }
}

/// Admission thread: classify + batch requests, feed the work queue, flush
/// deadlines. On shutdown it drains the batcher (partial batches included)
/// into the queue and closes it, so workers finish everything admitted.
#[allow(clippy::too_many_arguments)]
fn ingest_loop(
    batcher_cfg: BatcherConfig,
    rx: Receiver<Msg>,
    queue: Arc<WorkQueue>,
    metrics: Arc<ServerMetrics>,
    inflight: Arc<AtomicUsize>,
    kv: Option<Arc<KvManager>>,
    fleet: Option<Arc<Fleet>>,
) {
    let mut batcher = DynamicBatcher::new(batcher_cfg);
    // Formed batches land on a chip lane: round-robin over the fleet's
    // prefill-capable chips (chip 0 without a fleet).
    let mut prefill_rr: u64 = 0;
    fn prefill_target(fleet: Option<&Fleet>, rr: &mut u64) -> usize {
        match fleet {
            Some(f) => {
                let chip = f.prefill_chip_index(*rr);
                *rr += 1;
                chip
            }
            None => 0,
        }
    }
    // Admit one request into the batcher, forwarding any formed batch.
    // Unservable lengths are normally rejected at submit; this is the
    // defense-in-depth path (shed, never poison the pool — and a shed
    // generate request must give back its kv-arena reservation, on every
    // chip in a fleet: the door projected it on the decode target).
    let admit = |batcher: &mut DynamicBatcher, rr: &mut u64, req: Request| {
        let (id, generate) = (req.id, req.generate);
        match batcher.push(req) {
            Ok(Some(batch)) => queue.push(prefill_target(fleet.as_deref(), rr), batch),
            Ok(None) => {}
            Err(_) => {
                metrics.record_rejected();
                metrics.ledger_shed(id);
                inflight.fetch_sub(1, Ordering::AcqRel);
                if generate > 0 {
                    match &fleet {
                        Some(f) => f.release_stream(id),
                        None => {
                            if let Some(kv) = &kv {
                                kv.release(id);
                            }
                        }
                    }
                }
            }
        }
    };
    loop {
        // Wait for work, but wake at the batcher's earliest deadline.
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(req)) => admit(&mut batcher, &mut prefill_rr, req),
            Ok(Msg::Shutdown) => {
                // Drain requests that were already sent when shutdown was
                // signalled — a submit that returned Ok is never dropped.
                while let Ok(msg) = rx.try_recv() {
                    if let Msg::Req(req) = msg {
                        admit(&mut batcher, &mut prefill_rr, req);
                    }
                }
                break;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for batch in batcher.poll_deadline(Instant::now()) {
            queue.push(prefill_target(fleet.as_deref(), &mut prefill_rr), batch);
        }
    }
    for batch in batcher.drain() {
        queue.push(prefill_target(fleet.as_deref(), &mut prefill_rr), batch);
    }
    queue.close();
}

/// Engine worker: build the engine, then pull work (warm-class first,
/// alternating prefill/decode when both wait) until the queue closes and
/// drains. Execute failures shed the batch/group and are counted, not fatal
/// — one bad batch must not take the pool down. With chunking on
/// (`prefill_chunk > 0`), prefill batches run one chunk per pop and park
/// in between; a chunk that fails sheds its whole batch and releases the
/// first-chunk KV registrations.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    ctx: &WorkerCtx,
    make_engine: &(dyn Fn(&WorkerCtx) -> Result<Engine> + Send + Sync),
    queue: Arc<WorkQueue>,
    resp_tx: Sender<Response>,
    tok_tx: Sender<TokenEvent>,
    pooled: Arc<ServerMetrics>,
    own: Arc<ServerMetrics>,
    inflight: Arc<AtomicUsize>,
    prefill_chunk: usize,
) -> Result<()> {
    let mut engine = make_engine(ctx)?;
    if let Some(w) = &ctx.obs {
        // Bind the recorder's KV lane to whichever arena this pool ended
        // up with (configured or shared-fallback); first worker wins,
        // attach is idempotent. (In a fleet each chip's manager binds the
        // same lane — per-chip attribution rides on the worker lanes.)
        let rec = w.recorder();
        engine
            .kv_manager()
            .attach_span_writer(SpanWriter::new(Arc::clone(rec), rec.kv_lane()));
    }
    // The chip lane this worker serves: its own index in a fleet (worker
    // i ↔ chip i), the single shared lane 0 otherwise.
    let chip = if ctx.fleet.is_some() { ctx.worker } else { 0 };
    let mut warm: Option<BatchClass> = None;
    let mut first_err: Option<Error> = None;
    let mut last_was_decode = false;
    // Reusable decode-group buffer: pop fills it, execute_decode drains it
    // — the steady-state token loop never allocates a group vector.
    let mut group_buf: Vec<DecodeState> = Vec::with_capacity(MAX_DECODE_GROUP);
    // Final responses all leave through here: record, release the in-flight
    // slot, send. A dropped receiver is a client gone — not a pool error.
    let finish = |mut resp: Response| {
        resp.worker = ctx.worker;
        if let Some(fleet) = &ctx.fleet {
            // Terminal sweep: a generate stream clamped to zero tokens at
            // prefill was released by the engine on THIS chip, but its
            // door projection lives on its decode-target chip. Release
            // everywhere — a no-op on arenas that never saw the id.
            fleet.release_stream(resp.id);
        }
        pooled.ledger_complete(resp.id);
        pooled.record_response(&resp, resp.prefill_len);
        own.record_response(&resp, resp.prefill_len);
        inflight.fetch_sub(1, Ordering::AcqRel);
        let _ = resp_tx.send(resp);
    };
    // Every shed (failed batch, group, or chunk) exits through here with
    // EVERY id in the failed unit: count the error, mark each id shed in
    // the lifecycle ledger, free the in-flight slots, release the KV
    // registrations/reservations (a no-op for ids the manager never saw —
    // encode-only requests), latch the first error. `engine` and
    // `first_err` are arguments because both are mutably borrowed
    // elsewhere in the loop.
    let shed = |engine: &Engine,
                n: usize,
                ids: Vec<crate::coordinator::request::RequestId>,
                e: Error,
                first_err: &mut Option<Error>| {
        pooled.record_execute_error();
        own.record_execute_error();
        inflight.fetch_sub(n, Ordering::AcqRel);
        let shed_t = ctx.obs.as_ref().map(|w| w.now_us());
        for id in ids {
            pooled.ledger_shed(id);
            // A fleet stream can hold state on two chips at once (KV on
            // its prefill chip, projection on its decode target — or
            // mid-migration on both): sweep every arena.
            match &ctx.fleet {
                Some(fleet) => fleet.release_stream(id),
                None => engine.kv_manager().release(id),
            }
            if let Some(w) = &ctx.obs {
                w.record(SpanEvent::marker(SpanKind::Shed, id, shed_t.unwrap_or(0.0)));
            }
        }
        if first_err.is_none() {
            *first_err = Some(e);
        }
    };
    // Streams entering (or continuing) decode go to their decode chip's
    // lane. Without a fleet that's lane 0 — the pre-fleet behavior. With
    // one, the target is the deterministic prefix-group hash, and a
    // stream whose KV sits on this chip but decodes elsewhere pays a
    // priced migration: its pages move arena-to-arena (a shared radix
    // chain physically moves once — mates attach warm), and the
    // transfer's DRAM wall-stall and energy — priced at the SOURCE
    // chip's operating point, like a KvSwap — land on the stream's own
    // ledger before its first decode step there.
    let route_decode = |states: Vec<DecodeState>| match &ctx.fleet {
        None => queue.push_decode(0, states),
        Some(fleet) => {
            let mut per: Vec<Vec<DecodeState>> =
                (0..fleet.n_chips()).map(|_| Vec::new()).collect();
            for mut st in states {
                let target = fleet.decode_chip_index(st.prefix_group, st.id);
                if target != chip {
                    if let Some(m) = fleet.chips[chip].kv.migrate_out(st.id) {
                        let moved = fleet.chips[target].kv.migrate_in(st.id, &m);
                        if moved > 0 {
                            // Priced at the source chip's *current*
                            // operating point — a re-pointed chip's DMA
                            // runs at its runtime frequency, not the
                            // build-time pin.
                            let hw = fleet.chips[chip].current_hw();
                            st.charge_migration(
                                hw.dram_ns(moved as usize) * 1e-3,
                                hw.dram_pj(moved as usize) * 1e-6,
                                moved,
                            );
                        }
                    }
                }
                per[target].push(st);
            }
            for (target, group) in per.into_iter().enumerate() {
                queue.push_decode(target, group);
            }
        }
    };
    while let Some(item) = queue.pop(chip, warm, last_was_decode, &mut group_buf) {
        // A prefill to advance by one chunk this iteration (fresh from a
        // batch, or resumed from the parked pool).
        let mut chunk_to_run: Option<Box<PrefillState>> = None;
        match item {
            WorkItem::Prefill(batch) => {
                last_was_decode = false;
                warm = Some(batch.class);
                let n = batch.requests.len();
                // A shed batch must mark every member terminal in the
                // ledger, and generate members may hold kv-arena admission
                // reservations that must release or the admission bound
                // leaks shut (client-triggerable via a malformed payload).
                // `KvManager::release` skips ids it never saw, so passing
                // all ids is safe.
                let ids: Vec<_> = batch.requests.iter().map(|r| r.id).collect();
                pooled.record_batch(batch.class, n);
                own.record_batch(batch.class, n);
                if prefill_chunk > 0 {
                    match engine.begin_prefill(batch, prefill_chunk) {
                        Ok(state) => chunk_to_run = Some(Box::new(state)),
                        Err(e) => shed(&engine, n, ids, e, &mut first_err),
                    }
                } else {
                    match engine.execute(batch) {
                        Ok(outcome) => {
                            outcome.responses.into_iter().for_each(&finish);
                            // Streams entering decode keep their in-flight
                            // slot until their final response.
                            route_decode(outcome.decoding);
                        }
                        Err(e) => shed(&engine, n, ids, e, &mut first_err),
                    }
                }
            }
            WorkItem::PrefillChunk(state) => {
                last_was_decode = false;
                warm = Some(state.class());
                chunk_to_run = Some(state);
            }
            WorkItem::Decode { interleaved, coalesce_wait_us } => {
                last_was_decode = true;
                let n = group_buf.len();
                match engine.execute_decode(&mut group_buf) {
                    Ok(outcome) => {
                        pooled.record_decode_step(
                            outcome.pad_waste_tokens,
                            outcome.kv_swap_ins,
                            outcome.kv_swap_bytes,
                            interleaved,
                            outcome.planned,
                            coalesce_wait_us,
                        );
                        own.record_decode_step(
                            outcome.pad_waste_tokens,
                            outcome.kv_swap_ins,
                            outcome.kv_swap_bytes,
                            interleaved,
                            outcome.planned,
                            coalesce_wait_us,
                        );
                        for mut ev in outcome.tokens {
                            ev.worker = ctx.worker;
                            pooled.record_token(&ev);
                            own.record_token(&ev);
                            let _ = tok_tx.send(ev);
                        }
                        route_decode(outcome.active);
                        outcome.responses.into_iter().for_each(&finish);
                    }
                    // Shed the whole group: their requests never answer, so
                    // their arena pages and reservations free up (the ids
                    // are still in the buffer — execute_decode drains it
                    // only on success).
                    Err(e) => {
                        let ids: Vec<_> = group_buf.iter().map(|s| s.id).collect();
                        group_buf.clear();
                        shed(&engine, n, ids, e, &mut first_err);
                    }
                }
            }
        }
        if let Some(state) = chunk_to_run {
            // Snapshot before the call: an Err consumes the state, and the
            // shed path must release the first-chunk KV registrations and
            // the batch's in-flight slots.
            let n = state.n_requests();
            let ids = state.request_ids();
            queue.chunk_started();
            let progress = engine.prefill_chunk(*state);
            // (The counter drops only after a Parked state is back in the
            // queue, so a concurrent decode pop never sees the prefill
            // vanish for an instant between executing and parked.)
            match progress {
                Ok(PrefillProgress::Parked(st)) => {
                    pooled.record_prefill_chunk();
                    own.record_prefill_chunk();
                    queue.push_parked(chip, st);
                }
                Ok(PrefillProgress::Done(outcome)) => {
                    pooled.record_prefill_chunk();
                    own.record_prefill_chunk();
                    outcome.responses.into_iter().for_each(&finish);
                    route_decode(outcome.decoding);
                }
                // Shed mid-prefill: the whole batch never answers.
                Err(e) => shed(&engine, n, ids, e, &mut first_err),
            }
            queue.chunk_finished();
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}
