//! Threaded serving loop: ingest → dynamic batch → engine → respond.
//!
//! One engine thread owns the PJRT executables and the batcher; clients
//! submit through an mpsc channel and receive responses on a per-server
//! response channel. (std threads — tokio is not vendored offline.)

use crate::coordinator::batcher::{BatcherConfig, DynamicBatcher};
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::request::{Request, Response};
use crate::error::{Error, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

enum Msg {
    Req(Request),
    Shutdown,
}

/// Handle a client uses to talk to a running server.
pub struct ServerHandle {
    tx: Sender<Msg>,
    pub responses: Receiver<Response>,
    pub metrics: Arc<ServerMetrics>,
    join: Option<JoinHandle<Result<()>>>,
    started: Instant,
}

impl ServerHandle {
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(Msg::Req(req))
            .map_err(|_| Error::serve("server is down".to_string()))
    }

    /// Stop the engine loop (drains pending batches first) and join.
    pub fn shutdown(mut self) -> Result<ServerReport> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| Error::serve("engine thread panicked".to_string()))??;
        }
        let wall = self.started.elapsed().as_secs_f64();
        Ok(ServerReport { wall_seconds: wall, metrics: Arc::clone(&self.metrics) })
    }

    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }
}

/// Final report after shutdown.
pub struct ServerReport {
    pub wall_seconds: f64,
    pub metrics: Arc<ServerMetrics>,
}

impl ServerReport {
    pub fn json(&self) -> crate::util::json::Json {
        self.metrics.report(self.wall_seconds)
    }
}

/// The server: spawns the engine thread.
pub struct Server;

impl Server {
    /// Start serving. PJRT executables are not `Send`, so the engine is
    /// *constructed inside* the worker thread from the given factory
    /// (typically: create the PJRT client, load artifacts, build `Engine`).
    /// `batcher_cfg.max_seq` must match the artifact model's token plane.
    pub fn start<F>(make_engine: F, batcher_cfg: BatcherConfig) -> ServerHandle
    where
        F: FnOnce() -> Result<Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let metrics = Arc::new(ServerMetrics::new());
        let m2 = Arc::clone(&metrics);
        let join = std::thread::Builder::new()
            .name("trex-engine".to_string())
            .spawn(move || {
                let engine = make_engine()?;
                engine_loop(engine, batcher_cfg, rx, resp_tx, m2)
            })
            .expect("spawn engine thread");
        ServerHandle { tx, responses: resp_rx, metrics, join: Some(join), started: Instant::now() }
    }
}

fn engine_loop(
    mut engine: Engine,
    batcher_cfg: BatcherConfig,
    rx: Receiver<Msg>,
    resp_tx: Sender<Response>,
    metrics: Arc<ServerMetrics>,
) -> Result<()> {
    let mut batcher = DynamicBatcher::new(batcher_cfg);
    let run_batch = |engine: &mut Engine,
                         batch: crate::coordinator::batcher::FormedBatch|
     -> Result<()> {
        let lens: Vec<usize> = batch.requests.iter().map(|r| r.len).collect();
        metrics.record_batch(batch.class, batch.requests.len());
        let responses = engine.execute(batch)?;
        for (resp, len) in responses.into_iter().zip(lens) {
            metrics.record_response(&resp, len);
            // A dropped receiver is a client gone — not an engine error.
            let _ = resp_tx.send(resp);
        }
        Ok(())
    };

    loop {
        // Wait for work, but wake at the batcher's earliest deadline.
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(req)) => {
                if let Some(batch) = batcher.push(req)? {
                    run_batch(&mut engine, batch)?;
                }
            }
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for batch in batcher.poll_deadline(Instant::now()) {
            run_batch(&mut engine, batch)?;
        }
    }
    // Drain everything left.
    for batch in batcher.drain() {
        run_batch(&mut engine, batch)?;
    }
    Ok(())
}
