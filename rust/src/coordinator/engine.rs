//! Engine: executes formed batches — numerics via the runtime backend,
//! performance via the cycle-level simulator.
//!
//! The engine pads each request to its class's per-input slot, concatenates
//! the batch on the token axis (the chip's reconfigured 128-token plane),
//! runs the class's compiled executable, and splits the output back per
//! request. Per-batch chip latency/energy/EMA come from [`crate::sim`] on
//! the *served model's* config (the artifact model for numerics can be the
//! tiny proxy while performance is reported for the paper workload — both
//! are recorded on the response).
//!
//! In the worker pool each worker owns its own `Engine` (executables are
//! not `Send`), but all engines share one [`SimCache`] so every
//! `(class, seq)` pass is simulated exactly once process-wide.

use crate::config::{HwConfig, ModelConfig};
use crate::coordinator::batcher::FormedBatch;
use crate::coordinator::request::Response;
use crate::coordinator::sim_cache::{CachedPass, SimCache};
use crate::error::{Error, Result};
use crate::model::build_program;
use crate::runtime::ArtifactSet;
use crate::sim::{simulate, BatchClass, SimOptions};
use std::sync::Arc;
use std::time::Instant;

/// Engine configuration.
pub struct EngineConfig {
    pub hw: HwConfig,
    /// Model whose *performance* is simulated per batch.
    pub perf_model: ModelConfig,
    /// Run the artifact self-test at startup.
    pub self_test: bool,
}

/// Executes batches. Owns the compiled artifacts; the simulation cache is
/// shared (per (class, padded-seq) — programs are deterministic).
pub struct Engine {
    artifacts: ArtifactSet,
    cfg: EngineConfig,
    sim_cache: Arc<SimCache>,
}

impl Engine {
    /// Engine with a private simulation cache (single-engine setups).
    pub fn new(artifacts: ArtifactSet, cfg: EngineConfig) -> Result<Self> {
        Self::with_cache(artifacts, cfg, Arc::new(SimCache::new()))
    }

    /// Engine over a shared simulation cache (the pool path — every worker
    /// passes the pool's cache so passes are simulated once process-wide).
    pub fn with_cache(
        artifacts: ArtifactSet,
        cfg: EngineConfig,
        sim_cache: Arc<SimCache>,
    ) -> Result<Self> {
        if cfg.self_test {
            artifacts.self_test()?;
        }
        Ok(Engine { artifacts, cfg, sim_cache })
    }

    pub fn model_name(&self) -> &str {
        &self.artifacts.model_name
    }
    pub fn d_model(&self) -> usize {
        self.artifacts.d_model
    }
    pub fn max_seq(&self) -> usize {
        self.artifacts.max_seq
    }
    pub fn sim_cache(&self) -> &Arc<SimCache> {
        &self.sim_cache
    }

    /// Simulate (with shared caching) the chip pass for a batch class at `seq`.
    fn perf(&self, class: BatchClass, seq: usize) -> CachedPass {
        self.sim_cache.get_or_simulate(class, seq, || {
            let prog = build_program(&self.cfg.perf_model, seq, class.batch());
            let stats = simulate(
                &self.cfg.hw,
                &prog,
                &SimOptions {
                    act_bits: self.cfg.perf_model.act_bits,
                    ..SimOptions::paper(&self.cfg.hw)
                },
            );
            CachedPass {
                chip_us: stats.seconds() * 1e6,
                chip_uj: stats.energy.total_uj(),
                ema_bytes: stats.ema_bytes(),
                utilization: stats.utilization(&self.cfg.hw),
            }
        })
    }

    /// Execute one formed batch end-to-end.
    ///
    /// Timing is split explicitly at `t0`, the instant this engine began
    /// serving the batch: `queue_us` is arrival → `t0` (pure waiting:
    /// batcher residency + work-queue residency), `host_latency_us` is
    /// `t0` → response built (plane assembly + executable run + split).
    /// A request that arrived while another batch was executing therefore
    /// accrues that wait in `queue_us` and can never go negative.
    pub fn execute(&mut self, batch: FormedBatch) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        let entry = self.artifacts.get(batch.class)?;
        let d = entry.d_model;
        let slot = entry.seq; // per-input token slot of this class
        let tokens = entry.tokens;
        let n_req = batch.requests.len();
        if n_req == 0 || n_req > entry.batch {
            return Err(Error::serve(format!(
                "batch of {n_req} requests for class {}",
                batch.class.name()
            )));
        }
        // Assemble the token plane: each request padded to its slot;
        // missing batch-mates (deadline flush) stay zero.
        let mut plane = vec![0.0f32; tokens * d];
        for (i, r) in batch.requests.iter().enumerate() {
            if r.len > slot {
                return Err(Error::serve(format!(
                    "request {} len {} exceeds class slot {slot}",
                    r.id, r.len
                )));
            }
            if r.payload.len() != r.len * d {
                return Err(Error::serve(format!(
                    "request {} payload {} != len {} × d_model {d}",
                    r.id,
                    r.payload.len(),
                    r.len
                )));
            }
            plane[i * slot * d..(i * slot + r.len) * d].copy_from_slice(&r.payload);
        }

        let (seq_for_perf, class) = (slot, batch.class);
        let out = entry.exe.run_f32(&plane, tokens, d)?;
        let perf = self.perf(class, seq_for_perf);
        let per_req_uj = perf.chip_uj / n_req as f64;
        let per_req_ema = perf.ema_bytes / n_req as u64;
        let host_us = t0.elapsed().as_nanos() as f64 / 1e3;

        let mut responses = Vec::with_capacity(n_req);
        for (i, r) in batch.requests.iter().enumerate() {
            let start = i * slot * d;
            responses.push(Response {
                id: r.id,
                output: out[start..start + r.len * d].to_vec(),
                host_latency_us: host_us,
                queue_us: t0.saturating_duration_since(r.arrival).as_nanos() as f64 / 1e3,
                chip_us: perf.chip_us,
                chip_uj: per_req_uj,
                ema_bytes: per_req_ema,
                class,
                utilization: perf.utilization,
                worker: 0,
            });
        }
        Ok(responses)
    }
}
