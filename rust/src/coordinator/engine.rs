//! Engine: executes formed batches — numerics via PJRT, performance via the
//! cycle-level simulator.
//!
//! The engine pads each request to its class's per-input slot, concatenates
//! the batch on the token axis (the chip's reconfigured 128-token plane),
//! runs the class's compiled executable, and splits the output back per
//! request. Per-batch chip latency/energy/EMA come from [`crate::sim`] on
//! the *served model's* config (the artifact model for numerics can be the
//! tiny proxy while performance is reported for the paper workload — both
//! are recorded on the response).

use crate::config::{HwConfig, ModelConfig};
use crate::coordinator::batcher::FormedBatch;
use crate::coordinator::request::Response;
use crate::error::{Error, Result};
use crate::model::build_program;
use crate::runtime::ArtifactSet;
use crate::sim::{simulate, BatchClass, SimOptions};
use std::collections::HashMap;
use std::time::Instant;

/// Engine configuration.
pub struct EngineConfig {
    pub hw: HwConfig,
    /// Model whose *performance* is simulated per batch.
    pub perf_model: ModelConfig,
    /// Run the artifact self-test at startup.
    pub self_test: bool,
}

/// Executes batches. Owns the compiled artifacts and a simulation cache
/// (per (class, padded-seq) — programs are deterministic).
pub struct Engine {
    artifacts: ArtifactSet,
    cfg: EngineConfig,
    sim_cache: HashMap<(BatchClass, usize), CachedPass>,
}

#[derive(Clone, Copy)]
struct CachedPass {
    chip_us: f64,
    chip_uj: f64,
    ema_bytes: u64,
    utilization: f64,
}

impl Engine {
    pub fn new(artifacts: ArtifactSet, cfg: EngineConfig) -> Result<Self> {
        if cfg.self_test {
            artifacts.self_test()?;
        }
        Ok(Engine { artifacts, cfg, sim_cache: HashMap::new() })
    }

    pub fn model_name(&self) -> &str {
        &self.artifacts.model_name
    }
    pub fn d_model(&self) -> usize {
        self.artifacts.d_model
    }
    pub fn max_seq(&self) -> usize {
        self.artifacts.max_seq
    }

    /// Simulate (with caching) the chip pass for a batch class at `seq`.
    fn perf(&mut self, class: BatchClass, seq: usize) -> CachedPass {
        let key = (class, seq);
        if let Some(c) = self.sim_cache.get(&key) {
            return *c;
        }
        let prog = build_program(&self.cfg.perf_model, seq, class.batch());
        let stats = simulate(
            &self.cfg.hw,
            &prog,
            &SimOptions { act_bits: self.cfg.perf_model.act_bits, ..SimOptions::paper(&self.cfg.hw) },
        );
        let pass = CachedPass {
            chip_us: stats.seconds() * 1e6,
            chip_uj: stats.energy.total_uj(),
            ema_bytes: stats.ema_bytes(),
            utilization: stats.utilization(&self.cfg.hw),
        };
        self.sim_cache.insert(key, pass);
        pass
    }

    /// Execute one formed batch end-to-end.
    pub fn execute(&mut self, batch: FormedBatch) -> Result<Vec<Response>> {
        let entry = self.artifacts.get(batch.class)?;
        let d = entry.d_model;
        let slot = entry.seq; // per-input token slot of this class
        let tokens = entry.tokens;
        let n_req = batch.requests.len();
        if n_req == 0 || n_req > entry.batch {
            return Err(Error::serve(format!(
                "batch of {n_req} requests for class {}",
                batch.class.name()
            )));
        }
        // Assemble the token plane: each request padded to its slot;
        // missing batch-mates (deadline flush) stay zero.
        let mut plane = vec![0.0f32; tokens * d];
        for (i, r) in batch.requests.iter().enumerate() {
            if r.len > slot {
                return Err(Error::serve(format!(
                    "request {} len {} exceeds class slot {slot}",
                    r.id, r.len
                )));
            }
            if r.payload.len() != r.len * d {
                return Err(Error::serve(format!(
                    "request {} payload {} != len {} × d_model {d}",
                    r.id,
                    r.payload.len(),
                    r.len
                )));
            }
            plane[i * slot * d..(i * slot + r.len) * d].copy_from_slice(&r.payload);
        }

        let t0 = Instant::now();
        let (seq_for_perf, class) = (slot, batch.class);
        let out = entry.exe.run_f32(&plane, tokens, d)?;
        let host_us = t0.elapsed().as_nanos() as f64 / 1e3;

        let perf = self.perf(class, seq_for_perf);
        let per_req_uj = perf.chip_uj / n_req as f64;
        let per_req_ema = perf.ema_bytes / n_req as u64;

        let now = Instant::now();
        let mut responses = Vec::with_capacity(n_req);
        for (i, r) in batch.requests.iter().enumerate() {
            let start = i * slot * d;
            responses.push(Response {
                id: r.id,
                output: out[start..start + r.len * d].to_vec(),
                host_latency_us: host_us,
                queue_us: now.duration_since(r.arrival).as_nanos() as f64 / 1e3
                    - host_us,
                chip_us: perf.chip_us,
                chip_uj: per_req_uj,
                ema_bytes: per_req_ema,
                class,
                utilization: perf.utilization,
            });
        }
        Ok(responses)
    }
}
