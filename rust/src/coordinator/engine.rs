//! Engine: executes formed batches and decode steps — numerics via the
//! runtime backend, performance via the cycle-level simulator.
//!
//! **Prefill** ([`Engine::execute`]): the engine pads each request to its
//! class's per-input slot, concatenates the batch on the token axis (the
//! chip's reconfigured 128-token plane), runs the class's compiled
//! executable, and splits the output back per request. Requests with
//! `generate > 0` don't complete here: they come back as [`DecodeState`]s
//! that the pool re-enqueues for token-level continuous batching. Their
//! decode budget is clamped to the GB's KV-residency cap for the class
//! ([`GbBudget::max_decode_len`]) — capped, never rejected.
//!
//! **Chunked prefill** ([`Engine::begin_prefill`] /
//! [`Engine::prefill_chunk`]): the same pass, split into phase-group
//! chunks so the worker loop can interleave decode steps mid-prefill
//! instead of letting one long pass monopolize a worker (the paper's
//! utilization argument, applied to the serving plane). Between chunks the
//! simulation parks as a [`PrefillState`] — a suspended
//! [`crate::sim::Stepper`] plus the batch — in the shared work pool; the
//! final chunk runs the numerics and settles stats **bit-identical** to
//! the monolithic pass. KV registration happens at the *first* chunk (the
//! prefix becomes arena-resident as prefill starts writing it), so a shed
//! mid-prefill must release it — the worker's Err path does.
//!
//! **Decode** ([`Engine::execute_decode`]): one autoregressive step for a
//! group of up to [`MAX_DECODE_GROUP`] streams, which may sit at *different*
//! KV depths (the group is whatever the queue held between steps). Each
//! stream emits one [`TokenEvent`]; exhausted streams fold into their final
//! [`Response`]. A stream's FIRST step is simulated exactly (program
//! rebuild + op walk, cached per `(group size, max KV depth)` in the shared
//! [`SimCache`]); every steady-state step is priced through the compiled
//! [`StepPlan`] — O(phases) arithmetic on a reusable scratch stepper, zero
//! per-step heap allocation, bit-identical to the exact path (the parity
//! sweep pins it). The step's weight-streaming EMA is split across the
//! group — the decode-side amortization the paper's batching argument
//! predicts.
//!
//! In the worker pool each worker owns its own `Engine` (executables are
//! not `Send`), but all engines share one [`SimCache`] — every pass is
//! simulated exactly once process-wide, with chunked prefills claiming
//! their key via the cache's in-flight guard — and one [`PlanRegistry`],
//! so every decode plan is compiled exactly once.

use crate::config::{HwConfig, ModelConfig};
use crate::coordinator::batcher::FormedBatch;
use crate::coordinator::request::{Request, RequestId, Response, TokenEvent};
use crate::coordinator::server::WorkerCtx;
use crate::coordinator::sim_cache::{CachedPass, ChunkClaim, PassKey, SimCache};
use crate::error::{Error, Result};
use crate::fleet::Fleet;
use crate::kv::{KvArenaConfig, KvManager, KvQuant};
use crate::model::{build_decode_step, build_program, Program};
use crate::obs::{SpanEvent, SpanKind, SpanWriter};
use crate::runtime::ArtifactSet;
use crate::sim::{
    simulate, BatchClass, GbBudget, PlanRegistry, SimOptions, StepPlan, Stepper, StepperParts,
};
use std::sync::Arc;
use std::time::Instant;

/// Most streams one decode step batches (the chip's four-up plane slicing).
pub const MAX_DECODE_GROUP: usize = crate::kv::MAX_GROUP_STREAMS;

/// Engine configuration.
pub struct EngineConfig {
    pub hw: HwConfig,
    /// Model whose *performance* is simulated per batch.
    pub perf_model: ModelConfig,
    /// Run the artifact self-test at startup.
    pub self_test: bool,
    /// KV-cache arena precision: residency accounting, decode caps, and the
    /// per-step dequant charge all follow it. `Fp16` is the honest
    /// full-precision baseline; `Int8`/`Int4` halve/quarter residency.
    pub kv_quant: KvQuant,
    /// Override the KV arena's page budget (`None`: carve it out of the GB
    /// after the fixed decode residents — see [`KvArenaConfig::for_pool`]).
    pub kv_pages: Option<usize>,
}

/// A generate request's in-flight decode stream between steps. Created by
/// [`Engine::execute`] after prefill, advanced one token per
/// [`Engine::execute_decode`], folded into a final [`Response`] when
/// `remaining` hits zero.
#[derive(Debug)]
pub struct DecodeState {
    pub id: RequestId,
    /// Class the request prefilled in (metrics attribution + cap basis).
    pub class: BatchClass,
    pub prefill_len: usize,
    /// Current KV depth: prefill length + tokens generated so far.
    pub past_len: usize,
    /// Tokens still to generate (> 0; already clamped to the residency cap).
    pub remaining: usize,
    pub generated: usize,
    /// Prefix-sharing group the request declared (`Request::prefix_group`)
    /// — the fleet router's placement-affinity key: every mate of one
    /// group decodes on the same chip, so the shared radix chain migrates
    /// there once.
    pub prefix_group: Option<u64>,
    pub arrival: Instant,
    /// Current token embedding (`d_model` wide) — next step's input row.
    last: Vec<f32>,
    /// Prefill output, held for the final response.
    output: Vec<f32>,
    queue_us: f64,
    utilization: f64,
    chip_us: f64,
    chip_uj: f64,
    ema_bytes: u64,
    /// Where this stream's last recorded span ended (µs on the flight
    /// recorder's clock). Each decode step records a span from here to its
    /// own completion, so a stream's spans tile its whole lifetime — they
    /// sum to its e2e latency. 0 when tracing is off (never read).
    span_cursor_us: f64,
}

impl DecodeState {
    /// Charge a chip-to-chip KV migration to this stream (fleet mode):
    /// the transfer's DRAM wall-stall and energy land on the stream's own
    /// ledger — like a `KvSwap`, priced at the source chip's operating
    /// point by the caller — before its first decode step on the target.
    pub fn charge_migration(&mut self, us: f64, uj: f64, bytes: u64) {
        self.chip_us += us;
        self.chip_uj += uj;
        self.ema_bytes += bytes;
    }

    fn into_response(self) -> Response {
        // The decode phase's wall time (between-steps queue residency plus
        // per-step host time) counts toward end-to-end latency: the host
        // side is "everything since arrival that wasn't prefill queueing",
        // so the documented `queue_us + host_latency_us` e2e invariant
        // holds for generate requests too. (The difference is non-negative:
        // Instant is monotonic and queue_us was measured at prefill start.)
        let e2e_us = self.arrival.elapsed().as_nanos() as f64 / 1e3;
        let host_latency_us = e2e_us - self.queue_us;
        Response {
            id: self.id,
            output: self.output,
            host_latency_us,
            queue_us: self.queue_us,
            chip_us: self.chip_us,
            chip_uj: self.chip_uj,
            ema_bytes: self.ema_bytes,
            class: self.class,
            utilization: self.utilization,
            prefill_len: self.prefill_len,
            tokens_generated: self.generated,
            worker: 0,
        }
    }
}

/// What one prefill batch produced: finished responses plus streams that
/// continue into the decode loop.
#[derive(Default)]
pub struct ExecOutcome {
    pub responses: Vec<Response>,
    pub decoding: Vec<DecodeState>,
}

/// A prefill batch parked between chunks: the requests, the built program,
/// the phase cursor, and the suspended simulation state. Lives in the
/// shared work pool alongside [`DecodeState`]s — any worker may resume it
/// (the suspended half is owned and `Send`; every pool engine clones the
/// same `HwConfig`/perf model, so resuming elsewhere is exact).
#[derive(Debug)]
pub struct PrefillState {
    class: BatchClass,
    requests: Vec<Request>,
    /// First-chunk start: `queue_us` is arrival → here, host latency spans
    /// here → completion (chunk gaps included — they are real host time the
    /// request experienced).
    t0: Instant,
    prog: Program,
    next_phase: usize,
    chunk_phases: usize,
    /// `Some`: this state OWNS the chunked simulation for its pass key
    /// (it claimed it via [`SimCache::begin_chunked`]) and steps it chunk
    /// by chunk. `None` with `cached` unset: a *follower* — another
    /// worker's chunked simulation was mid-flight at `begin_prefill`, so
    /// this state runs no simulation and resolves the value at its final
    /// chunk (riding the owner's publish).
    parts: Option<StepperParts>,
    /// The pass was already in the shared sim cache at `begin_prefill`:
    /// chunk-by-chunk re-simulation would duplicate work the pool promises
    /// to do exactly once, so the first chunk completes directly with this
    /// value (there is no simulation occupancy left to break up).
    cached: Option<CachedPass>,
    /// The shared cache the claim lives in (for the `Drop` release).
    cache: Arc<SimCache>,
    /// Holds the sim-cache in-flight claim for its key. Released by
    /// `publish_chunked` at the final chunk (which happens before any
    /// fallible numerics, so worker sheds never leak it); a state dropped
    /// while still owning — an external driver discarding a parked owner —
    /// abandons the claim in `Drop`, so later prefills of the key are
    /// never demoted to stalling followers.
    owns_key: bool,
    chunks_done: usize,
}

impl Drop for PrefillState {
    fn drop(&mut self) {
        if self.owns_key {
            self.cache.abandon_chunked(PassKey::prefill(self.class, self.prog.seq));
        }
    }
}

impl PrefillState {
    pub fn class(&self) -> BatchClass {
        self.class
    }
    pub fn n_requests(&self) -> usize {
        self.requests.len()
    }
    /// Ids holding KV registrations/reservations — what a shed must release.
    pub fn generate_ids(&self) -> Vec<RequestId> {
        self.requests.iter().filter(|r| r.generate > 0).map(|r| r.id).collect()
    }
    /// Every id in the batch — what a shed must mark terminal in the
    /// lifecycle ledger (encode-only requests too, not just KV holders).
    pub fn request_ids(&self) -> Vec<RequestId> {
        self.requests.iter().map(|r| r.id).collect()
    }
    pub fn chunks_done(&self) -> usize {
        self.chunks_done
    }
    /// This parked prefill holds the sim-cache in-flight claim for its
    /// pass key — it is the chunked-simulation owner; followers and
    /// cached-at-begin states return false.
    pub fn owns_simulation(&self) -> bool {
        self.owns_key
    }
    pub fn phases_done(&self) -> usize {
        self.next_phase
    }
    pub fn phases_total(&self) -> usize {
        self.prog.phases.len()
    }
}

/// One chunk's result: the pass parked again, or it completed. The parked
/// state is boxed — it carries a whole suspended simulation and would
/// otherwise dwarf the other variant.
pub enum PrefillProgress {
    Parked(Box<PrefillState>),
    Done(ExecOutcome),
}

/// What one decode step produced: one token per participating stream,
/// streams still decoding, and final responses for exhausted streams.
#[derive(Default)]
pub struct DecodeOutcome {
    pub tokens: Vec<TokenEvent>,
    pub active: Vec<DecodeState>,
    pub responses: Vec<Response>,
    /// Token-slots the step wasted padding shallower members to the
    /// deepest (`Σ max(past) − past_i`) — what depth-bucketed grouping
    /// exists to bound.
    pub pad_waste_tokens: u64,
    /// Evicted members that had to swap their KV back in for this step.
    pub kv_swap_ins: u64,
    /// Swap-in EMA bytes the step paid before running.
    pub kv_swap_bytes: u64,
    /// The step was priced through the compiled plan path (steady state)
    /// rather than the exact program rebuild.
    pub planned: bool,
}

/// Slots in the engine's direct-mapped plan-step memo. Groups of streams
/// revisit the same `(group, past_len)` while a cohort decodes in lockstep;
/// the memo catches those without the shared cache growing one entry per
/// depth. (First steps still insert `PassKey{past_len}` entries via the
/// exact path, so the shared decode family grows with first-step depths —
/// but no longer with every token of every generation.)
const PLAN_MEMO_SLOTS: usize = 32;

/// One memoized plan-priced step (`group` 0 marks an empty slot).
#[derive(Debug, Clone, Copy, Default)]
struct PlanMemoSlot {
    group: usize,
    past_len: usize,
    pass: CachedPass,
}

/// Reused per-step buffers for [`Engine::execute_decode`]: the decode hot
/// path re-fills these instead of allocating fresh vectors every token.
#[derive(Debug, Default)]
struct DecodeScratch {
    plane: Vec<f32>,
    past_lens: Vec<usize>,
    members: Vec<(RequestId, usize)>,
}

/// Executes batches. Owns the compiled artifacts; the simulation cache is
/// shared (keyed by [`PassKey`] — programs are deterministic), and so is
/// the [`KvManager`] in pool setups — aggregate KV residency is a
/// *pool-wide* property, not a per-worker one — and the [`PlanRegistry`]
/// of compiled decode step plans.
pub struct Engine {
    artifacts: ArtifactSet,
    cfg: EngineConfig,
    sim_cache: Arc<SimCache>,
    /// Paged KV-cache manager: registered at prefill, consulted before
    /// every decode step (swap-in charges), released at completion.
    kv: Arc<KvManager>,
    /// Per-class decode-length caps (indexed by `BatchClass::index()`),
    /// derived from the GB's KV residency at the class's batch width and
    /// the arena's quantization mode.
    decode_caps: [usize; 3],
    /// Compiled decode step plans, shared pool-wide (one compile per
    /// `(model, group, quant)` key across all workers).
    plans: Arc<PlanRegistry>,
    /// Per-group-width handles into the registry (this engine's model and
    /// quant are fixed), so the hot path never touches the registry lock
    /// after the first step at each width.
    plan_cache: [Option<Arc<StepPlan>>; MAX_DECODE_GROUP + 1],
    /// Reusable plan-execution state, suspended between steps: the
    /// steady-state decode hot path prices steps with zero per-step heap
    /// allocations (ledger nodes and frontier state persist across
    /// [`Stepper::reset`]).
    plan_scratch: Option<StepperParts>,
    /// Small per-engine memo of recently plan-priced steps.
    plan_memo: [PlanMemoSlot; PLAN_MEMO_SLOTS],
    /// Reused decode-step buffers.
    scratch: DecodeScratch,
    /// Flight-recorder handle bound to this worker's lane (`None`: tracing
    /// off — every record site below is a branch on this option, so the
    /// disabled hot path allocates and locks nothing).
    obs: Option<SpanWriter>,
    /// Plan-registry namespace ([`PlanRegistry::get_or_compile_scoped`]):
    /// 0 for the single-chip pool (all workers share plans); for a fleet
    /// worker, [`plan_scope_for`]`(chip, epoch)` — chips at different
    /// operating points compile different step timings for the same
    /// `(model, group, quant)` key, and the epoch qualifier means a
    /// re-pointed chip's stale plans are simply never addressable again.
    plan_scope: u64,
    /// The fleet this engine's chip lives in (`None`: single-chip pool —
    /// no runtime re-pointing, every `sync_operating_point` is a no-op).
    fleet: Option<Arc<Fleet>>,
    /// Index of the bound chip in `fleet` (worker i ↔ chip i).
    chip: usize,
    /// Last chip operating-point epoch this engine re-costed at. The DVFS
    /// governor bumps the chip's epoch on every re-point; the engine
    /// adopts it — new `HwConfig`, fresh plan scope, cleared caches —
    /// before the next batch/step it executes.
    op_epoch: u64,
}

/// Plan-registry scope for a fleet chip at an operating-point epoch. Low
/// 16 bits carry `chip + 1` (0 is the single-chip scope), the rest the
/// epoch, so every `(chip, epoch)` pair prices into a distinct namespace
/// and a stale plan can never be fetched after a re-point.
fn plan_scope_for(chip: usize, epoch: u64) -> u64 {
    (chip as u64 + 1) | (epoch << 16)
}

impl Engine {
    /// Engine with a private simulation cache (single-engine setups).
    pub fn new(artifacts: ArtifactSet, cfg: EngineConfig) -> Result<Self> {
        Self::with_cache(artifacts, cfg, Arc::new(SimCache::new()))
    }

    /// Engine over a shared simulation cache with a *private* KV manager —
    /// the single-engine shape. Pool workers should share one manager via
    /// [`Engine::with_parts`] / [`Engine::for_worker`] instead, or each
    /// worker budgets the arena as if it owned the whole GB.
    pub fn with_cache(
        artifacts: ArtifactSet,
        cfg: EngineConfig,
        sim_cache: Arc<SimCache>,
    ) -> Result<Self> {
        let kv = Arc::new(KvManager::new(
            &cfg.hw,
            &cfg.perf_model,
            KvArenaConfig::for_pool(&cfg.hw, &cfg.perf_model, cfg.kv_quant, cfg.kv_pages),
        ));
        Self::with_parts(artifacts, cfg, sim_cache, kv, Arc::new(PlanRegistry::new()))
    }

    /// Engine over an explicitly shared simulation cache, KV manager *and*
    /// step-plan registry (the pool path). The manager's quantization mode
    /// is authoritative for decode caps, dequant charges and plan keys.
    pub fn with_parts(
        artifacts: ArtifactSet,
        cfg: EngineConfig,
        sim_cache: Arc<SimCache>,
        kv: Arc<KvManager>,
        plans: Arc<PlanRegistry>,
    ) -> Result<Self> {
        if cfg.self_test {
            artifacts.self_test()?;
        }
        let mut decode_caps = [0usize; 3];
        for class in BatchClass::ALL {
            decode_caps[class.index()] = GbBudget::max_decode_len_quant(
                &cfg.hw,
                &cfg.perf_model,
                class.batch(),
                kv.quant(),
            );
        }
        Ok(Engine {
            artifacts,
            cfg,
            sim_cache,
            kv,
            decode_caps,
            plans,
            plan_cache: std::array::from_fn(|_| None),
            plan_scratch: None,
            plan_memo: [PlanMemoSlot::default(); PLAN_MEMO_SLOTS],
            scratch: DecodeScratch::default(),
            obs: None,
            plan_scope: 0,
            fleet: None,
            chip: 0,
            op_epoch: 0,
        })
    }

    /// Convenience for pool engine factories: shared cache always, shared
    /// KV manager always — the one from `PoolConfig::kv` when configured,
    /// else a pool-wide fallback the first worker's engine installs in
    /// [`WorkerCtx::kv_shared`] (decode streams hop workers through the
    /// shared queue, so per-worker private arenas would leak entries and
    /// miss eviction/swap charges).
    pub fn for_worker(
        artifacts: ArtifactSet,
        mut cfg: EngineConfig,
        ctx: &WorkerCtx,
    ) -> Result<Self> {
        // Fleet worker: the factory's HwConfig is the catalog's *base*;
        // this worker runs its bound chip — its *current* operating point
        // (the governor may have re-pointed it already), GB override, and
        // a per-chip plan-registry scope (plans compiled at one chip's
        // frequency must not serve another's).
        if let Some(fleet) = &ctx.fleet {
            cfg.hw = fleet.chip(ctx.worker).current_hw();
        }
        let kv = match &ctx.kv {
            Some(kv) => Arc::clone(kv),
            None => Arc::clone(ctx.kv_shared.get_or_init(|| {
                Arc::new(KvManager::new(
                    &cfg.hw,
                    &cfg.perf_model,
                    KvArenaConfig::for_pool(&cfg.hw, &cfg.perf_model, cfg.kv_quant, cfg.kv_pages),
                ))
            })),
        };
        let mut engine =
            Self::with_parts(artifacts, cfg, Arc::clone(&ctx.sim_cache), kv, Arc::clone(&ctx.plans))?;
        engine.obs = ctx.obs.clone();
        if let Some(fleet) = &ctx.fleet {
            let epoch = fleet.chip(ctx.worker).op_epoch();
            engine.fleet = Some(Arc::clone(fleet));
            engine.chip = ctx.worker;
            engine.op_epoch = epoch;
            engine.plan_scope = plan_scope_for(ctx.worker, epoch);
        }
        Ok(engine)
    }

    /// Attach (or detach) a flight-recorder writer. Pool engines inherit
    /// theirs from [`WorkerCtx::obs`]; standalone engines can opt in here.
    pub fn set_span_writer(&mut self, obs: Option<SpanWriter>) {
        self.obs = obs;
    }

    pub fn model_name(&self) -> &str {
        &self.artifacts.model_name
    }
    pub fn d_model(&self) -> usize {
        self.artifacts.d_model
    }
    pub fn max_seq(&self) -> usize {
        self.artifacts.max_seq
    }
    pub fn sim_cache(&self) -> &Arc<SimCache> {
        &self.sim_cache
    }
    /// The paged KV-cache manager this engine charges residency against.
    pub fn kv_manager(&self) -> &Arc<KvManager> {
        &self.kv
    }

    /// Admission cap on total KV depth (prefill + generated) for a class:
    /// the longest prefix the GB keeps resident at the class's batch width.
    pub fn decode_cap(&self, class: BatchClass) -> usize {
        self.decode_caps[class.index()]
    }

    /// Adopt the bound chip's current operating point if the DVFS governor
    /// re-pointed it since this engine last priced work. Atomic re-cost of
    /// everything compiled at the old point — plans are compiled per
    /// operating point, so a stale plan is a *correctness* bug, not just a
    /// perf bug: new `HwConfig`, fresh (epoch-qualified) plan scope so
    /// stale registry entries are unreachable, old scope freed, per-engine
    /// plan handles/memo/scratch dropped, and the chip's sim cache cleared
    /// (a `PassKey` does not carry the operating point). `decode_caps` are
    /// GB-byte-derived and a VDD re-point leaves the GB alone, so the
    /// admission caps streams were admitted under keep holding.
    ///
    /// Called at the top of [`Engine::execute`], [`Engine::begin_prefill`]
    /// and [`Engine::execute_decode`] — every entry point that prices work
    /// — so the window between a governor re-point and adoption is at most
    /// the batch/step already executing, which priced coherently at the
    /// old point.
    fn sync_operating_point(&mut self) {
        let Some(fleet) = &self.fleet else { return };
        let chip = fleet.chip(self.chip);
        let epoch = chip.op_epoch();
        if epoch == self.op_epoch {
            return;
        }
        let old_scope = self.plan_scope;
        self.cfg.hw = chip.current_hw();
        self.plan_cache = std::array::from_fn(|_| None);
        self.plan_memo = [PlanMemoSlot::default(); PLAN_MEMO_SLOTS];
        self.plan_scratch = None;
        self.sim_cache.clear();
        self.op_epoch = epoch;
        self.plan_scope = plan_scope_for(self.chip, epoch);
        self.plans.invalidate_scope(old_scope);
    }

    fn sim_options(&self, gb: GbBudget) -> SimOptions {
        // Double-buffered W_D prefetch is only legal when its second slot
        // fits the GB alongside the other residents (gb.rs); past that point
        // the chip streams single-buffered — which is exactly the regime
        // `max_decode_len`'s single-buffer cap extends into, so simulate the
        // DMA stalls it actually pays there.
        SimOptions {
            act_bits: self.cfg.perf_model.act_bits,
            prefetch: gb.fits_with_prefetch(),
            gb: Some(gb),
            ..SimOptions::paper(&self.cfg.hw)
        }
    }

    /// Compute (no caching) the chip pass value for a batch class at `seq`.
    fn prefill_pass_value(&self, class: BatchClass, seq: usize) -> CachedPass {
        let m = &self.cfg.perf_model;
        let prog = build_program(m, seq, class.batch());
        let gb = GbBudget::for_config(&self.cfg.hw, m, seq, class.batch());
        let stats = simulate(&self.cfg.hw, &prog, &self.sim_options(gb));
        CachedPass {
            chip_us: stats.seconds() * 1e6,
            chip_uj: stats.energy.total_uj(),
            ema_bytes: stats.ema_bytes(),
            ema_kv_bytes: stats.ema_kv_bytes(),
            utilization: stats.utilization(&self.cfg.hw),
        }
    }

    /// Simulate (with shared caching) the chip pass for a batch class at
    /// `seq`. Rides an in-flight chunked owner's simulation when one holds
    /// the key, so the monolithic and chunked paths together still compute
    /// each pass exactly once.
    fn perf(&self, class: BatchClass, seq: usize) -> CachedPass {
        self.sim_cache
            .wait_or_simulate(PassKey::prefill(class, seq), || self.prefill_pass_value(class, seq))
    }

    /// Simulate (with shared caching) one decode step of a `group`-stream
    /// batch at KV depth `past_len` — the EXACT path: build the step
    /// program and walk it through the Stepper. Kept for prefill-adjacent
    /// first steps (and as the plan path's parity anchor); steady-state
    /// steps go through [`Engine::decode_perf_plan`]. The budget and the
    /// dequant charge follow the arena's quantization mode; both are
    /// deterministic in `(group, past_len)`, so they live inside the
    /// cached pass (swap-in charges are *not* — they depend on eviction
    /// history and are added per occurrence by [`Engine::execute_decode`]).
    fn decode_perf(&self, group: usize, past_len: usize) -> CachedPass {
        let quant = self.kv.quant();
        self.sim_cache.get_or_simulate(PassKey::decode(group, past_len, quant), || {
            let m = &self.cfg.perf_model;
            let prog = build_decode_step(m, past_len, group);
            let gb = GbBudget::for_decode_quant(&self.cfg.hw, m, past_len, group, quant);
            let mut opts = self.sim_options(gb);
            // The chip pads the group to its deepest member, so the
            // dequant pass covers the padded planes too.
            opts.kv_dequant_bytes_per_layer = self.kv.dequant_bytes_per_layer(group, past_len);
            let stats = simulate(&self.cfg.hw, &prog, &opts);
            CachedPass {
                chip_us: stats.seconds() * 1e6,
                chip_uj: stats.energy.total_uj(),
                ema_bytes: stats.ema_bytes(),
                ema_kv_bytes: stats.ema_kv_bytes(),
                utilization: stats.utilization(&self.cfg.hw),
            }
        })
    }

    /// Price one steady-state decode step through the compiled plan:
    /// O(phases) arithmetic against a reusable scratch stepper — zero heap
    /// allocation per step once warm — memoized per `(group, past_len)` in
    /// a small direct-mapped table. Bit-identical to [`Engine::decode_perf`]
    /// (the parity sweep pins `run_plan` against the rebuilt program).
    fn decode_perf_plan(&mut self, group: usize, past_len: usize) -> CachedPass {
        let slot = group.wrapping_mul(31).wrapping_add(past_len) % PLAN_MEMO_SLOTS;
        let hit = self.plan_memo[slot];
        if hit.group == group && hit.past_len == past_len {
            return hit.pass;
        }
        if self.plan_cache[group].is_none() {
            let quant = self.kv.quant();
            let plan = {
                let hw = &self.cfg.hw;
                let m = &self.cfg.perf_model;
                self.plans.get_or_compile_scoped(self.plan_scope, &m.name, group, quant, || {
                    StepPlan::compile_budgeted(hw, m, group, quant)
                })
            };
            self.plan_cache[group] = Some(plan);
        }
        let plan = Arc::clone(self.plan_cache[group].as_ref().expect("cache just filled"));
        // Stale-plan detector: a plan compiled at a different operating
        // point than this engine's current one must never price a step.
        // `sync_operating_point` + epoch-qualified scopes make this
        // unreachable; if it ever fires (a future re-point path missing an
        // invalidation), count it on the chip — the fuzzer's invariant
        // asserts the counter stays zero — and recompile at the current
        // point so the step still prices correctly.
        let plan = if plan.point == self.cfg.hw.max_point() {
            plan
        } else {
            if let Some(fleet) = &self.fleet {
                fleet.chip(self.chip).note_stale_plan();
            }
            self.plan_cache[group] = None;
            Arc::new(StepPlan::compile_budgeted(
                &self.cfg.hw,
                &self.cfg.perf_model,
                group,
                self.kv.quant(),
            ))
        };
        let parts = match self.plan_scratch.take() {
            Some(parts) => parts,
            None => {
                let opts = SimOptions {
                    act_bits: self.cfg.perf_model.act_bits,
                    ..SimOptions::paper(&self.cfg.hw)
                };
                Stepper::new(&self.cfg.hw, opts).suspend()
            }
        };
        let mut stepper = Stepper::resume(&self.cfg.hw, parts);
        stepper.reset();
        stepper.run_plan(&plan, past_len);
        let s = stepper.settle();
        let pass = CachedPass {
            chip_us: s.seconds() * 1e6,
            chip_uj: s.energy.total_uj(),
            ema_bytes: s.ema_bytes,
            ema_kv_bytes: s.ema_kv_bytes,
            utilization: s.utilization(&self.cfg.hw),
        };
        self.plan_scratch = Some(stepper.suspend());
        self.plan_memo[slot] = PlanMemoSlot { group, past_len, pass };
        pass
    }

    /// Execute one formed prefill batch end-to-end.
    ///
    /// Timing is split explicitly at `t0`, the instant this engine began
    /// serving the batch: `queue_us` is arrival → `t0` (pure waiting:
    /// batcher residency + work-queue residency), `host_latency_us` is
    /// `t0` → response built (plane assembly + executable run + split).
    /// A request that arrived while another batch was executing therefore
    /// accrues that wait in `queue_us` and can never go negative.
    pub fn execute(&mut self, batch: FormedBatch) -> Result<ExecOutcome> {
        self.sync_operating_point();
        let t0 = Instant::now();
        let entry = self.artifacts.get(batch.class)?;
        let d = entry.d_model;
        let slot = entry.seq; // per-input token slot of this class
        let tokens = entry.tokens;
        let n_req = batch.requests.len();
        if n_req == 0 || n_req > entry.batch {
            return Err(Error::serve(format!(
                "batch of {n_req} requests for class {}",
                batch.class.name()
            )));
        }
        let plane = assemble_plane(&batch.requests, d, slot, tokens)?;
        let (seq_for_perf, class) = (slot, batch.class);
        let out = entry.exe.run_f32(&plane, tokens, d)?;
        let perf = self.perf(class, seq_for_perf);
        Ok(self.finish_prefill(batch.requests, class, &out, d, slot, perf, t0, true))
    }

    /// Start a chunked prefill: validate the batch, register KV for its
    /// generate streams (first-chunk registration — see module docs), build
    /// the pass program and park a fresh simulation at phase 0. The caller
    /// then drives [`Engine::prefill_chunk`] until it reports `Done`. When
    /// the pass is already in the shared sim cache, the chunk loop is
    /// skipped entirely, so repeat prefills of a key never re-simulate.
    /// Cold keys are claimed through the cache's per-key in-flight guard
    /// ([`SimCache::begin_chunked`]): exactly one racer becomes the owner
    /// and simulates chunk by chunk; the others become *followers* that
    /// run no simulation and ride the owner's published value at their
    /// final chunk — chunked and monolithic paths together compute every
    /// pass exactly once (closing the race PR 4 documented as accepted).
    ///
    /// Payload-shape validation is deferred to the final chunk's plane
    /// assembly: a malformed payload sheds *mid-prefill*, exercising the
    /// release path a parked prefill needs anyway.
    pub fn begin_prefill(
        &mut self,
        batch: FormedBatch,
        chunk_phases: usize,
    ) -> Result<PrefillState> {
        self.sync_operating_point();
        let t0 = Instant::now();
        let entry = self.artifacts.get(batch.class)?;
        let slot = entry.seq;
        let max_batch = entry.batch;
        let n_req = batch.requests.len();
        if n_req == 0 || n_req > max_batch {
            return Err(Error::serve(format!(
                "batch of {n_req} requests for class {}",
                batch.class.name()
            )));
        }
        for r in &batch.requests {
            if r.len > slot {
                return Err(Error::serve(format!(
                    "request {} len {} exceeds class slot {slot}",
                    r.id, r.len
                )));
            }
        }
        let class = batch.class;
        let cap = self.decode_cap(class);
        for r in &batch.requests {
            if r.generate == 0 {
                continue;
            }
            if r.generate.min(cap.saturating_sub(r.len)) > 0 {
                // The prefix becomes arena-resident as the first chunk
                // starts writing it (no swap charge — written fresh).
                self.kv.register(r.id, r.len, r.prefix_group);
            } else {
                // Cap-clamped to zero: give back the admission reservation.
                self.kv.release(r.id);
            }
        }
        let m = &self.cfg.perf_model;
        let prog = build_program(m, slot, class.batch());
        let (cached, parts, owns_key) = match self.sim_cache.begin_chunked(PassKey::prefill(
            class, slot,
        )) {
            ChunkClaim::Cached(pass) => (Some(pass), None, false),
            ChunkClaim::Owner => {
                let gb = GbBudget::for_config(&self.cfg.hw, m, slot, class.batch());
                let opts = self.sim_options(gb);
                (None, Some(Stepper::new(&self.cfg.hw, opts).suspend()), true)
            }
            // Another worker's chunked simulation is mid-flight: follow it.
            ChunkClaim::InFlight => (None, None, false),
        };
        Ok(PrefillState {
            class,
            requests: batch.requests,
            t0,
            prog,
            next_phase: 0,
            chunk_phases: chunk_phases.max(1),
            parts,
            cached,
            cache: Arc::clone(&self.sim_cache),
            owns_key,
            chunks_done: 0,
        })
    }

    /// Advance a parked prefill by one chunk (`chunk_phases` phases). While
    /// phases remain the state parks again — the worker returns it to the
    /// shared pool so decode steps (or other work) can interleave. The
    /// final chunk settles the chunked simulation (bit-identical to the
    /// monolithic pass — pinned by `chunked_phase_ranges_match_monolithic`
    /// at the sim layer and by the engine-level equivalence integration
    /// test), runs the numerics, and completes exactly like
    /// [`Engine::execute`].
    pub fn prefill_chunk(&mut self, mut st: PrefillState) -> Result<PrefillProgress> {
        let key = PassKey::prefill(st.class, st.prog.seq);
        let chunk_t0 = self.obs.as_ref().map(|w| w.now_us());
        let mut published: Option<CachedPass> = None;
        if st.cached.is_none() {
            if let Some(parts) = st.parts.take() {
                // Owner: advance the claimed chunked simulation.
                let mut stepper = Stepper::resume(&self.cfg.hw, parts);
                let total = st.prog.phases.len();
                let end = (st.next_phase + st.chunk_phases).min(total);
                stepper.run_phases(&st.prog, st.next_phase..end);
                st.next_phase = end;
                st.chunks_done += 1;
                if let Some(w) = &self.obs {
                    // Batch-scoped worker-lane detail (id 0): the stream
                    // view carries one tiling Prefill span instead.
                    let mut ev =
                        SpanEvent::marker(SpanKind::PrefillChunk, 0, chunk_t0.unwrap_or(0.0));
                    ev.t_end_us = w.now_us();
                    ev.past_len = st.prog.seq as u32;
                    ev.group = st.chunks_done as u32;
                    w.record(ev);
                }
                if end < total {
                    st.parts = Some(stepper.suspend());
                    return Ok(PrefillProgress::Parked(Box::new(st)));
                }
                stepper.account_program(&st.prog);
                let stats = stepper.finish();
                let pass = CachedPass {
                    chip_us: stats.seconds() * 1e6,
                    chip_uj: stats.energy.total_uj(),
                    ema_bytes: stats.ema_bytes(),
                    ema_kv_bytes: stats.ema_kv_bytes(),
                    utilization: stats.utilization(&self.cfg.hw),
                };
                // Publish BEFORE the fallible numerics below: the simulated
                // value is payload-independent, so even a batch that sheds
                // on a malformed payload leaves the cache warm — and the
                // claim released, so followers never stall on a shed owner.
                published = Some(self.sim_cache.publish_chunked(key, pass));
                st.owns_key = false;
            }
        }
        let perf = if let Some(pass) = published {
            pass
        } else if let Some(pass) = st.cached {
            // Cached at begin: nothing was re-stepped — count the hit when
            // the value is actually consumed (as the monolithic path does).
            self.sim_cache.get_or_simulate(key, || pass)
        } else {
            // Follower: ride the in-flight owner's publish (bounded wait);
            // if the owner shed, compute exactly once under the cache lock.
            self.sim_cache
                .wait_or_simulate(key, || self.prefill_pass_value(st.class, st.prog.seq))
        };
        let entry = self.artifacts.get(st.class)?;
        let (d, slot, tokens) = (entry.d_model, entry.seq, entry.tokens);
        // Deferred payload validation: a malformed payload errors HERE,
        // mid-prefill — the worker's shed path releases the first-chunk KV
        // registrations.
        let plane = assemble_plane(&st.requests, d, slot, tokens)?;
        let out = entry.exe.run_f32(&plane, tokens, d)?;
        // `take`, not move: PrefillState has a Drop guard for its claim.
        let requests = std::mem::take(&mut st.requests);
        Ok(PrefillProgress::Done(self.finish_prefill(
            requests,
            st.class,
            &out,
            d,
            slot,
            perf,
            st.t0,
            false,
        )))
    }

    /// Split a finished prefill pass back into per-request responses and
    /// decode streams. `register_kv` is true on the monolithic path (KV
    /// registration happens here); the chunked path registered at its
    /// first chunk.
    #[allow(clippy::too_many_arguments)]
    fn finish_prefill(
        &self,
        requests: Vec<Request>,
        class: BatchClass,
        out: &[f32],
        d: usize,
        slot: usize,
        perf: CachedPass,
        t0: Instant,
        register_kv: bool,
    ) -> ExecOutcome {
        let n_req = requests.len();
        let per_req_uj = perf.chip_uj / n_req as f64;
        let per_req_ema = perf.ema_bytes / n_req as u64;
        let per_req_kv_ema = perf.ema_kv_bytes / n_req as u64;
        let host_us = t0.elapsed().as_nanos() as f64 / 1e3;
        let cap = self.decode_cap(class);
        // Tracing: one timestamp for the whole batch — spans are derived
        // from the latencies already measured, not re-measured per span.
        let obs_now = self.obs.as_ref().map(|w| w.now_us());

        let mut outcome = ExecOutcome::default();
        for (i, r) in requests.iter().enumerate() {
            let start = i * slot * d;
            let output = out[start..start + r.len * d].to_vec();
            let queue_us = t0.saturating_duration_since(r.arrival).as_nanos() as f64 / 1e3;
            // Clamp the decode budget so prefill + generated never outgrows
            // the resident KV prefix — capped, not rejected.
            let generate = r.generate.min(cap.saturating_sub(r.len));
            let now_us = obs_now.unwrap_or(0.0);
            if let Some(w) = &self.obs {
                // Queue and prefill spans tile arrival → now exactly:
                // [arrival, t0] + [t0, now] with t0 = now − host_us.
                let t0_us = now_us - host_us;
                let mut q = SpanEvent::marker(SpanKind::Queue, r.id, (t0_us - queue_us).max(0.0));
                q.t_end_us = t0_us;
                w.record(q);
                let mut pf = SpanEvent::marker(SpanKind::Prefill, r.id, t0_us);
                pf.t_end_us = now_us;
                pf.chip_us = perf.chip_us;
                pf.chip_uj = per_req_uj;
                pf.ema_bytes = per_req_ema;
                pf.ema_kv_bytes = per_req_kv_ema;
                pf.past_len = r.len as u32;
                pf.group = n_req as u32;
                w.record(pf);
            }
            if generate > 0 {
                if register_kv {
                    // The stream's prefill KV becomes arena-resident (no
                    // swap charge — prefill writes the planes fresh).
                    self.kv.register(r.id, r.len, r.prefix_group);
                }
                // The stream's next input is its last prefill output row.
                let last = output[(r.len - 1) * d..r.len * d].to_vec();
                outcome.decoding.push(DecodeState {
                    id: r.id,
                    class,
                    prefill_len: r.len,
                    past_len: r.len,
                    remaining: generate,
                    generated: 0,
                    prefix_group: r.prefix_group,
                    arrival: r.arrival,
                    last,
                    output,
                    queue_us,
                    utilization: perf.utilization,
                    chip_us: perf.chip_us,
                    chip_uj: per_req_uj,
                    ema_bytes: per_req_ema,
                    span_cursor_us: now_us,
                });
            } else {
                if r.generate > 0 && register_kv {
                    // Asked to generate but cap-clamped to zero: release
                    // any admission reservation so the arena slot frees.
                    self.kv.release(r.id);
                }
                outcome.responses.push(Response {
                    id: r.id,
                    output,
                    host_latency_us: host_us,
                    queue_us,
                    chip_us: perf.chip_us,
                    chip_uj: per_req_uj,
                    ema_bytes: per_req_ema,
                    class,
                    utilization: perf.utilization,
                    prefill_len: r.len,
                    tokens_generated: 0,
                    worker: 0,
                });
                if let Some(w) = &self.obs {
                    w.record(SpanEvent::marker(SpanKind::Complete, r.id, now_us));
                }
            }
        }
        outcome
    }

    /// Execute ONE decode step for a group of streams. Group membership is
    /// whatever the pool's queue held — streams join and leave between
    /// steps, and their KV depths may differ (the chip pads to the deepest;
    /// the simulation is keyed by that max).
    ///
    /// The group arrives in the caller's reusable buffer and is **drained**
    /// on success (the worker loop re-pops into the same buffer every step
    /// — no per-step group allocation). On error the buffer is left intact
    /// so the shed path can read the member ids.
    ///
    /// Pricing: a group whose members have all generated at least one
    /// token is in steady state and goes through the compiled plan
    /// ([`Engine::decode_perf_plan`]); a group containing a stream's FIRST
    /// decode step keeps the exact rebuild path — prefill-adjacent, cold
    /// by definition, and it keeps the exact path continuously exercised
    /// in production as the plan's parity anchor.
    ///
    /// Numerics run one `d_model` row per stream through the backend — the
    /// reference backend accepts any row count; fixed-shape AOT artifacts
    /// would need dedicated decode executables (ROADMAP).
    pub fn execute_decode(&mut self, group: &mut Vec<DecodeState>) -> Result<DecodeOutcome> {
        self.sync_operating_point();
        let n = group.len();
        if n == 0 {
            return Ok(DecodeOutcome::default());
        }
        if n > MAX_DECODE_GROUP {
            return Err(Error::serve(format!("decode group of {n} exceeds {MAX_DECODE_GROUP}")));
        }
        let d = self.artifacts.d_model;
        self.scratch.plane.clear();
        self.scratch.past_lens.clear();
        self.scratch.members.clear();
        for s in group.iter() {
            if s.last.len() != d {
                return Err(Error::serve(format!(
                    "stream {}: token row {} != d_model {d}",
                    s.id,
                    s.last.len()
                )));
            }
            self.scratch.plane.extend_from_slice(&s.last);
            self.scratch.past_lens.push(s.past_len);
            self.scratch.members.push((s.id, s.past_len));
        }
        let max_past = *self.scratch.past_lens.iter().max().expect("non-empty group");
        let steady = group.iter().all(|s| s.generated > 0);
        // Aggregate residency: every member becomes arena-resident at its
        // current depth before the step — evicted members pay swap-in EMA
        // for their whole KV (parked streams are never free).
        let charge = self.kv.prepare_group(&self.scratch.members);
        let swap_us = self.cfg.hw.dram_ns(charge.swap_in_bytes as usize) * 1e-3;
        let swap_uj = self.cfg.hw.dram_pj(charge.swap_in_bytes as usize) * 1e-6;
        // Any class entry works: the decode plane is row-wise and `n` rows.
        let out = self.artifacts.get(BatchClass::B4)?.exe.run_f32(&self.scratch.plane, n, d)?;
        let perf =
            if steady { self.decode_perf_plan(n, max_past) } else { self.decode_perf(n, max_past) };
        // Two conventions, both deliberate: energy/EMA are *shares* (the
        // step's cost split across the group, like prefill's per-request
        // split), while `us_per_token` is the paper's µs/token (step wall
        // time over n tokens) and `Response.chip_us` accumulates the FULL
        // step latency — every rider experiences the whole step's wall
        // time, swap-in stalls included.
        let step_us = perf.chip_us + swap_us;
        let per_us = step_us / n as f64;
        let per_uj = (perf.chip_uj + swap_uj) / n as f64;
        let per_ema = (perf.ema_bytes + charge.swap_in_bytes) / n as u64;

        let per_kv_ema = (perf.ema_kv_bytes + charge.swap_in_bytes) / n as u64;
        let obs_now = self.obs.as_ref().map(|w| w.now_us());

        let mut outcome = DecodeOutcome {
            pad_waste_tokens: self.scratch.past_lens.iter().map(|&p| (max_past - p) as u64).sum(),
            kv_swap_ins: charge.swap_ins,
            kv_swap_bytes: charge.swap_in_bytes,
            planned: steady,
            ..DecodeOutcome::default()
        };
        for (i, mut s) in group.drain(..).enumerate() {
            let step_past = s.past_len;
            let index = s.generated;
            // Reuse the stream's token-row allocation (validated == d).
            s.last.copy_from_slice(&out[i * d..(i + 1) * d]);
            s.past_len += 1;
            s.generated += 1;
            s.remaining -= 1;
            s.chip_us += step_us;
            s.chip_uj += per_uj;
            s.ema_bytes += per_ema;
            if let Some(w) = &self.obs {
                // The span runs from the stream's previous span end (not
                // this step's dispatch): between-step queue residency is
                // real latency the request experienced, and charging it
                // here makes a stream's spans tile its e2e exactly.
                let now_us = obs_now.unwrap_or(0.0);
                let mut ev = SpanEvent::marker(SpanKind::DecodeStep, s.id, s.span_cursor_us);
                ev.t_end_us = now_us;
                ev.chip_us = per_us;
                ev.chip_uj = per_uj;
                ev.ema_bytes = per_ema;
                ev.ema_kv_bytes = per_kv_ema;
                ev.past_len = step_past as u32;
                ev.group = n as u32;
                w.record(ev);
                s.span_cursor_us = now_us;
                if s.remaining == 0 {
                    w.record(SpanEvent::marker(SpanKind::Complete, s.id, now_us));
                }
            }
            outcome.tokens.push(TokenEvent {
                id: s.id,
                index,
                past_len: step_past,
                us_per_token: per_us,
                chip_uj: per_uj,
                ema_bytes: per_ema,
                group_past_lens: self.scratch.past_lens.clone(),
                worker: 0,
                emitted: Instant::now(),
            });
            if s.remaining == 0 {
                // Final token: the stream's arena pages and admission
                // reservation free up for waiting streams.
                self.kv.release(s.id);
                outcome.responses.push(s.into_response());
            } else {
                outcome.active.push(s);
            }
        }
        // Step done: surviving members park (resident, evictable again).
        self.kv.finish_group(&self.scratch.members);
        Ok(outcome)
    }
}

/// Assemble the class token plane: each request padded to its per-input
/// slot; missing batch-mates (deadline flush) stay zero. Validates payload
/// shape — the only per-request check that needs the payload itself.
fn assemble_plane(requests: &[Request], d: usize, slot: usize, tokens: usize) -> Result<Vec<f32>> {
    let mut plane = vec![0.0f32; tokens * d];
    for (i, r) in requests.iter().enumerate() {
        if r.len > slot {
            return Err(Error::serve(format!(
                "request {} len {} exceeds class slot {slot}",
                r.id, r.len
            )));
        }
        if r.payload.len() != r.len * d {
            return Err(Error::serve(format!(
                "request {} payload {} != len {} × d_model {d}",
                r.id,
                r.payload.len(),
                r.len
            )));
        }
        plane[i * slot * d..(i * slot + r.len) * d].copy_from_slice(&r.payload);
    }
    Ok(plane)
}

#[cfg(test)]
impl DecodeState {
    /// Bare stream for grouper unit tests (no payload, one token left).
    pub(crate) fn stub(id: RequestId, class: BatchClass, past_len: usize) -> DecodeState {
        DecodeState {
            id,
            class,
            prefill_len: past_len,
            past_len,
            remaining: 1,
            generated: 0,
            prefix_group: None,
            arrival: Instant::now(),
            last: Vec::new(),
            output: Vec::new(),
            queue_us: 0.0,
            utilization: 0.0,
            chip_us: 0.0,
            chip_uj: 0.0,
            span_cursor_us: 0.0,
            ema_bytes: 0,
        }
    }
}
