//! Serving metrics: lock-protected running aggregates + final report.
//!
//! The pool keeps one `ServerMetrics` per worker plus one pooled sink every
//! worker also records into, so per-worker and pooled views stay consistent
//! without a merge pass at shutdown. Percentiles (p50/p95/p99) come from the
//! raw end-to-end latency samples each sink retains.

use crate::sim::BatchClass;
use crate::util::json::Json;
use crate::util::stats::Running;
use std::sync::Mutex;

#[derive(Debug, Default)]
struct Inner {
    completed: u64,
    batches: u64,
    tokens: u64,
    /// Requests refused at admission (backpressure / malformed length).
    rejected: u64,
    /// Batches dropped because the engine's execute failed.
    execute_errors: u64,
    host_latency_us: Running,
    queue_us: Running,
    chip_us: Running,
    chip_uj: Running,
    utilization: Running,
    ema_bytes: u64,
    per_class: [u64; 3],
    /// Raw end-to-end latencies for percentile reporting.
    latencies: Vec<f64>,
}

/// Thread-safe metrics sink shared by engine workers.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, class: BatchClass, n_requests: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.per_class[class.index()] += n_requests as u64;
    }

    pub fn record_response(&self, r: &crate::coordinator::request::Response, len: usize) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.tokens += len as u64;
        m.host_latency_us.push(r.host_latency_us);
        m.queue_us.push(r.queue_us);
        m.chip_us.push(r.chip_us);
        m.chip_uj.push(r.chip_uj);
        m.utilization.push(r.utilization);
        m.ema_bytes += r.ema_bytes;
        m.latencies.push(r.host_latency_us + r.queue_us);
    }

    /// A request refused at admission (backpressure or bad length).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A batch the engine failed to execute (its requests are shed).
    pub fn record_execute_error(&self) {
        self.inner.lock().unwrap().execute_errors += 1;
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn rejected(&self) -> u64 {
        self.inner.lock().unwrap().rejected
    }

    pub fn execute_errors(&self) -> u64 {
        self.inner.lock().unwrap().execute_errors
    }

    /// Snapshot as JSON (also the report printed by examples).
    pub fn report(&self, wall_seconds: f64) -> Json {
        let m = self.inner.lock().unwrap();
        let thr = if wall_seconds > 0.0 { m.completed as f64 / wall_seconds } else { 0.0 };
        let tok_thr = if wall_seconds > 0.0 { m.tokens as f64 / wall_seconds } else { 0.0 };
        let pct = |p: f64| Json::num(crate::util::stats::percentile(&m.latencies, p));
        Json::obj(vec![
            ("completed", Json::num(m.completed as f64)),
            ("batches", Json::num(m.batches as f64)),
            ("tokens", Json::num(m.tokens as f64)),
            ("rejected", Json::num(m.rejected as f64)),
            ("execute_errors", Json::num(m.execute_errors as f64)),
            ("throughput_rps", Json::num(thr)),
            ("throughput_tok_s", Json::num(tok_thr)),
            ("host_latency_us_mean", Json::num(m.host_latency_us.mean())),
            ("e2e_latency_us_p50", pct(50.0)),
            ("e2e_latency_us_p95", pct(95.0)),
            ("e2e_latency_us_p99", pct(99.0)),
            ("queue_us_mean", Json::num(m.queue_us.mean())),
            ("chip_us_per_pass_mean", Json::num(m.chip_us.mean())),
            ("chip_uj_per_request_mean", Json::num(m.chip_uj.mean())),
            ("utilization_mean", Json::num(m.utilization.mean())),
            ("ema_bytes_total", Json::num(m.ema_bytes as f64)),
            (
                "requests_per_class",
                Json::obj(vec![
                    ("b1", Json::num(m.per_class[0] as f64)),
                    ("b2", Json::num(m.per_class[1] as f64)),
                    ("b4", Json::num(m.per_class[2] as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;

    #[test]
    fn aggregates() {
        let m = ServerMetrics::new();
        m.record_batch(BatchClass::B4, 4);
        for i in 0..4 {
            m.record_response(
                &Response {
                    id: i,
                    output: vec![],
                    host_latency_us: 100.0,
                    queue_us: 50.0,
                    chip_us: 10.0,
                    chip_uj: 1.0,
                    ema_bytes: 1000,
                    class: BatchClass::B4,
                    utilization: 0.5,
                    worker: 0,
                },
                8,
            );
        }
        m.record_rejected();
        assert_eq!(m.completed(), 4);
        assert_eq!(m.rejected(), 1);
        let j = m.report(2.0);
        assert_eq!(j.get("throughput_rps").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("tokens").unwrap().as_f64().unwrap(), 32.0);
        assert_eq!(j.get("rejected").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("ema_bytes_total").unwrap().as_f64().unwrap(), 4000.0);
        assert_eq!(j.get("e2e_latency_us_p50").unwrap().as_f64().unwrap(), 150.0);
        assert_eq!(j.get("e2e_latency_us_p95").unwrap().as_f64().unwrap(), 150.0);
        assert_eq!(
            j.get("requests_per_class").unwrap().get("b4").unwrap().as_f64().unwrap(),
            4.0
        );
    }
}
