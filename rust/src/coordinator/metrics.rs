//! Serving metrics: lock-protected running aggregates + final report.
//!
//! The pool keeps one `ServerMetrics` per worker plus one pooled sink every
//! worker also records into, so per-worker and pooled views stay consistent
//! without a merge pass at shutdown. Percentiles (p50/p95/p99) come from
//! bounded [`Reservoir`] samplers — exact on small runs, O(1)-memory under
//! sustained traffic (the raw `Vec` they replaced grew without bound and
//! leaked in a long-running pool).

use crate::coordinator::request::RequestId;
use crate::sim::BatchClass;
use crate::util::json::Json;
use crate::util::stats::{percentile, Reservoir, Running, RESERVOIR_CAP};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Chunk-completion instants retained for observability (tests/benches
/// verify decode tokens interleave *between* a prefill's chunks).
const CHUNK_MARKS_CAP: usize = 1024;

/// Version stamped into every machine-readable report this crate emits
/// (`ServerReport`, `ReplayStats`, telemetry snapshots, span JSONL, the
/// Chrome trace's `otherData`). Bump when a key is renamed, removed, or
/// changes meaning; pure additions keep the version. Consumers can also
/// rely on stable key *order*: all JSON objects serialize through
/// `util::json::Json::Obj` (a `BTreeMap`), so keys are always emitted in
/// sorted order regardless of insertion order.
///
/// v2: fleet-aware reports — every `workers[]` entry carries `chip_id`,
/// fleet pools add `kv_arena_per_chip`, and Chrome traces group worker
/// lanes one process per chip.
pub const REPORT_SCHEMA_VERSION: u64 = 2;

#[derive(Debug, Default)]
struct Inner {
    completed: u64,
    batches: u64,
    tokens: u64,
    /// Decode steps executed (token-level batches).
    decode_steps: u64,
    /// Tokens generated autoregressively across all streams.
    tokens_decoded: u64,
    /// Token-slots decode steps wasted padding shallower group members to
    /// the deepest (what depth-bucketed grouping bounds).
    pad_waste_tokens: u64,
    /// Evicted streams swapped back into the KV arena (and the EMA bytes
    /// those swap-ins were charged).
    kv_swap_ins: u64,
    kv_swap_bytes: u64,
    /// Requests refused at admission (backpressure / malformed length).
    rejected: u64,
    /// Batches dropped because the engine's execute failed.
    execute_errors: u64,
    /// Prefill chunks executed (0 with chunking off; ≥ phase-count/chunk
    /// per batch with it on).
    prefill_chunks: u64,
    /// Decode steps that ran while at least one prefill was parked
    /// mid-flight — the interleaving chunked prefill exists to buy.
    interleaved_decode_steps: u64,
    /// Decode steps priced through the compiled step plan (steady state);
    /// the rest took the exact program-rebuild path (first steps).
    decode_plan_steps: u64,
    /// Coalescing wait each dispatched decode group's oldest member paid.
    coalesce_wait_us: Running,
    /// Chunk-completion instants (bounded; observability for tests).
    chunk_marks: Vec<Instant>,
    host_latency_us: Running,
    queue_us: Running,
    chip_us: Running,
    chip_uj: Running,
    utilization: Running,
    ema_bytes: u64,
    per_class: [u64; 3],
    /// End-to-end latency samples for percentile reporting (bounded).
    latencies: Reservoir,
    /// Modeled per-token decode latency samples (bounded).
    us_per_token: Reservoir,
    /// Modeled per-token decode energy samples (bounded).
    uj_per_token: Reservoir,
    /// *Interval* window of modeled us/token samples — everything recorded
    /// since the last [`ServerMetrics::take_interval`] drain. This is the
    /// DVFS governor's observation signal: the cumulative reservoirs above
    /// average over the whole run and go numb to load swings, while this
    /// window is exactly one sampler tick wide. Bounded: past
    /// [`RESERVOIR_CAP`] samples, new arrivals ring-overwrite the oldest
    /// (the count stays exact; percentiles cover the most recent window).
    interval_us: Vec<f64>,
    /// Tokens recorded into the current interval (including overwritten).
    interval_seen: u64,
}

/// One drained sampler interval of decode-token latency
/// ([`ServerMetrics::take_interval`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IntervalStats {
    /// Tokens recorded since the previous drain (exact even when the
    /// bounded window overwrote old samples).
    pub tokens: u64,
    /// Modeled us/token p50 over the interval window (0 when empty).
    pub us_per_token_p50: f64,
    /// Modeled us/token p95 over the interval window (0 when empty).
    pub us_per_token_p95: f64,
}

/// The counter snapshot the telemetry sampler reads each interval —
/// cheap (one lock, reservoir percentiles over ≤ cap samples) relative to
/// the full JSON [`ServerMetrics::report`].
#[derive(Debug, Clone, Copy, Default)]
pub struct MetricsSample {
    pub completed: u64,
    pub rejected: u64,
    pub execute_errors: u64,
    pub tokens_decoded: u64,
    pub interleave_ratio: f64,
    pub coalesce_wait_us_mean: f64,
    pub us_per_token_p50: f64,
    pub us_per_token_p95: f64,
    pub uj_per_token_p50: f64,
    pub uj_per_token_p95: f64,
}

/// Where one admitted request currently is in its lifecycle. Terminal
/// states carry the instant of the transition so ordering properties
/// ("no token event after its stream sheds") are checkable after the fact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Admitted (submit returned `Ok`), no terminal event yet.
    Admitted,
    /// Final response sent.
    Completed,
    /// Shed after admission (batcher reject, engine execute error, chunk
    /// or decode-group failure) — the request will never answer.
    Shed,
}

/// Per-request lifecycle ledger (opt-in via
/// [`crate::coordinator::PoolConfig::lifecycle_ledger`]): every admitted
/// request must reach **exactly one** terminal state — completed or shed —
/// which is the scheduler-conservation invariant the fuzzer and the replay
/// driver check. Transition violations (double terminal, terminal without
/// admission, re-admission of a live id) are latched as strings rather
/// than panicking the pool: the *checker* fails, the serving plane keeps
/// running.
#[derive(Debug, Default)]
struct LedgerInner {
    states: HashMap<RequestId, (Lifecycle, Instant)>,
    admitted: u64,
    completed: u64,
    shed: u64,
    violations: Vec<String>,
}

impl LedgerInner {
    fn admit(&mut self, id: RequestId) {
        match self.states.get(&id) {
            Some((Lifecycle::Admitted, _)) => {
                self.violations.push(format!("request {id} admitted twice while live"));
            }
            // Id reuse after a terminal is legal (a client retrying a shed
            // id): the new life starts a fresh entry.
            _ => {
                self.states.insert(id, (Lifecycle::Admitted, Instant::now()));
                self.admitted += 1;
            }
        }
    }

    fn terminal(&mut self, id: RequestId, to: Lifecycle) {
        let verb = if to == Lifecycle::Completed { "completed" } else { "shed" };
        match self.states.get_mut(&id) {
            Some(entry) => {
                if entry.0 == Lifecycle::Admitted {
                    *entry = (to, Instant::now());
                    if to == Lifecycle::Completed {
                        self.completed += 1;
                    } else {
                        self.shed += 1;
                    }
                } else {
                    self.violations.push(format!(
                        "request {id} {verb} after already terminal ({:?}) — double terminal",
                        entry.0
                    ));
                }
            }
            None => {
                self.violations.push(format!("request {id} {verb} without admission"));
            }
        }
    }
}

/// Snapshot of the ledger for post-drain auditing.
#[derive(Debug, Clone, Default)]
pub struct LedgerAudit {
    pub admitted: u64,
    pub completed: u64,
    pub shed: u64,
    /// Admitted requests with no terminal event — after a full drain this
    /// must be empty (a non-empty list is a lost request).
    pub open: Vec<RequestId>,
    /// Transition violations observed live (double terminal, terminal
    /// without admission, re-admission of a live id).
    pub violations: Vec<String>,
}

impl LedgerAudit {
    /// Conservation holds: every admission reached exactly one terminal.
    pub fn conserved(&self) -> bool {
        self.open.is_empty()
            && self.violations.is_empty()
            && self.admitted == self.completed + self.shed
    }
}

/// Thread-safe metrics sink shared by engine workers.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
    /// `Some` once [`ServerMetrics::enable_ledger`] ran — the pool enables
    /// it on the pooled sink only (per-worker sinks see a per-id lifecycle
    /// only partially: prefill and final decode step may run on different
    /// workers).
    ledger: Mutex<Option<LedgerInner>>,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    // ------------------------------------------------------ lifecycle ledger

    /// Turn on per-request lifecycle tracking (see [`LedgerAudit`]). Off by
    /// default: the ledger holds one entry per request ever admitted, which
    /// is unbounded memory under sustained production traffic — it exists
    /// for the fuzzer, the replay driver, and tests.
    pub fn enable_ledger(&self) {
        let mut l = self.ledger.lock().unwrap();
        if l.is_none() {
            *l = Some(LedgerInner::default());
        }
    }

    pub fn ledger_enabled(&self) -> bool {
        self.ledger.lock().unwrap().is_some()
    }

    /// A request's submit returned `Ok` — it is now the pool's to finish.
    pub fn ledger_admit(&self, id: RequestId) {
        if let Some(l) = self.ledger.lock().unwrap().as_mut() {
            l.admit(id);
        }
    }

    /// Terminal: final response sent.
    pub fn ledger_complete(&self, id: RequestId) {
        if let Some(l) = self.ledger.lock().unwrap().as_mut() {
            l.terminal(id, Lifecycle::Completed);
        }
    }

    /// Terminal: shed after admission — the request will never answer.
    pub fn ledger_shed(&self, id: RequestId) {
        if let Some(l) = self.ledger.lock().unwrap().as_mut() {
            l.terminal(id, Lifecycle::Shed);
        }
    }

    /// Current lifecycle of one id (with the instant of its last
    /// transition), if the ledger is enabled and has seen it.
    pub fn ledger_state(&self, id: RequestId) -> Option<(Lifecycle, Instant)> {
        self.ledger.lock().unwrap().as_ref().and_then(|l| l.states.get(&id).copied())
    }

    /// Snapshot for post-drain auditing (`None`: ledger disabled).
    pub fn ledger_audit(&self) -> Option<LedgerAudit> {
        let guard = self.ledger.lock().unwrap();
        let l = guard.as_ref()?;
        let mut open: Vec<RequestId> = l
            .states
            .iter()
            .filter(|(_, (s, _))| *s == Lifecycle::Admitted)
            .map(|(id, _)| *id)
            .collect();
        open.sort_unstable();
        Some(LedgerAudit {
            admitted: l.admitted,
            completed: l.completed,
            shed: l.shed,
            open,
            violations: l.violations.clone(),
        })
    }

    pub fn record_batch(&self, class: BatchClass, n_requests: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.per_class[class.index()] += n_requests as u64;
    }

    pub fn record_response(&self, r: &crate::coordinator::request::Response, len: usize) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.tokens += len as u64;
        m.host_latency_us.push(r.host_latency_us);
        m.queue_us.push(r.queue_us);
        m.chip_us.push(r.chip_us);
        m.chip_uj.push(r.chip_uj);
        m.utilization.push(r.utilization);
        m.ema_bytes += r.ema_bytes;
        m.latencies.push(r.host_latency_us + r.queue_us);
    }

    /// One generated token (streamed mid-request by a decode step).
    ///
    /// Deliberately does NOT add `ev.ema_bytes` (or energy) into the running
    /// totals: the stream's final [`Response`] accumulates every step's
    /// share, and `record_response` counts that once — adding it here too
    /// would double-count decode EMA.
    pub fn record_token(&self, ev: &crate::coordinator::request::TokenEvent) {
        let mut m = self.inner.lock().unwrap();
        m.tokens_decoded += 1;
        m.us_per_token.push(ev.us_per_token);
        m.uj_per_token.push(ev.chip_uj);
        // Interval window: bounded ring-overwrite so a sampler that stalls
        // (or a pool with telemetry off) never grows this without limit.
        if m.interval_us.len() < RESERVOIR_CAP {
            m.interval_us.push(ev.us_per_token);
        } else {
            let slot = (m.interval_seen as usize) % RESERVOIR_CAP;
            m.interval_us[slot] = ev.us_per_token;
        }
        m.interval_seen += 1;
    }

    /// Drain the per-interval us/token window: percentiles over everything
    /// recorded since the previous drain, then reset. One consumer — the
    /// telemetry sampler calls this once per tick and shares the result
    /// with the snapshot ring and the DVFS governor. Empty intervals (no
    /// decode traffic since the last tick) report zeros, never NaN.
    pub fn take_interval(&self) -> IntervalStats {
        let mut m = self.inner.lock().unwrap();
        let stats = IntervalStats {
            tokens: m.interval_seen,
            us_per_token_p50: percentile(&m.interval_us, 50.0),
            us_per_token_p95: percentile(&m.interval_us, 95.0),
        };
        m.interval_us.clear();
        m.interval_seen = 0;
        stats
    }

    /// One decode step executed (any group size), with the step's padding
    /// waste, KV swap-in charges, whether it interleaved with a parked
    /// prefill, whether the compiled plan priced it, and the coalescing
    /// wait its group paid before dispatch.
    #[allow(clippy::too_many_arguments)]
    pub fn record_decode_step(
        &self,
        pad_waste_tokens: u64,
        kv_swap_ins: u64,
        kv_swap_bytes: u64,
        interleaved: bool,
        planned: bool,
        coalesce_wait_us: f64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.decode_steps += 1;
        m.pad_waste_tokens += pad_waste_tokens;
        m.kv_swap_ins += kv_swap_ins;
        m.kv_swap_bytes += kv_swap_bytes;
        if interleaved {
            m.interleaved_decode_steps += 1;
        }
        if planned {
            m.decode_plan_steps += 1;
        }
        m.coalesce_wait_us.push(coalesce_wait_us);
    }

    /// Decode steps priced through the compiled step plan.
    pub fn decode_plan_steps(&self) -> u64 {
        self.inner.lock().unwrap().decode_plan_steps
    }

    /// One prefill chunk executed (parked again or completed).
    pub fn record_prefill_chunk(&self) {
        let mut m = self.inner.lock().unwrap();
        m.prefill_chunks += 1;
        if m.chunk_marks.len() < CHUNK_MARKS_CAP {
            m.chunk_marks.push(Instant::now());
        }
    }

    /// A request refused at admission (backpressure or bad length).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A batch the engine failed to execute (its requests are shed).
    pub fn record_execute_error(&self) {
        self.inner.lock().unwrap().execute_errors += 1;
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn tokens_decoded(&self) -> u64 {
        self.inner.lock().unwrap().tokens_decoded
    }

    pub fn pad_waste_tokens(&self) -> u64 {
        self.inner.lock().unwrap().pad_waste_tokens
    }

    pub fn prefill_chunks(&self) -> u64 {
        self.inner.lock().unwrap().prefill_chunks
    }

    pub fn interleaved_decode_steps(&self) -> u64 {
        self.inner.lock().unwrap().interleaved_decode_steps
    }

    /// Chunk-completion instants, in execution order (bounded — the first
    /// `CHUNK_MARKS_CAP` chunks of the run).
    pub fn chunk_marks(&self) -> Vec<Instant> {
        self.inner.lock().unwrap().chunk_marks.clone()
    }

    pub fn kv_swap_bytes(&self) -> u64 {
        self.inner.lock().unwrap().kv_swap_bytes
    }

    pub fn rejected(&self) -> u64 {
        self.inner.lock().unwrap().rejected
    }

    pub fn execute_errors(&self) -> u64 {
        self.inner.lock().unwrap().execute_errors
    }

    /// One cheap snapshot of the counters the telemetry sampler emits
    /// per interval — a single lock acquisition, no JSON.
    pub fn sample(&self) -> MetricsSample {
        let m = self.inner.lock().unwrap();
        let interleave = if m.decode_steps > 0 {
            m.interleaved_decode_steps as f64 / m.decode_steps as f64
        } else {
            0.0
        };
        MetricsSample {
            completed: m.completed,
            rejected: m.rejected,
            execute_errors: m.execute_errors,
            tokens_decoded: m.tokens_decoded,
            interleave_ratio: interleave,
            coalesce_wait_us_mean: m.coalesce_wait_us.mean(),
            us_per_token_p50: m.us_per_token.percentile(50.0),
            us_per_token_p95: m.us_per_token.percentile(95.0),
            uj_per_token_p50: m.uj_per_token.percentile(50.0),
            uj_per_token_p95: m.uj_per_token.percentile(95.0),
        }
    }

    /// Snapshot as JSON (also the report printed by examples).
    pub fn report(&self, wall_seconds: f64) -> Json {
        let m = self.inner.lock().unwrap();
        let thr = if wall_seconds > 0.0 { m.completed as f64 / wall_seconds } else { 0.0 };
        // Token throughput covers everything that crossed the server:
        // prefill tokens AND autoregressively decoded ones.
        let all_tokens = (m.tokens + m.tokens_decoded) as f64;
        let tok_thr = if wall_seconds > 0.0 { all_tokens / wall_seconds } else { 0.0 };
        let pct = |p: f64| Json::num(m.latencies.percentile(p));
        let tok_pct = |p: f64| Json::num(m.us_per_token.percentile(p));
        // Interleave ratio: share of decode steps that ran while a prefill
        // was parked mid-flight (0 with chunking off).
        let interleave = if m.decode_steps > 0 {
            m.interleaved_decode_steps as f64 / m.decode_steps as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("schema_version", Json::num(REPORT_SCHEMA_VERSION as f64)),
            ("completed", Json::num(m.completed as f64)),
            ("batches", Json::num(m.batches as f64)),
            ("tokens", Json::num(m.tokens as f64)),
            ("decode_steps", Json::num(m.decode_steps as f64)),
            ("decode_plan_steps", Json::num(m.decode_plan_steps as f64)),
            ("tokens_decoded", Json::num(m.tokens_decoded as f64)),
            ("prefill_chunks", Json::num(m.prefill_chunks as f64)),
            ("interleave_ratio", Json::num(interleave)),
            ("coalesce_wait_us_mean", Json::num(m.coalesce_wait_us.mean())),
            ("pad_waste_tokens", Json::num(m.pad_waste_tokens as f64)),
            ("kv_swap_ins", Json::num(m.kv_swap_ins as f64)),
            ("kv_swap_bytes", Json::num(m.kv_swap_bytes as f64)),
            ("rejected", Json::num(m.rejected as f64)),
            ("execute_errors", Json::num(m.execute_errors as f64)),
            ("throughput_rps", Json::num(thr)),
            ("throughput_tok_s", Json::num(tok_thr)),
            ("host_latency_us_mean", Json::num(m.host_latency_us.mean())),
            ("e2e_latency_us_p50", pct(50.0)),
            ("e2e_latency_us_p95", pct(95.0)),
            ("e2e_latency_us_p99", pct(99.0)),
            ("us_per_token_p50", tok_pct(50.0)),
            ("us_per_token_p95", tok_pct(95.0)),
            ("uj_per_token_p50", Json::num(m.uj_per_token.percentile(50.0))),
            ("uj_per_token_p95", Json::num(m.uj_per_token.percentile(95.0))),
            ("queue_us_mean", Json::num(m.queue_us.mean())),
            // Per *request*: for generate requests this is prefill + every
            // decode step the request joined, not a single pass.
            ("chip_us_per_request_mean", Json::num(m.chip_us.mean())),
            ("chip_uj_per_request_mean", Json::num(m.chip_uj.mean())),
            ("utilization_mean", Json::num(m.utilization.mean())),
            ("ema_bytes_total", Json::num(m.ema_bytes as f64)),
            (
                "requests_per_class",
                Json::obj(vec![
                    ("b1", Json::num(m.per_class[0] as f64)),
                    ("b2", Json::num(m.per_class[1] as f64)),
                    ("b4", Json::num(m.per_class[2] as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;

    fn resp(id: u64) -> Response {
        Response {
            id,
            output: vec![],
            host_latency_us: 100.0,
            queue_us: 50.0,
            chip_us: 10.0,
            chip_uj: 1.0,
            ema_bytes: 1000,
            class: BatchClass::B4,
            utilization: 0.5,
            prefill_len: 8,
            tokens_generated: 0,
            worker: 0,
        }
    }

    #[test]
    fn aggregates() {
        let m = ServerMetrics::new();
        m.record_batch(BatchClass::B4, 4);
        for i in 0..4 {
            m.record_response(&resp(i), 8);
        }
        m.record_rejected();
        assert_eq!(m.completed(), 4);
        assert_eq!(m.rejected(), 1);
        let j = m.report(2.0);
        assert_eq!(j.get("throughput_rps").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("tokens").unwrap().as_f64().unwrap(), 32.0);
        assert_eq!(j.get("rejected").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("ema_bytes_total").unwrap().as_f64().unwrap(), 4000.0);
        assert_eq!(j.get("e2e_latency_us_p50").unwrap().as_f64().unwrap(), 150.0);
        assert_eq!(j.get("e2e_latency_us_p95").unwrap().as_f64().unwrap(), 150.0);
        // No decode traffic: token percentiles report zero, not NaN.
        assert_eq!(j.get("tokens_decoded").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(j.get("us_per_token_p50").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(
            j.get("requests_per_class").unwrap().get("b4").unwrap().as_f64().unwrap(),
            4.0
        );
    }

    #[test]
    fn token_events_feed_us_per_token_percentiles() {
        use crate::coordinator::request::TokenEvent;
        use std::time::Instant;
        let m = ServerMetrics::new();
        for (i, us) in [100.0, 200.0, 300.0, 400.0, 500.0].iter().enumerate() {
            m.record_decode_step(0, 0, 0, false, false, 0.0);
            m.record_token(&TokenEvent {
                id: 7,
                index: i,
                past_len: 8 + i,
                us_per_token: *us,
                chip_uj: 0.5,
                ema_bytes: 10,
                group_past_lens: vec![8 + i],
                worker: 0,
                emitted: Instant::now(),
            });
        }
        assert_eq!(m.tokens_decoded(), 5);
        let j = m.report(1.0);
        assert_eq!(j.get("decode_steps").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.get("tokens_decoded").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.get("us_per_token_p50").unwrap().as_f64().unwrap(), 300.0);
        assert!((j.get("us_per_token_p95").unwrap().as_f64().unwrap() - 480.0).abs() < 1e-9);
        // Token events do NOT touch the EMA total — the final response
        // carries the accumulated decode shares and is counted exactly once
        // (no double counting).
        assert_eq!(j.get("ema_bytes_total").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn decode_step_pad_and_swap_counters_aggregate() {
        let m = ServerMetrics::new();
        m.record_decode_step(3, 1, 4096, true, true, 150.0);
        m.record_decode_step(0, 0, 0, false, false, 50.0);
        assert_eq!(m.pad_waste_tokens(), 3);
        assert_eq!(m.kv_swap_bytes(), 4096);
        assert_eq!(m.interleaved_decode_steps(), 1);
        assert_eq!(m.decode_plan_steps(), 1);
        let j = m.report(1.0);
        assert_eq!(j.get("decode_steps").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("decode_plan_steps").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("pad_waste_tokens").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("kv_swap_ins").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("kv_swap_bytes").unwrap().as_f64().unwrap(), 4096.0);
        assert_eq!(j.get("interleave_ratio").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(j.get("coalesce_wait_us_mean").unwrap().as_f64().unwrap(), 100.0);
    }

    #[test]
    fn prefill_chunks_counted_and_marked() {
        let m = ServerMetrics::new();
        assert_eq!(m.report(1.0).get("prefill_chunks").unwrap().as_f64().unwrap(), 0.0);
        for _ in 0..3 {
            m.record_prefill_chunk();
        }
        assert_eq!(m.prefill_chunks(), 3);
        let marks = m.chunk_marks();
        assert_eq!(marks.len(), 3);
        assert!(marks.windows(2).all(|w| w[0] <= w[1]), "marks in execution order");
    }

    #[test]
    fn latency_samples_stay_bounded_under_sustained_traffic() {
        // Regression: `latencies`/`us_per_token` grew one f64 per response/
        // token forever — a memory leak under sustained serving. The
        // reservoir keeps percentiles honest at O(cap) memory.
        use crate::coordinator::request::TokenEvent;
        use crate::util::stats::RESERVOIR_CAP;
        use std::time::Instant;
        let m = ServerMetrics::new();
        let n = (RESERVOIR_CAP * 3) as u64;
        for i in 0..n {
            m.record_response(&resp(i), 8);
            m.record_token(&TokenEvent {
                id: i,
                index: 0,
                past_len: 8,
                us_per_token: 250.0,
                chip_uj: 0.1,
                ema_bytes: 10,
                group_past_lens: vec![8],
                worker: 0,
                emitted: Instant::now(),
            });
        }
        {
            let inner = m.inner.lock().unwrap();
            assert_eq!(inner.latencies.len(), RESERVOIR_CAP, "bounded");
            assert_eq!(inner.latencies.seen(), n);
            assert_eq!(inner.us_per_token.len(), RESERVOIR_CAP, "bounded");
        }
        // Constant inputs → exact percentiles regardless of sampling.
        let j = m.report(1.0);
        assert_eq!(j.get("e2e_latency_us_p95").unwrap().as_f64().unwrap(), 150.0);
        assert_eq!(j.get("us_per_token_p50").unwrap().as_f64().unwrap(), 250.0);
        assert_eq!(j.get("tokens_decoded").unwrap().as_f64().unwrap(), n as f64);
    }

    fn tok(us: f64) -> crate::coordinator::request::TokenEvent {
        crate::coordinator::request::TokenEvent {
            id: 1,
            index: 0,
            past_len: 8,
            us_per_token: us,
            chip_uj: 0.1,
            ema_bytes: 10,
            group_past_lens: vec![8],
            worker: 0,
            emitted: Instant::now(),
        }
    }

    #[test]
    fn interval_window_boundaries_empty_single_and_wrap() {
        let m = ServerMetrics::new();

        // Empty interval: no decode traffic since the last drain — zeros,
        // never NaN (the sampler serializes these straight into JSON).
        let empty = m.take_interval();
        assert_eq!(empty, IntervalStats::default());
        assert!(empty.us_per_token_p50 == 0.0 && empty.us_per_token_p95 == 0.0);

        // Single sample: every percentile IS that sample.
        m.record_token(&tok(123.0));
        let one = m.take_interval();
        assert_eq!(one.tokens, 1);
        assert_eq!(one.us_per_token_p50, 123.0);
        assert_eq!(one.us_per_token_p95, 123.0);

        // The drain resets the window: the next interval starts empty.
        assert_eq!(m.take_interval(), IntervalStats::default());

        // Cumulative percentiles are NOT reset by interval drains.
        assert_eq!(m.sample().us_per_token_p50, 123.0);
    }

    #[test]
    fn interval_window_wraps_past_the_cap() {
        // Overfill the bounded window: the token count stays exact, and
        // the percentiles cover the most recent RESERVOIR_CAP samples —
        // the first (low) half was ring-overwritten by the second (high).
        let m = ServerMetrics::new();
        let n = RESERVOIR_CAP as u64 * 2;
        for i in 0..n {
            let us = if i < RESERVOIR_CAP as u64 { 1.0 } else { 1000.0 };
            m.record_token(&tok(us));
        }
        let iv = m.take_interval();
        assert_eq!(iv.tokens, n, "count exact despite overwrites");
        assert_eq!(iv.us_per_token_p50, 1000.0, "window holds the latest samples");
        assert_eq!(iv.us_per_token_p95, 1000.0);
        {
            let inner = m.inner.lock().unwrap();
            assert!(inner.interval_us.is_empty(), "drain clears the window");
        }
    }

    #[test]
    fn ledger_disabled_is_inert() {
        let m = ServerMetrics::new();
        m.ledger_admit(1);
        m.ledger_complete(1);
        assert!(!m.ledger_enabled());
        assert!(m.ledger_audit().is_none());
        assert!(m.ledger_state(1).is_none());
    }

    #[test]
    fn ledger_conservation_happy_path() {
        let m = ServerMetrics::new();
        m.enable_ledger();
        m.enable_ledger(); // idempotent — does not reset counts
        for id in 0..4u64 {
            m.ledger_admit(id);
        }
        m.ledger_complete(0);
        m.ledger_complete(1);
        m.ledger_shed(2);
        let mid = m.ledger_audit().unwrap();
        assert_eq!(mid.admitted, 4);
        assert_eq!(mid.open, vec![3]);
        assert!(!mid.conserved(), "3 is still open");
        m.ledger_complete(3);
        let done = m.ledger_audit().unwrap();
        assert!(done.conserved(), "{done:?}");
        assert_eq!((done.completed, done.shed), (3, 1));
        assert_eq!(m.ledger_state(2).unwrap().0, Lifecycle::Shed);
        assert_eq!(m.ledger_state(3).unwrap().0, Lifecycle::Completed);
    }

    #[test]
    fn ledger_latches_violations_instead_of_panicking() {
        let m = ServerMetrics::new();
        m.enable_ledger();
        m.ledger_admit(1);
        m.ledger_admit(1); // live re-admit
        m.ledger_complete(1);
        m.ledger_shed(1); // double terminal
        m.ledger_complete(9); // terminal without admission
        let a = m.ledger_audit().unwrap();
        assert_eq!(a.violations.len(), 3, "{:?}", a.violations);
        assert!(!a.conserved());
        assert!(a.violations[0].contains("admitted twice"));
        assert!(a.violations[1].contains("double terminal"));
        assert!(a.violations[2].contains("without admission"));
    }

    #[test]
    fn ledger_allows_id_reuse_after_terminal() {
        let m = ServerMetrics::new();
        m.enable_ledger();
        m.ledger_admit(7);
        m.ledger_shed(7);
        m.ledger_admit(7); // retry of a shed id: a fresh life
        m.ledger_complete(7);
        let a = m.ledger_audit().unwrap();
        assert!(a.conserved(), "{a:?}");
        assert_eq!((a.admitted, a.completed, a.shed), (2, 1, 1));
    }

    #[test]
    fn ledger_terminal_instants_order_token_events() {
        let m = ServerMetrics::new();
        m.enable_ledger();
        m.ledger_admit(1);
        let before = Instant::now();
        m.ledger_shed(1);
        let (state, at) = m.ledger_state(1).unwrap();
        assert_eq!(state, Lifecycle::Shed);
        assert!(at >= before, "terminal instant is of the transition");
    }

    #[test]
    fn decode_ema_counted_exactly_once_across_token_and_response() {
        use crate::coordinator::request::TokenEvent;
        use std::time::Instant;
        let m = ServerMetrics::new();
        // A generate request: prefill share 1000 + 3 decode steps × 10.
        for i in 0..3 {
            m.record_token(&TokenEvent {
                id: 1,
                index: i,
                past_len: 8 + i,
                us_per_token: 50.0,
                chip_uj: 0.1,
                ema_bytes: 10,
                group_past_lens: vec![8 + i],
                worker: 0,
                emitted: Instant::now(),
            });
        }
        let mut r = resp(1);
        r.ema_bytes = 1000 + 3 * 10; // final response accumulates the shares
        r.tokens_generated = 3;
        m.record_response(&r, 8);
        let j = m.report(1.0);
        assert_eq!(j.get("ema_bytes_total").unwrap().as_f64().unwrap(), 1030.0);
        assert_eq!(j.get("tokens_decoded").unwrap().as_f64().unwrap(), 3.0);
    }
}
