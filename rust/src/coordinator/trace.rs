//! Workload trace generation: request streams with the length distributions
//! that motivate dynamic batching (BERT-style NLU inputs are short; ViT is
//! always full-length).
//!
//! This generator is **closed-loop** — callers submit, drain, and retry, so
//! offered load self-throttles to pool capacity. For open-loop traffic
//! (submission on a trace clock, rejections shed at the door, overload that
//! actually overloads), see [`crate::workload`]: trace files, seeded
//! arrival-shape generators, and the replay driver behind `serve --trace`
//! and the `fig11_replay` bench.

use crate::config::ModelConfig;
use crate::coordinator::request::Request;
use crate::util::rng::Rng;

/// Deterministic, seeded request generator for a workload.
pub struct TraceGenerator {
    rng: Rng,
    mean_len: f64,
    max_len: usize,
    d_model: usize,
    next_id: u64,
    /// Fixed-length workloads (ViT) always emit `max_len`.
    fixed: bool,
    /// Sample lengths uniformly over each batch class in turn (equal
    /// B1/B2/B4 traffic) instead of the workload distribution.
    class_mix: bool,
    /// Decode tokens each emitted request asks for (0 = encode-only).
    generate: usize,
}

impl TraceGenerator {
    pub fn for_model(m: &ModelConfig, artifact_max_seq: usize, d_model: usize, seed: u64) -> Self {
        let max_len = m.max_seq.min(artifact_max_seq);
        let fixed = m.mean_input_len >= m.max_seq as f64;
        // Scale the workload's mean length into the artifact's token plane.
        let mean_len = m.mean_input_len / m.max_seq as f64 * max_len as f64;
        TraceGenerator {
            rng: Rng::new(seed),
            mean_len,
            max_len,
            d_model,
            next_id: 0,
            fixed,
            class_mix: false,
            generate: 0,
        }
    }

    /// Every emitted request asks for `n` decode tokens (builder-style).
    pub fn with_generate(mut self, n: usize) -> Self {
        self.generate = n;
        self
    }

    /// Uniform-random payload request with workload-distributed length.
    pub fn next(&mut self) -> Request {
        let len = if self.class_mix {
            // Pick a class uniformly, then a length uniform within it:
            // B4 ∈ [1, max/4], B2 ∈ (max/4, max/2], B1 ∈ (max/2, max].
            let quarter = (self.max_len / 4).max(1);
            match self.rng.below(3) {
                0 => self.rng.range(1, quarter),
                1 => self.rng.range(quarter + 1, (self.max_len / 2).max(quarter + 1)),
                _ => self.rng.range(self.max_len / 2 + 1, self.max_len),
            }
        } else if self.fixed {
            self.max_len
        } else {
            self.rng.seq_len(self.mean_len, self.max_len)
        };
        let payload: Vec<f32> = (0..len * self.d_model)
            .map(|_| self.rng.normal_f32() * 0.5)
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, len, payload).with_generate(self.generate)
    }

    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next()).collect()
    }

    /// Generator that offers the three batch classes in equal proportion —
    /// the mixed B1/B2/B4 load the pool benches and tests drive.
    pub fn mixed(max_seq: usize, d_model: usize, seed: u64) -> Self {
        TraceGenerator {
            rng: Rng::new(seed),
            mean_len: 0.0,
            max_len: max_seq,
            d_model,
            next_id: 0,
            fixed: false,
            class_mix: true,
            generate: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_in_range_and_short_biased_for_bert() {
        let m = ModelConfig::bert_large();
        let mut g = TraceGenerator::for_model(&m, 32, 64, 7);
        let reqs = g.take(500);
        assert!(reqs.iter().all(|r| (1..=32).contains(&r.len)));
        let mean = reqs.iter().map(|r| r.len as f64).sum::<f64>() / 500.0;
        // bert mean_input_len 28/128 scaled to 32-plane ⇒ ~7.
        assert!((3.0..12.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn vit_is_fixed_full_length() {
        let m = ModelConfig::vit_base();
        let mut g = TraceGenerator::for_model(&m, 32, 64, 7);
        assert!(g.take(50).iter().all(|r| r.len == 32));
    }

    #[test]
    fn mixed_trace_covers_all_classes() {
        use crate::sim::{batch_class, BatchClass};
        let mut g = TraceGenerator::mixed(32, 64, 11);
        let reqs = g.take(300);
        let mut per_class = [0usize; 3];
        for r in &reqs {
            assert!((1..=32).contains(&r.len));
            assert_eq!(r.payload.len(), r.len * 64);
            match batch_class(r.len, 32).unwrap() {
                BatchClass::B1 => per_class[0] += 1,
                BatchClass::B2 => per_class[1] += 1,
                BatchClass::B4 => per_class[2] += 1,
            }
        }
        // Equal-probability mix: each class sees a healthy share of 300.
        assert!(per_class.iter().all(|&n| n > 50), "per_class {per_class:?}");
    }

    #[test]
    fn ids_unique_and_payload_sized() {
        let m = ModelConfig::s2t_small();
        let mut g = TraceGenerator::for_model(&m, 32, 64, 9);
        let reqs = g.take(100);
        let mut ids: Vec<_> = reqs.iter().map(|r| r.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 100);
        assert!(reqs.iter().all(|r| r.payload.len() == r.len * 64));
    }
}
