//! Process-wide simulation cache shared by all pool workers.
//!
//! Chip passes are deterministic per [`PassKey`], so the cycle-level
//! simulation only ever needs to run once per key no matter how many engine
//! workers serve traffic. The cache computes misses *under the write lock*,
//! which guarantees exactly-once simulation even when several workers race
//! on a cold key — the simulation is microseconds-cheap next to a duplicated
//! run, and cold keys are rare.
//!
//! Keys carry `past_len` so decode steps cache alongside prefill passes:
//! a generate request's prefill (`past_len` = 0) shares the exact key a
//! plain request of the same class/slot uses — prefill results are reused as
//! decode prefixes — while each `(group size, KV depth)` decode step gets
//! its own entry.

use crate::kv::KvQuant;
use crate::sim::BatchClass;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Upper bound a [`SimCache::wait_or_simulate`] caller spends waiting for
/// an in-flight chunked owner before falling back to computing the value
/// itself (liveness over strict exactly-once in the stalled-owner corner —
/// an owner normally publishes in well under a millisecond of execution).
const CHUNK_WAIT_MAX: Duration = Duration::from_millis(100);

/// Identity of one deterministic chip pass.
///
/// * Prefill: `batch` = class batch, `seq` = the class's per-input slot,
///   `past_len` = 0, `kv_bits` = 0.
/// * Decode step: `batch` = decode-group size (1..=4), `seq` = 1,
///   `past_len` = the KV depth the step attends over, `kv_bits` = the
///   arena's storage width — decode timing/EMA depend on the quant mode
///   (dequant charge + quantized GB budget), so engines with different
///   modes sharing one cache must not collide.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PassKey {
    pub batch: usize,
    pub seq: usize,
    pub past_len: usize,
    pub kv_bits: u64,
}

impl PassKey {
    /// Key for a whole-sequence pass of `class` at per-input slot `seq`.
    pub fn prefill(class: BatchClass, seq: usize) -> PassKey {
        PassKey { batch: class.batch(), seq, past_len: 0, kv_bits: 0 }
    }

    /// Key for one decode step of a `batch`-stream group at KV depth
    /// `past_len` (always ≥ 1: the stream prefilled at least one token)
    /// over a `quant`-precision KV arena.
    pub fn decode(batch: usize, past_len: usize, quant: KvQuant) -> PassKey {
        PassKey { batch, seq: 1, past_len, kv_bits: quant.bits() }
    }
}

/// One simulated chip pass (the per-batch quantities the engine attaches to
/// every response it serves from that pass).
#[derive(Debug, Clone, Copy, Default)]
pub struct CachedPass {
    pub chip_us: f64,
    pub chip_uj: f64,
    pub ema_bytes: u64,
    /// KV share of `ema_bytes` (dequant re-streams; swap-ins are charged
    /// per occurrence by the engine, not cached here).
    pub ema_kv_bytes: u64,
    pub utilization: f64,
}

/// Hit/miss counters snapshot.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Outcome of claiming a key for an out-of-lock (chunked) simulation —
/// see [`SimCache::begin_chunked`].
pub enum ChunkClaim {
    /// Already simulated — complete directly, nothing to re-step.
    Cached(CachedPass),
    /// The caller owns the chunked simulation for this key. It must
    /// [`SimCache::publish_chunked`] when done (or
    /// [`SimCache::abandon_chunked`] on a shed) — the claim is what keeps
    /// racers from duplicating the compute.
    Owner,
    /// Another worker's chunked simulation is mid-flight: don't simulate;
    /// resolve the value at completion via [`SimCache::wait_or_simulate`].
    InFlight,
}

/// Thread-safe `PassKey → CachedPass` map with exactly-once compute
/// semantics and hit/miss accounting.
///
/// Two compute disciplines cover every caller:
/// * [`SimCache::get_or_simulate`] computes misses *under the write lock*
///   — exactly-once for monolithic simulations, which finish in
///   microseconds.
/// * Chunked prefills step their simulation across parked chunks, far
///   outside any lock, so they claim the key first
///   ([`SimCache::begin_chunked`]): one owner simulates, racers ride its
///   published result ([`SimCache::wait_or_simulate`]) instead of
///   duplicating the chunk-by-chunk compute — closing the cold-key race
///   the chunked path previously documented as accepted.
#[derive(Debug, Default)]
pub struct SimCache {
    map: RwLock<HashMap<PassKey, CachedPass>>,
    /// Keys whose chunked simulation is being computed outside the cache
    /// lock right now (owner claims). Guarded by its own mutex; never
    /// locked while holding `map` (the reverse nesting is allowed).
    in_flight: Mutex<HashSet<PassKey>>,
    in_flight_cv: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SimCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Return the cached pass for `key`, simulating it with `simulate`
    /// exactly once across all threads if absent.
    pub fn get_or_simulate(
        &self,
        key: PassKey,
        simulate: impl FnOnce() -> CachedPass,
    ) -> CachedPass {
        if let Some(pass) = self.map.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *pass;
        }
        let mut map = self.map.write().unwrap();
        // Re-check: another worker may have filled the key while we waited
        // for the write lock.
        if let Some(pass) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *pass;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let pass = simulate();
        map.insert(key, pass);
        pass
    }

    /// Non-counting lookup (the chunked path now claims keys through
    /// [`SimCache::begin_chunked`], which folds this check in; `peek`
    /// remains for observability and tests).
    pub fn peek(&self, key: PassKey) -> Option<CachedPass> {
        self.map.read().unwrap().get(&key).copied()
    }

    /// Claim `key` for an out-of-lock chunked simulation. Exactly one
    /// caller per cold key becomes the [`ChunkClaim::Owner`]; later racers
    /// see [`ChunkClaim::InFlight`] and skip simulating entirely.
    pub fn begin_chunked(&self, key: PassKey) -> ChunkClaim {
        let mut inf = self.in_flight.lock().unwrap();
        // Check the map under the guard lock so a publish between an
        // unlocked peek and the claim can't be missed.
        if let Some(pass) = self.map.read().unwrap().get(&key) {
            return ChunkClaim::Cached(*pass);
        }
        if !inf.insert(key) {
            return ChunkClaim::InFlight;
        }
        ChunkClaim::Owner
    }

    /// Publish the owner's finished chunked simulation and release the
    /// claim, waking any waiters. Returns the value now cached for the key
    /// (the owner's, unless a fallback racer beat it — then the cached one
    /// wins, keeping every consumer consistent).
    pub fn publish_chunked(&self, key: PassKey, pass: CachedPass) -> CachedPass {
        let out = self.get_or_simulate(key, || pass);
        let mut inf = self.in_flight.lock().unwrap();
        inf.remove(&key);
        self.in_flight_cv.notify_all();
        out
    }

    /// The owner shed before finishing: release the claim so waiters stop
    /// waiting (they fall back to computing the value themselves, still
    /// exactly once, under the cache lock).
    pub fn abandon_chunked(&self, key: PassKey) {
        let mut inf = self.in_flight.lock().unwrap();
        inf.remove(&key);
        self.in_flight_cv.notify_all();
    }

    /// Resolve `key`, riding an in-flight chunked owner's result when one
    /// exists: wait (bounded by [`CHUNK_WAIT_MAX`]) for its publish instead
    /// of duplicating the simulation; with no owner this is exactly
    /// [`SimCache::get_or_simulate`]. The bounded wait guarantees liveness
    /// even if an owner stalls or never publishes.
    pub fn wait_or_simulate(
        &self,
        key: PassKey,
        simulate: impl FnOnce() -> CachedPass,
    ) -> CachedPass {
        // Fast path: already cached.
        if let Some(pass) = self.map.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *pass;
        }
        let deadline = Instant::now() + CHUNK_WAIT_MAX;
        let mut inf = self.in_flight.lock().unwrap();
        loop {
            if let Some(pass) = self.map.read().unwrap().get(&key) {
                drop(inf);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return *pass;
            }
            let now = Instant::now();
            if !inf.contains(&key) || now >= deadline {
                drop(inf);
                return self.get_or_simulate(key, simulate);
            }
            let wait = deadline.saturating_duration_since(now).min(Duration::from_millis(10));
            let (guard, _timeout) = self.in_flight_cv.wait_timeout(inf, wait).unwrap();
            inf = guard;
        }
    }

    /// Keys currently claimed by chunked owners (observability/tests).
    pub fn in_flight_chunked(&self) -> usize {
        self.in_flight.lock().unwrap().len()
    }

    /// Drop every cached pass, returning how many were evicted. Hit/miss
    /// counters and in-flight chunked claims are untouched — a claim's
    /// owner is mid-simulation and will publish into the fresh map. Used
    /// when the pricing config a cache's entries were simulated under
    /// changes (a runtime DVFS re-point): `PassKey` carries no operating
    /// point, so every entry is stale the moment the chip moves.
    pub fn clear(&self) -> usize {
        let mut map = self.map.write().unwrap();
        let n = map.len();
        map.clear();
        n
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.read().unwrap().len(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pass(v: f64) -> CachedPass {
        CachedPass { chip_us: v, chip_uj: v, ema_bytes: v as u64, ema_kv_bytes: 0, utilization: v }
    }

    #[test]
    fn computes_once_per_key() {
        let cache = SimCache::new();
        let mut computed = 0;
        for _ in 0..5 {
            cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 8), || {
                computed += 1;
                pass(1.0)
            });
        }
        assert_eq!(computed, 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (4, 1, 1));
        assert!((s.hit_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn clear_evicts_entries_but_keeps_counters() {
        let cache = SimCache::new();
        cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 8), || pass(1.0));
        cache.get_or_simulate(PassKey::decode(4, 16, KvQuant::Fp16), || pass(2.0));
        assert_eq!(cache.clear(), 2);
        assert!(cache.is_empty());
        // Cleared entries re-simulate (the point: stale pricing is gone)...
        let mut recomputed = false;
        cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 8), || {
            recomputed = true;
            pass(9.0)
        });
        assert!(recomputed);
        // ...and the lifetime hit/miss history survives the wipe.
        assert_eq!(cache.stats().misses, 3);
    }

    #[test]
    fn distinct_keys_are_distinct_entries() {
        let cache = SimCache::new();
        cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 8), || pass(1.0));
        cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 8), || pass(2.0));
        cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 16), || pass(3.0));
        assert_eq!(cache.len(), 3);
        let got = cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 8), || unreachable!());
        assert_eq!(got.chip_us, 2.0);
    }

    #[test]
    fn decode_steps_key_by_group_past_len_and_quant() {
        let q = KvQuant::Fp16;
        let cache = SimCache::new();
        cache.get_or_simulate(PassKey::decode(4, 16, q), || pass(1.0));
        cache.get_or_simulate(PassKey::decode(4, 17, q), || pass(2.0)); // deeper KV
        cache.get_or_simulate(PassKey::decode(2, 16, q), || pass(3.0)); // smaller group
        // A different arena precision is a different pass (its dequant
        // charge and GB budget differ) — never a shared entry.
        cache.get_or_simulate(PassKey::decode(4, 16, KvQuant::Int4), || pass(4.0));
        assert_eq!(cache.len(), 4);
        // Same (group, depth, quant) hits.
        let got = cache.get_or_simulate(PassKey::decode(4, 16, q), || unreachable!());
        assert_eq!(got.chip_us, 1.0);
        // Prefill keys never collide with decode keys on the same numbers.
        assert_ne!(PassKey::prefill(BatchClass::B4, 1), PassKey::decode(4, 16, q));
    }

    #[test]
    fn prefill_key_is_shared_with_decode_prefixes() {
        // A generate request's prefill pass and a plain request of the same
        // class/slot must map to one entry — that reuse is the point of
        // keying by past_len instead of a separate decode cache.
        let cache = SimCache::new();
        cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 16), || pass(5.0));
        let reused = cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 16), || unreachable!());
        assert_eq!(reused.chip_us, 5.0);
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn chunked_claim_is_exclusive_and_waiters_ride_the_publish() {
        let cache = Arc::new(SimCache::new());
        let key = PassKey::prefill(BatchClass::B2, 16);
        // First claimer owns; racers see InFlight and must not simulate.
        assert!(matches!(cache.begin_chunked(key), ChunkClaim::Owner));
        assert!(matches!(cache.begin_chunked(key), ChunkClaim::InFlight));
        assert_eq!(cache.in_flight_chunked(), 1);
        // A waiter rides the owner's publish — its own closure never runs.
        let waiter = {
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || {
                cache.wait_or_simulate(key, || unreachable!("waiter must ride the publish"))
            })
        };
        std::thread::sleep(Duration::from_millis(5));
        let out = cache.publish_chunked(key, pass(9.0));
        assert_eq!(out.chip_us, 9.0);
        assert_eq!(waiter.join().unwrap().chip_us, 9.0);
        assert_eq!(cache.in_flight_chunked(), 0);
        // The key is now plainly cached; exactly one miss was recorded.
        assert!(matches!(cache.begin_chunked(key), ChunkClaim::Cached(_)));
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn abandoned_claim_falls_back_to_compute_under_lock() {
        let cache = SimCache::new();
        let key = PassKey::prefill(BatchClass::B1, 8);
        assert!(matches!(cache.begin_chunked(key), ChunkClaim::Owner));
        // The owner sheds mid-prefill: the claim is released and the next
        // consumer computes the value itself — still exactly once.
        cache.abandon_chunked(key);
        assert_eq!(cache.in_flight_chunked(), 0);
        let got = cache.wait_or_simulate(key, || pass(3.0));
        assert_eq!(got.chip_us, 3.0);
        assert_eq!(cache.stats().misses, 1);
        assert!(matches!(cache.begin_chunked(key), ChunkClaim::Cached(_)));
    }

    #[test]
    fn wait_or_simulate_without_owner_matches_get_or_simulate() {
        let cache = SimCache::new();
        let key = PassKey::prefill(BatchClass::B4, 32);
        let got = cache.wait_or_simulate(key, || pass(2.0));
        assert_eq!(got.chip_us, 2.0);
        let again = cache.wait_or_simulate(key, || unreachable!());
        assert_eq!(again.chip_us, 2.0);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn concurrent_cold_key_simulates_exactly_once() {
        let cache = Arc::new(SimCache::new());
        let calls = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();
        for _ in 0..8 {
            let cache = Arc::clone(&cache);
            let calls = Arc::clone(&calls);
            threads.push(std::thread::spawn(move || {
                cache.get_or_simulate(PassKey::prefill(BatchClass::B1, 32), || {
                    calls.fetch_add(1, Ordering::SeqCst);
                    pass(7.0)
                })
            }));
        }
        for t in threads {
            assert_eq!(t.join().unwrap().chip_us, 7.0);
        }
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        assert_eq!(cache.stats().misses, 1);
    }
}
