//! Process-wide simulation cache shared by all pool workers.
//!
//! Chip passes are deterministic per [`PassKey`], so the cycle-level
//! simulation only ever needs to run once per key no matter how many engine
//! workers serve traffic. The cache computes misses *under the write lock*,
//! which guarantees exactly-once simulation even when several workers race
//! on a cold key — the simulation is microseconds-cheap next to a duplicated
//! run, and cold keys are rare.
//!
//! Keys carry `past_len` so decode steps cache alongside prefill passes:
//! a generate request's prefill (`past_len` = 0) shares the exact key a
//! plain request of the same class/slot uses — prefill results are reused as
//! decode prefixes — while each `(group size, KV depth)` decode step gets
//! its own entry.

use crate::kv::KvQuant;
use crate::sim::BatchClass;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Identity of one deterministic chip pass.
///
/// * Prefill: `batch` = class batch, `seq` = the class's per-input slot,
///   `past_len` = 0, `kv_bits` = 0.
/// * Decode step: `batch` = decode-group size (1..=4), `seq` = 1,
///   `past_len` = the KV depth the step attends over, `kv_bits` = the
///   arena's storage width — decode timing/EMA depend on the quant mode
///   (dequant charge + quantized GB budget), so engines with different
///   modes sharing one cache must not collide.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PassKey {
    pub batch: usize,
    pub seq: usize,
    pub past_len: usize,
    pub kv_bits: u64,
}

impl PassKey {
    /// Key for a whole-sequence pass of `class` at per-input slot `seq`.
    pub fn prefill(class: BatchClass, seq: usize) -> PassKey {
        PassKey { batch: class.batch(), seq, past_len: 0, kv_bits: 0 }
    }

    /// Key for one decode step of a `batch`-stream group at KV depth
    /// `past_len` (always ≥ 1: the stream prefilled at least one token)
    /// over a `quant`-precision KV arena.
    pub fn decode(batch: usize, past_len: usize, quant: KvQuant) -> PassKey {
        PassKey { batch, seq: 1, past_len, kv_bits: quant.bits() }
    }
}

/// One simulated chip pass (the per-batch quantities the engine attaches to
/// every response it serves from that pass).
#[derive(Debug, Clone, Copy)]
pub struct CachedPass {
    pub chip_us: f64,
    pub chip_uj: f64,
    pub ema_bytes: u64,
    pub utilization: f64,
}

/// Hit/miss counters snapshot.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe `PassKey → CachedPass` map with exactly-once compute
/// semantics and hit/miss accounting.
#[derive(Debug, Default)]
pub struct SimCache {
    map: RwLock<HashMap<PassKey, CachedPass>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SimCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Return the cached pass for `key`, simulating it with `simulate`
    /// exactly once across all threads if absent.
    pub fn get_or_simulate(
        &self,
        key: PassKey,
        simulate: impl FnOnce() -> CachedPass,
    ) -> CachedPass {
        if let Some(pass) = self.map.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *pass;
        }
        let mut map = self.map.write().unwrap();
        // Re-check: another worker may have filled the key while we waited
        // for the write lock.
        if let Some(pass) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *pass;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let pass = simulate();
        map.insert(key, pass);
        pass
    }

    /// Non-counting lookup. The chunked-prefill path checks for an already
    /// simulated pass up front — a hit means phase-by-phase re-simulation
    /// would be pure duplicated work, so the chunk loop is skipped and the
    /// completion path's [`SimCache::get_or_simulate`] records the hit when
    /// the value is actually consumed.
    pub fn peek(&self, key: PassKey) -> Option<CachedPass> {
        self.map.read().unwrap().get(&key).copied()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.read().unwrap().len(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pass(v: f64) -> CachedPass {
        CachedPass { chip_us: v, chip_uj: v, ema_bytes: v as u64, utilization: v }
    }

    #[test]
    fn computes_once_per_key() {
        let cache = SimCache::new();
        let mut computed = 0;
        for _ in 0..5 {
            cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 8), || {
                computed += 1;
                pass(1.0)
            });
        }
        assert_eq!(computed, 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (4, 1, 1));
        assert!((s.hit_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn distinct_keys_are_distinct_entries() {
        let cache = SimCache::new();
        cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 8), || pass(1.0));
        cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 8), || pass(2.0));
        cache.get_or_simulate(PassKey::prefill(BatchClass::B4, 16), || pass(3.0));
        assert_eq!(cache.len(), 3);
        let got = cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 8), || unreachable!());
        assert_eq!(got.chip_us, 2.0);
    }

    #[test]
    fn decode_steps_key_by_group_past_len_and_quant() {
        let q = KvQuant::Fp16;
        let cache = SimCache::new();
        cache.get_or_simulate(PassKey::decode(4, 16, q), || pass(1.0));
        cache.get_or_simulate(PassKey::decode(4, 17, q), || pass(2.0)); // deeper KV
        cache.get_or_simulate(PassKey::decode(2, 16, q), || pass(3.0)); // smaller group
        // A different arena precision is a different pass (its dequant
        // charge and GB budget differ) — never a shared entry.
        cache.get_or_simulate(PassKey::decode(4, 16, KvQuant::Int4), || pass(4.0));
        assert_eq!(cache.len(), 4);
        // Same (group, depth, quant) hits.
        let got = cache.get_or_simulate(PassKey::decode(4, 16, q), || unreachable!());
        assert_eq!(got.chip_us, 1.0);
        // Prefill keys never collide with decode keys on the same numbers.
        assert_ne!(PassKey::prefill(BatchClass::B4, 1), PassKey::decode(4, 16, q));
    }

    #[test]
    fn prefill_key_is_shared_with_decode_prefixes() {
        // A generate request's prefill pass and a plain request of the same
        // class/slot must map to one entry — that reuse is the point of
        // keying by past_len instead of a separate decode cache.
        let cache = SimCache::new();
        cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 16), || pass(5.0));
        let reused = cache.get_or_simulate(PassKey::prefill(BatchClass::B2, 16), || unreachable!());
        assert_eq!(reused.chip_us, 5.0);
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn concurrent_cold_key_simulates_exactly_once() {
        let cache = Arc::new(SimCache::new());
        let calls = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();
        for _ in 0..8 {
            let cache = Arc::clone(&cache);
            let calls = Arc::clone(&calls);
            threads.push(std::thread::spawn(move || {
                cache.get_or_simulate(PassKey::prefill(BatchClass::B1, 32), || {
                    calls.fetch_add(1, Ordering::SeqCst);
                    pass(7.0)
                })
            }));
        }
        for t in threads {
            assert_eq!(t.join().unwrap().chip_us, 7.0);
        }
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        assert_eq!(cache.stats().misses, 1);
    }
}
