//! Request/response types of the serving plane.

use crate::sim::BatchClass;
use std::time::Instant;

pub type RequestId = u64;

/// One inference request: a token-embedding matrix of `len` rows.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Input length in tokens (≤ hardware max).
    pub len: usize,
    /// Row-major `(len, d_model)` activations.
    pub payload: Vec<f32>,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, len: usize, payload: Vec<f32>) -> Self {
        Request { id, len, payload, arrival: Instant::now() }
    }
    pub fn d_model(&self) -> usize {
        if self.len == 0 {
            0
        } else {
            self.payload.len() / self.len
        }
    }
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    /// `(len, d_model)` output rows (padding stripped).
    pub output: Vec<f32>,
    /// Wall-clock execute time (host side): plane assembly + executable run
    /// + output split, measured from the instant a worker picked the batch.
    pub host_latency_us: f64,
    /// Pure waiting time: arrival → execution start (batcher residency plus
    /// work-queue residency). Non-negative by construction; end-to-end
    /// latency is `queue_us + host_latency_us`.
    pub queue_us: f64,
    /// Modeled chip latency for the batch this request rode in.
    pub chip_us: f64,
    /// Modeled chip energy share for this request, µJ.
    pub chip_uj: f64,
    /// Modeled chip EMA share for this request, bytes.
    pub ema_bytes: u64,
    /// Batch class the request was served in.
    pub class: BatchClass,
    /// Modeled MAC-plane utilization of the pass.
    pub utilization: f64,
    /// Pool worker that executed the batch (0 in single-engine setups).
    pub worker: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d_model_derivation() {
        let r = Request::new(1, 4, vec![0.0; 4 * 16]);
        assert_eq!(r.d_model(), 16);
        let z = Request::new(2, 0, vec![]);
        assert_eq!(z.d_model(), 0);
    }
}
