//! Request/response types of the serving plane.

use crate::sim::BatchClass;
use std::time::Instant;

pub type RequestId = u64;

/// One inference request: a token-embedding matrix of `len` rows, plus an
/// optional autoregressive decode budget.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Input length in tokens (≤ hardware max).
    pub len: usize,
    /// Row-major `(len, d_model)` activations.
    pub payload: Vec<f32>,
    /// Tokens to generate autoregressively after the prefill pass
    /// (0 = encode-only request). The engine clamps this to the GB's KV
    /// residency cap for the request's class — see
    /// [`crate::sim::GbBudget::max_decode_len`].
    pub generate: usize,
    /// Hashed prompt-prefix identity ([`crate::kv::prefix_id`] of the
    /// trace's `prefix_group` tag): requests sharing it attach to one
    /// refcounted KV prefix in the arena instead of each paying a copy.
    pub prefix_group: Option<u64>,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, len: usize, payload: Vec<f32>) -> Self {
        Request { id, len, payload, generate: 0, prefix_group: None, arrival: Instant::now() }
    }

    /// Ask for `n` decode tokens after prefill (builder-style).
    pub fn with_generate(mut self, n: usize) -> Self {
        self.generate = n;
        self
    }

    /// Tag this request as sharing its prompt prefix with every other
    /// request carrying the same identity (builder-style; hash a trace tag
    /// with [`crate::kv::prefix_id`]).
    pub fn with_prefix_group(mut self, group: u64) -> Self {
        self.prefix_group = Some(group);
        self
    }

    pub fn d_model(&self) -> usize {
        if self.len == 0 {
            0
        } else {
            self.payload.len() / self.len
        }
    }
}

/// One decoded token, streamed back while its request is still in flight.
#[derive(Debug, Clone)]
pub struct TokenEvent {
    pub id: RequestId,
    /// 0-based index of this generated token within its request.
    pub index: usize,
    /// KV depth the producing step attended over (prefill len + index).
    pub past_len: usize,
    /// Modeled chip µs/token of the producing step: the step's wall time
    /// divided across the group's tokens (one per stream) — the paper's
    /// µs/token convention, same amortization as the energy/EMA shares.
    /// A solo stream pays the full step; a 4-up group a quarter each.
    pub us_per_token: f64,
    /// This stream's share of the step's modeled energy, µJ.
    pub chip_uj: f64,
    /// This stream's share of the step's EMA bytes (weight streaming split
    /// across the group — the amortization decode batching buys).
    pub ema_bytes: u64,
    /// KV depths of every stream that shared this step — continuous
    /// batching is observable here: mixed values = mixed-progress streams.
    pub group_past_lens: Vec<usize>,
    /// Pool worker that executed the step.
    pub worker: usize,
    pub emitted: Instant,
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    /// `(len, d_model)` output rows (padding stripped). For generate
    /// requests this is the prefill output; per-token results stream as
    /// [`TokenEvent`]s while decoding.
    pub output: Vec<f32>,
    /// Wall-clock execute time (host side): plane assembly + executable run
    /// + output split, measured from the instant a worker picked the batch.
    /// For generate requests this additionally covers the whole decode phase
    /// (between-steps queue residency + per-step host time), so
    /// `queue_us + host_latency_us` stays the true end-to-end latency.
    pub host_latency_us: f64,
    /// Pure waiting time: arrival → execution start (batcher residency plus
    /// work-queue residency). Non-negative by construction; end-to-end
    /// latency is `queue_us + host_latency_us`.
    pub queue_us: f64,
    /// Modeled chip wall latency this request *experienced*: the full
    /// prefill pass it rode in, plus — for generate requests — the full
    /// wall time of every decode step it joined (a rider occupies the whole
    /// step regardless of group size; energy/EMA below are shares instead).
    pub chip_us: f64,
    /// Modeled chip energy share for this request, µJ.
    pub chip_uj: f64,
    /// Modeled chip EMA share for this request, bytes.
    pub ema_bytes: u64,
    /// Batch class the request was prefilled in.
    pub class: BatchClass,
    /// Modeled MAC-plane utilization of the prefill pass.
    pub utilization: f64,
    /// Input (prefill) length in tokens.
    pub prefill_len: usize,
    /// Decode tokens actually generated (≤ requested: the GB residency cap
    /// clamps, see [`Request::generate`]).
    pub tokens_generated: usize,
    /// Pool worker that completed the request: the prefill worker for
    /// encode-only requests, the final decode step's worker for generate
    /// requests (0 in single-engine setups).
    pub worker: usize,
}

impl Response {
    /// End-to-end latency, µs: waiting plus host execution. This is the
    /// quantity a request's flight-recorder lifecycle spans tile — the
    /// `integration_obs` test pins span-sum == `e2e_us()`.
    pub fn e2e_us(&self) -> f64 {
        self.queue_us + self.host_latency_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d_model_derivation() {
        let r = Request::new(1, 4, vec![0.0; 4 * 16]);
        assert_eq!(r.d_model(), 16);
        let z = Request::new(2, 0, vec![]);
        assert_eq!(z.d_model(), 0);
    }

    #[test]
    fn generate_defaults_zero_and_builds() {
        let r = Request::new(1, 4, vec![0.0; 4 * 16]);
        assert_eq!(r.generate, 0);
        let g = r.with_generate(12);
        assert_eq!(g.generate, 12);
        assert_eq!(g.len, 4);
    }
}
