//! Paged KV-cache subsystem: pool-wide residency, quantized storage, and
//! the charges decode steps owe the EMA ledger.
//!
//! T-REX's decode path keeps the autoregressive KV cache resident in the
//! global buffer so each step reads its prefix with zero external-memory
//! traffic. The seed model budgeted that residency *per decode step* — one
//! group's KV, implicitly full-precision, with streams parked between steps
//! occupying the GB for free. This module replaces that idealization:
//!
//! * [`quant::KvQuant`] — the arena's storage precision (`fp16`/`int8`/
//!   `int4`): reduced modes halve/quarter every residency figure but owe a
//!   per-step dequant pass, charged by the `Stepper` as `KvDequant` EMA.
//! * [`arena::KvArena`] — fixed-size-page occupancy accounting over the GB
//!   bytes left after the weight and activation residents.
//! * [`manager::KvManager`] — the pool-wide manager: admission bounds
//!   concurrent generate streams by projected arena bytes, parked streams
//!   keep their pages (never free), LRU eviction makes room, and an evicted
//!   stream rejoining a step is charged swap-in EMA for its whole resident
//!   **private** KV before the step runs.
//! * [`radix::RadixIndex`] — the prefix-sharing layer: streams carrying a
//!   `prefix_group` identity attach to a refcounted chain of page spans, so
//!   N streams of one prompt keep ONE physical prefix copy (arena bytes
//!   grow ~O(unique tokens), not O(streams)), fork copy-on-write when
//!   decode outgrows an unaligned prefix, and free shared pages only when
//!   the last reference drops.
//!
//! The serving integration: `Engine` registers streams at prefill, calls
//! [`manager::KvManager::prepare_group`] before every decode step, and
//! releases on completion; the pool's admission path consults
//! [`manager::KvManager::try_admit`]; `coordinator::batcher::
//! form_decode_group` optionally groups streams by `past_len` bucket so the
//! pad waste the manager's depth-padded accounting charges stays bounded.

pub mod arena;
pub mod manager;
pub mod quant;
pub mod radix;

/// Most streams one decode step batches — the chip's four-up plane slicing.
/// `coordinator::engine::MAX_DECODE_GROUP` re-exports this; the arena sizes
/// its fixed residents (activation planes, dequant scratch) at this width.
pub const MAX_GROUP_STREAMS: usize = 4;

pub use arena::KvArena;
pub use manager::{KvArenaConfig, KvManager, KvMigration, KvResidual, KvStats, StepCharge};
pub use quant::KvQuant;
pub use radix::{prefix_id, PrefixId, RadixIndex};
