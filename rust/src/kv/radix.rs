//! Radix prefix index over resident KV: refcounted page spans shared by
//! every stream of one prefix group.
//!
//! Streams that share a prompt prefix (the trace format's `prefix_group`
//! tag) write byte-identical self-attention KV for the shared tokens. The
//! index keys that identity the way `PassKey{past_len}` keys the
//! `SimCache`: a [`PrefixId`] (FNV-1a of the tag) names the group, and the
//! group's resident prefix is a **chain of page spans** ordered from token
//! 0 outward — the radix structure degenerates to a chain because every
//! member shares from the root, but spans still split at page boundaries
//! when members attach at different prefill depths, so a group holds one
//! physical copy of its longest resident prefix and each member refcounts
//! exactly the pages its own prefill covers.
//!
//! Invariant the chain maintains: refcounts are **monotone non-increasing
//! from the root outward** (every attachment spans `[0, bytes)`), so a
//! span can only hit zero references at the tail — frees are tail-first
//! and a zero-ref interior span is structurally impossible. Decrements
//! saturate and `debug_assert` instead of underflowing: a shed racing a
//! prefix-mate's release must never double-free a shared page.
//!
//! The index counts pages; the [`super::arena::KvArena`] owns the
//! occupancy ledger (the manager moves `Attach::new_pages` /
//! [`RadixIndex::detach`] results through `alloc_shared` / `free_shared`).

use std::collections::HashMap;

/// Hashed prefix-group identity (FNV-1a of the trace tag).
pub type PrefixId = u64;

/// FNV-1a hash of a prefix-group tag — the stable, dependency-free way a
/// trace tag (or any prompt identity string) becomes a [`PrefixId`].
pub fn prefix_id(tag: &str) -> PrefixId {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in tag.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One refcounted page span of a group's prefix chain: pages
/// `[start_page, end_page)` counted from the prefix root, pinned by
/// `refs` attached streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Span {
    start_page: usize,
    end_page: usize,
    refs: usize,
}

impl Span {
    fn pages(&self) -> usize {
        self.end_page - self.start_page
    }
}

/// One group's resident prefix: spans ordered root-outward, contiguous
/// from page 0 to the chain's coverage.
#[derive(Debug, Default)]
struct Chain {
    spans: Vec<Span>,
    /// Logical-clock stamp of the detach that last left this chain with
    /// zero-ref tail pages (see [`RadixIndex::detach_retain`]) — the LRU
    /// key cold-chain reclamation orders by.
    cold_since: u64,
}

impl Chain {
    /// Pages the chain currently keeps resident.
    fn covered_pages(&self) -> usize {
        self.spans.last().map_or(0, |s| s.end_page)
    }

    /// Trailing pages no live stream references (retained by
    /// [`RadixIndex::detach_retain`], reclaimable LRU-first).
    fn cold_tail_pages(&self) -> usize {
        self.spans.iter().rev().take_while(|s| s.refs == 0).map(Span::pages).sum()
    }
}

/// What one attachment found and claimed.
#[derive(Debug, Default, Clone, Copy)]
pub struct Attach {
    /// Pages newly allocated for this stream (the chain extension past
    /// what was already resident) — the arena must have room for these.
    pub new_pages: usize,
    /// Pages that were already resident and are now additionally
    /// referenced — the prefix-hit bytes this stream never re-writes.
    pub hit_pages: usize,
}

/// Prefix-sharing index over the KV arena (see module docs).
#[derive(Debug)]
pub struct RadixIndex {
    page_bytes: u64,
    groups: HashMap<PrefixId, Chain>,
}

impl RadixIndex {
    pub fn new(page_bytes: u64) -> RadixIndex {
        RadixIndex { page_bytes: page_bytes.max(1), groups: HashMap::new() }
    }

    /// Pages `[0, bytes)` of a prefix touches (no minimum — a zero-byte
    /// prefix shares nothing, unlike a live stream's one-page floor).
    fn pages_spanned(&self, bytes: u64) -> usize {
        bytes.div_ceil(self.page_bytes) as usize
    }

    /// Pages an `attach(group, bytes)` would need to newly allocate —
    /// the manager makes arena room for exactly this before attaching.
    pub fn pages_needed(&self, group: PrefixId, bytes: u64) -> usize {
        let want = self.pages_spanned(bytes);
        let covered = self.groups.get(&group).map_or(0, |c| c.covered_pages());
        want.saturating_sub(covered)
    }

    /// Resident prefix bytes of a group (page-granular) — what a warm
    /// admission projection may discount.
    pub fn coverage_bytes(&self, group: PrefixId) -> u64 {
        self.groups.get(&group).map_or(0, |c| c.covered_pages() as u64 * self.page_bytes)
    }

    /// Attach a stream to its group's prefix for `[0, bytes)`: reference
    /// every covered span (splitting the span straddling the boundary at
    /// the page line), extend the chain for pages past coverage. Returns
    /// what was claimed; the caller owns moving `new_pages` through the
    /// arena's shared ledger.
    pub fn attach(&mut self, group: PrefixId, bytes: u64) -> Attach {
        let want = self.pages_spanned(bytes);
        if want == 0 {
            return Attach::default();
        }
        let chain = self.groups.entry(group).or_default();
        let covered = chain.covered_pages();
        let hit = want.min(covered);
        // Reference (and split if straddled) the covered part.
        let mut i = 0;
        while i < chain.spans.len() {
            let s = chain.spans[i];
            if s.end_page <= want {
                chain.spans[i].refs += 1;
            } else if s.start_page < want {
                // Straddles the boundary: split at the page line so the
                // tail keeps its original refs and only `[start, want)`
                // gains this stream.
                chain.spans[i] = Span { start_page: s.start_page, end_page: want, refs: s.refs + 1 };
                chain.spans.insert(i + 1, Span { start_page: want, end_page: s.end_page, refs: s.refs });
                break;
            } else {
                break;
            }
            i += 1;
        }
        // Extend past coverage: the new tail belongs to this stream alone.
        let new_pages = want.saturating_sub(covered);
        if new_pages > 0 {
            chain.spans.push(Span { start_page: covered, end_page: want, refs: 1 });
        }
        Attach { new_pages, hit_pages: hit }
    }

    /// Detach a stream from `[0, bytes)` of its group's prefix: decrement
    /// every covered span (saturating — a double-detach racing a
    /// prefix-mate's release must not underflow a live span's count) and
    /// free zero-ref tail spans. Returns the pages freed; the caller
    /// gives them back to the arena's shared ledger.
    pub fn detach(&mut self, group: PrefixId, bytes: u64) -> usize {
        let want = self.pages_spanned(bytes);
        let Some(chain) = self.groups.get_mut(&group) else {
            debug_assert!(want == 0, "detach from an unknown prefix group");
            return 0;
        };
        for s in chain.spans.iter_mut() {
            if s.end_page <= want {
                debug_assert!(s.refs > 0, "detach underflow: shared span already at zero refs");
                s.refs = s.refs.saturating_sub(1);
            }
        }
        // Root-monotone refcounts mean zero-ref spans pool at the tail.
        let mut freed = 0;
        while chain.spans.last().is_some_and(|s| s.refs == 0) {
            freed += chain.spans.pop().expect("checked last").pages();
        }
        debug_assert!(
            chain.spans.iter().all(|s| s.refs > 0),
            "zero-ref interior span survived a detach: {:?}",
            chain.spans
        );
        if chain.spans.is_empty() {
            self.groups.remove(&group);
        }
        freed
    }

    /// Detach a stream from `[0, bytes)` of its group's prefix like
    /// [`RadixIndex::detach`], but **retain** zero-ref tail spans as a
    /// *cold chain*: the pages stay resident (still counted in
    /// [`RadixIndex::shared_pages`]) so a future prefix-mate re-attaches
    /// warm, and [`RadixIndex::reclaim_cold`] returns them to the arena
    /// LRU-first when pressure demands. `stamp` is the caller's logical
    /// clock (the LRU key). Returns nothing freed — cold pages are freed
    /// only by reclamation.
    pub fn detach_retain(&mut self, group: PrefixId, bytes: u64, stamp: u64) {
        let want = self.pages_spanned(bytes);
        let Some(chain) = self.groups.get_mut(&group) else {
            debug_assert!(want == 0, "detach from an unknown prefix group");
            return;
        };
        for s in chain.spans.iter_mut() {
            if s.end_page <= want {
                debug_assert!(s.refs > 0, "detach underflow: shared span already at zero refs");
                s.refs = s.refs.saturating_sub(1);
            }
        }
        if chain.spans.last().is_some_and(|s| s.refs == 0) {
            chain.cold_since = stamp;
        }
    }

    /// Free up to `max_pages` cold pages (zero-ref tail spans retained by
    /// [`RadixIndex::detach_retain`]), reclaiming whole chains coldest
    /// (LRU) first; fully-emptied chains leave the index. Returns the
    /// pages freed — the caller gives them back to the arena's shared
    /// ledger.
    pub fn reclaim_cold(&mut self, max_pages: usize) -> usize {
        if max_pages == 0 {
            return 0;
        }
        let mut cold: Vec<(u64, PrefixId)> = self
            .groups
            .iter()
            .filter(|(_, c)| c.cold_tail_pages() > 0)
            .map(|(g, c)| (c.cold_since, *g))
            .collect();
        cold.sort_unstable();
        let mut freed = 0;
        for (_, g) in cold {
            if freed >= max_pages {
                break;
            }
            let chain = self.groups.get_mut(&g).expect("cold chain present");
            while freed < max_pages && chain.spans.last().is_some_and(|s| s.refs == 0) {
                freed += chain.spans.pop().expect("checked last").pages();
            }
            if chain.spans.is_empty() {
                self.groups.remove(&g);
            }
        }
        freed
    }

    /// Pages currently retained by cold (zero-ref tail) chain segments —
    /// resident-but-reclaimable shared capacity.
    pub fn cold_pages(&self) -> usize {
        self.groups.values().map(Chain::cold_tail_pages).sum()
    }

    /// Pages currently pinned by any prefix chain (the arena's shared
    /// gauge must agree with this).
    pub fn shared_pages(&self) -> usize {
        self.groups.values().map(|c| c.spans.iter().map(Span::pages).sum::<usize>()).sum()
    }

    /// Total stream references across every span — zero after a full
    /// drain, or somebody leaked an attachment.
    pub fn total_refs(&self) -> usize {
        self.groups.values().flat_map(|c| c.spans.iter()).map(|s| s.refs).sum()
    }

    /// Live prefix groups holding resident pages.
    pub fn groups(&self) -> usize {
        self.groups.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_id_is_stable_and_distinguishes_tags() {
        assert_eq!(prefix_id("sys-a"), prefix_id("sys-a"));
        assert_ne!(prefix_id("sys-a"), prefix_id("sys-b"));
        assert_ne!(prefix_id(""), prefix_id("g0"));
    }

    #[test]
    fn attach_shares_pages_and_extends_tail() {
        let mut idx = RadixIndex::new(2048);
        let g = prefix_id("g0");
        // First stream: 3 pages, all new.
        let a = idx.attach(g, 3 * 2048);
        assert_eq!((a.new_pages, a.hit_pages), (3, 0));
        assert_eq!(idx.shared_pages(), 3);
        // Prefix-mate at the same depth: pure hit.
        let b = idx.attach(g, 3 * 2048);
        assert_eq!((b.new_pages, b.hit_pages), (0, 3));
        assert_eq!(idx.shared_pages(), 3, "one physical copy");
        // Deeper mate extends the chain by the uncovered tail only.
        let c = idx.attach(g, 5 * 2048);
        assert_eq!((c.new_pages, c.hit_pages), (2, 3));
        assert_eq!(idx.shared_pages(), 5);
        assert_eq!(idx.total_refs(), 4, "[0,3) holds 3 refs, the [3,5) tail 1");
        assert_eq!(idx.coverage_bytes(g), 5 * 2048);
    }

    #[test]
    fn shallow_attach_splits_at_the_page_line() {
        let mut idx = RadixIndex::new(2048);
        let g = prefix_id("g0");
        idx.attach(g, 4 * 2048);
        // A mate covering only 1.5 pages references the 2 pages its bytes
        // touch; the untouched tail keeps a single owner.
        let a = idx.attach(g, 3 * 1024);
        assert_eq!((a.new_pages, a.hit_pages), (0, 2));
        idx.detach(g, 4 * 2048);
        // First stream gone: only the shallow mate's 2 pages stay pinned.
        assert_eq!(idx.shared_pages(), 2);
        idx.detach(g, 3 * 1024);
        assert_eq!(idx.shared_pages(), 0);
        assert_eq!(idx.total_refs(), 0);
        assert_eq!(idx.groups(), 0, "drained group leaves no chain behind");
    }

    #[test]
    fn detach_frees_only_at_zero_and_saturates() {
        let mut idx = RadixIndex::new(2048);
        let g = prefix_id("shared");
        idx.attach(g, 2 * 2048);
        idx.attach(g, 2 * 2048);
        assert_eq!(idx.detach(g, 2 * 2048), 0, "mate still pinned");
        assert_eq!(idx.detach(g, 2 * 2048), 2, "last ref frees the pages");
        // Detaching from a drained group is a harmless no-op.
        assert_eq!(idx.detach(g, 0), 0);
        assert_eq!(idx.shared_pages(), 0);
    }

    #[test]
    fn zero_bytes_attach_nothing() {
        let mut idx = RadixIndex::new(2048);
        let a = idx.attach(prefix_id("g"), 0);
        assert_eq!((a.new_pages, a.hit_pages), (0, 0));
        assert_eq!(idx.groups(), 0);
    }

    #[test]
    fn detach_retain_keeps_cold_pages_until_reclaimed_lru_first() {
        let mut idx = RadixIndex::new(2048);
        let (a, b) = (prefix_id("a"), prefix_id("b"));
        idx.attach(a, 3 * 2048);
        idx.attach(b, 2 * 2048);
        // Both groups' last mates leave; the chains go cold but stay
        // resident — a returning mate would re-attach warm.
        idx.detach_retain(a, 3 * 2048, 10);
        idx.detach_retain(b, 2 * 2048, 20);
        assert_eq!(idx.shared_pages(), 5, "cold pages stay resident");
        assert_eq!(idx.cold_pages(), 5);
        assert_eq!(idx.total_refs(), 0);
        // A mate re-attaching to a cold chain is a pure warm hit.
        let warm = idx.attach(a, 3 * 2048);
        assert_eq!((warm.new_pages, warm.hit_pages), (0, 3));
        assert_eq!(idx.cold_pages(), 2, "only b stays cold");
        idx.detach_retain(a, 3 * 2048, 30);
        // Reclaim under pressure: b (stamp 20) goes before a (stamp 30).
        assert_eq!(idx.reclaim_cold(2), 2);
        assert_eq!(idx.groups(), 1, "b fully reclaimed, a still cold");
        assert_eq!(idx.reclaim_cold(usize::MAX), 3);
        assert_eq!((idx.shared_pages(), idx.cold_pages(), idx.groups()), (0, 0, 0));
    }

    #[test]
    fn reclaim_cold_spares_referenced_spans() {
        let mut idx = RadixIndex::new(2048);
        let g = prefix_id("g");
        idx.attach(g, 4 * 2048); // deep mate
        idx.attach(g, 2 * 2048); // shallow mate
        idx.detach_retain(g, 4 * 2048, 5); // deep mate leaves; [2,4) goes cold
        assert_eq!(idx.cold_pages(), 2);
        assert_eq!(idx.reclaim_cold(usize::MAX), 2);
        assert_eq!(idx.shared_pages(), 2, "the shallow mate's pages survive");
        assert_eq!(idx.total_refs(), 1);
        assert_eq!(idx.reclaim_cold(usize::MAX), 0, "nothing cold left");
    }

    #[test]
    fn groups_are_independent() {
        let mut idx = RadixIndex::new(2048);
        idx.attach(prefix_id("a"), 2 * 2048);
        idx.attach(prefix_id("b"), 3 * 2048);
        assert_eq!(idx.shared_pages(), 5);
        assert_eq!(idx.pages_needed(prefix_id("a"), 4 * 2048), 2);
        assert_eq!(idx.pages_needed(prefix_id("b"), 2 * 2048), 0);
        assert_eq!(idx.detach(prefix_id("a"), 2 * 2048), 2);
        assert_eq!(idx.shared_pages(), 3);
    }
}
