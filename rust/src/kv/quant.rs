//! KV-cache quantization modes.
//!
//! The arena stores K/V planes at a configurable precision: `Fp16` is the
//! honest full-precision baseline (decode accumulators are 16-bit), `Int8`
//! and `Int4` halve / quarter every residency figure — pages per stream,
//! swap-in bytes, the aggregate arena footprint — at the cost of a per-step
//! dequant pass the executor charges (see `SimOptions::
//! kv_dequant_bytes_per_layer` and the `KvDequant` EMA category).

use crate::error::{Error, Result};

/// Storage precision of the KV-cache arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvQuant {
    /// Full-precision 16-bit K/V (no dequant pass).
    #[default]
    Fp16,
    /// 8-bit quantized K/V: half the residency, dequant charged per step.
    Int8,
    /// 4-bit quantized K/V: quarter the residency, dequant charged per step.
    Int4,
}

impl KvQuant {
    pub const ALL: [KvQuant; 3] = [KvQuant::Fp16, KvQuant::Int8, KvQuant::Int4];

    /// Stored bits per K/V element.
    pub fn bits(self) -> u64 {
        match self {
            KvQuant::Fp16 => 16,
            KvQuant::Int8 => 8,
            KvQuant::Int4 => 4,
        }
    }

    /// Bytes for `elems` stored elements (element counts in this codebase
    /// are always even — K and V come in pairs — so Int4 never truncates).
    pub fn bytes(self, elems: u64) -> u64 {
        elems * self.bits() / 8
    }

    /// Whether decoding through this mode needs the per-step dequant pass
    /// (everything below full precision does).
    pub fn dequant(self) -> bool {
        !matches!(self, KvQuant::Fp16)
    }

    pub fn name(self) -> &'static str {
        match self {
            KvQuant::Fp16 => "fp16",
            KvQuant::Int8 => "int8",
            KvQuant::Int4 => "int4",
        }
    }

    /// Parse a CLI flag value (`fp16` / `int8` / `int4`).
    pub fn parse(s: &str) -> Result<KvQuant> {
        match s {
            "fp16" => Ok(KvQuant::Fp16),
            "int8" => Ok(KvQuant::Int8),
            "int4" => Ok(KvQuant::Int4),
            other => Err(Error::config(format!(
                "unknown kv quantization mode {other:?} (expected fp16|int8|int4)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_and_bytes_scale() {
        assert_eq!(KvQuant::Fp16.bytes(128), 256);
        assert_eq!(KvQuant::Int8.bytes(128), 128);
        assert_eq!(KvQuant::Int4.bytes(128), 64);
        assert!(!KvQuant::Fp16.dequant());
        assert!(KvQuant::Int8.dequant() && KvQuant::Int4.dequant());
    }

    #[test]
    fn parse_roundtrip() {
        for q in KvQuant::ALL {
            assert_eq!(KvQuant::parse(q.name()).unwrap(), q);
        }
        assert!(KvQuant::parse("bf16").is_err());
    }
}
