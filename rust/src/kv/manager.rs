//! Pool-wide paged KV-cache manager.
//!
//! One `KvManager` is shared (via `Arc`) by every engine worker of a pool
//! plus the admission path, and owns the global-buffer KV arena: fixed-size
//! pages allocated per decode stream (self-attention KV growing with
//! `past_len`, plus the fixed cross-attention encoder memory for enc-dec
//! models), stored at a configurable [`KvQuant`] precision.
//!
//! It replaces the per-group `GbBudget::for_decode` idealization with an
//! **aggregate** residency model:
//!
//! * **Admission** — [`KvManager::try_admit`] bounds concurrent generate
//!   streams by projected arena bytes (`admit_oversub ×` capacity), so a
//!   pool can't accept more decode state than the arena can plausibly turn
//!   over.
//! * **Residency** — [`KvManager::register`] makes a freshly-prefilled
//!   stream resident; streams parked between steps *keep their pages* —
//!   parked KV is never free.
//! * **Eviction** — when a step needs pages the arena doesn't have, the
//!   least-recently-used parked stream is evicted (its pages freed, its
//!   logical bytes remembered). A group member is never evicted for its own
//!   step.
//! * **Swap-in charging** — [`KvManager::prepare_group`] returns the EMA
//!   bytes the step must pay up front: every evicted member re-streams its
//!   whole resident KV from DRAM before the step runs.
//!
//! If even evicting every evictable stream can't make room (a single group
//! larger than the arena, or concurrent workers' pinned in-flight groups
//! that genuinely don't co-fit), the manager *overcommits* rather than
//! deadlocks and counts it in [`KvStats::forced_overcommit`] — the
//! physical analogue is per-step spilling, which the GB budget path
//! already charges.

use crate::config::{HwConfig, ModelConfig};
use crate::coordinator::request::RequestId;
use crate::kv::arena::KvArena;
use crate::kv::quant::KvQuant;
use crate::kv::MAX_GROUP_STREAMS;
use crate::sim::GbBudget;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::Mutex;

/// Arena geometry + policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct KvArenaConfig {
    /// Fixed page size, bytes (default: `HwConfig::kv_page_bytes`).
    pub page_bytes: u64,
    /// Aggregate residency cap, pages.
    pub capacity_pages: usize,
    /// Storage precision of the arena.
    pub quant: KvQuant,
    /// Admission head-room: new generate streams are rejected once the
    /// projected bytes of live streams exceed `admit_oversub ×` capacity.
    /// 1.0 bounds admission at exactly what fits resident; > 1.0 admits
    /// more and lets the LRU churn (rejoining streams pay swap-in EMA).
    pub admit_oversub: f64,
}

impl KvArenaConfig {
    /// Derive the arena from the hardware and model: capacity is the GB
    /// minus the fixed decode residents (W_S, both W_D slots, activations
    /// and dequant scratch at the pool's widest group). `pages_override`
    /// (the `--kv-pages` knob) replaces the derived page count.
    pub fn for_pool(
        hw: &HwConfig,
        m: &ModelConfig,
        quant: KvQuant,
        pages_override: Option<usize>,
    ) -> KvArenaConfig {
        let b = GbBudget::for_decode_quant(hw, m, 0, MAX_GROUP_STREAMS, quant);
        // Single-buffer floor, same as `max_decode_len_quant`: deep-KV decode
        // gives the prefetch slot up first, so the arena and the caps are
        // derived from the SAME fixed-resident set — a group of streams at
        // their class cap fits the arena up to page rounding. (Cross-attention
        // memory is per-stream and lives in the streams' bytes, not here.)
        let fixed = b.ws_bytes + b.wd_slot_bytes + b.activation_bytes;
        let page_bytes = (hw.kv_page_bytes as u64).max(1);
        let derived = (b.capacity.saturating_sub(fixed) / page_bytes) as usize;
        KvArenaConfig {
            page_bytes,
            capacity_pages: pages_override.unwrap_or(derived).max(1),
            quant,
            admit_oversub: 1.5,
        }
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.page_bytes * self.capacity_pages as u64
    }
}

/// Counters the manager accumulates over its lifetime.
#[derive(Debug, Default, Clone, Copy)]
pub struct KvStats {
    /// Generate streams admitted (via `try_admit` or auto-registration).
    pub admitted: u64,
    /// Generate streams refused at admission (arena projection full).
    pub admit_rejected: u64,
    /// Parked streams evicted to make room.
    pub evictions: u64,
    /// Evicted streams that rejoined a step (each paid swap-in EMA).
    pub swap_ins: u64,
    /// Total swap-in EMA bytes charged.
    pub swap_in_bytes: u64,
    /// Streams released (completed or cap-clamped to zero).
    pub released: u64,
    /// Times a group couldn't fit even after evicting every parked stream.
    pub forced_overcommit: u64,
    /// High-water mark of arena occupancy, pages.
    pub peak_used_pages: usize,
}

/// Point-in-time occupancy snapshot: what the manager still holds. After a
/// pool drains (every admitted stream completed or shed), all four fields
/// must be zero — any nonzero field is a leaked reservation, pinned group,
/// or orphaned page. Checked by the scenario fuzzer after every drain.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct KvResidual {
    /// Admitted, unreleased streams.
    pub live_streams: usize,
    /// Arena pages still backing resident streams.
    pub resident_pages: usize,
    /// Admission-projection bytes still reserved.
    pub admitted_bytes: u64,
    /// Streams pinned by an in-flight decode group.
    pub pinned_streams: usize,
}

impl KvResidual {
    /// Nothing held: the drained-pool leak-freedom invariant.
    pub fn is_clean(&self) -> bool {
        self.live_streams == 0
            && self.resident_pages == 0
            && self.admitted_bytes == 0
            && self.pinned_streams == 0
    }
}

/// What one decode step owes the EMA ledger before it runs.
#[derive(Debug, Default, Clone, Copy)]
pub struct StepCharge {
    /// KV bytes re-streamed from DRAM for evicted members rejoining.
    pub swap_in_bytes: u64,
    /// How many members were swapped in.
    pub swap_ins: u64,
}

/// Per-stream arena bookkeeping. `bytes` is the stream's logical quantized
/// KV (self-attention prefix + cross-attention memory); `pages` backs it
/// while resident and is 0 after eviction (the bytes are remembered — they
/// are exactly what a rejoin must swap back in).
#[derive(Debug, Clone, Copy)]
struct StreamEntry {
    bytes: u64,
    pages: usize,
    resident: bool,
    /// In a decode step right now ([`KvManager::prepare_group`] …
    /// [`KvManager::finish_group`]): never evictable — a concurrent
    /// worker's group must not pull pages an in-flight step is reading.
    pinned: bool,
    last_used: u64,
    /// Projected lifetime bytes held against the admission bound.
    projected: u64,
}

#[derive(Debug)]
struct Inner {
    arena: KvArena,
    streams: HashMap<RequestId, StreamEntry>,
    /// Sum of live streams' projected bytes (the admission ledger).
    admitted_bytes: u64,
    /// LRU clock (incremented per step / registration).
    clock: u64,
    stats: KvStats,
}

impl Inner {
    /// Evict LRU parked streams until `pages` are free (never a `protect`
    /// member, never a pinned stream — some worker's in-flight step is
    /// reading those pages). Returns false when room could not be made —
    /// the caller proceeds overcommitted.
    fn make_room(&mut self, pages: usize, protect: &[RequestId]) -> bool {
        while self.arena.free_pages() < pages {
            let victim = self
                .streams
                .iter()
                .filter(|(id, e)| {
                    e.resident && e.pages > 0 && !e.pinned && !protect.contains(id)
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    let e = self.streams.get_mut(&id).expect("victim exists");
                    self.arena.free(e.pages);
                    e.pages = 0;
                    e.resident = false;
                    self.stats.evictions += 1;
                }
                None => return false,
            }
        }
        true
    }

    /// Make `id` resident with `bytes` of KV, growing/shrinking its pages;
    /// evicts others as needed. Assumes the entry exists.
    fn make_resident(&mut self, id: RequestId, bytes: u64, protect: &[RequestId]) {
        let entry = *self.streams.get(&id).expect("entry exists");
        let needed = self.arena.pages_for(bytes);
        let grow = needed.saturating_sub(entry.pages);
        if grow > 0 && !self.make_room(grow, protect) {
            self.stats.forced_overcommit += 1;
        }
        if needed >= entry.pages {
            self.arena.alloc(needed - entry.pages);
        } else {
            self.arena.free(entry.pages - needed);
        }
        let e = self.streams.get_mut(&id).expect("entry exists");
        e.bytes = bytes;
        e.pages = needed;
        e.resident = true;
        e.last_used = self.clock;
        self.stats.peak_used_pages = self.stats.peak_used_pages.max(self.arena.used_pages());
    }
}

/// Pool-wide paged KV-cache manager (see module docs). All methods take
/// `&self`; the state sits behind one mutex — decode steps touch it once
/// per step, far off any per-token hot path.
#[derive(Debug)]
pub struct KvManager {
    cfg: KvArenaConfig,
    /// Self-attention KV bytes one token adds for one stream.
    per_token_bytes: u64,
    /// Fixed cross-attention encoder-memory bytes per stream (enc-dec only).
    cross_bytes: u64,
    /// Decode-stack depth (per-layer dequant accounting).
    layers: u64,
    /// Residency caps per decode width (1/2/4-wide), indexed by
    /// `width.trailing_zeros()` — they clamp admission projections so an
    /// over-asking `generate` doesn't project bytes the engine's class cap
    /// will never let it grow to.
    caps: [usize; 3],
    inner: Mutex<Inner>,
}

impl KvManager {
    pub fn new(hw: &HwConfig, m: &ModelConfig, cfg: KvArenaConfig) -> KvManager {
        let stack = if m.dec_layers > 0 { m.dec_layers } else { m.enc_layers };
        let layers = (stack as u64).max(1);
        let cap = |w: usize| GbBudget::max_decode_len_quant(hw, m, w, cfg.quant);
        KvManager {
            per_token_bytes: GbBudget::kv_cache_bytes_quant(m, 1, 1, cfg.quant),
            cross_bytes: GbBudget::cross_kv_bytes_quant(m, 1, cfg.quant),
            layers,
            caps: [cap(1), cap(2), cap(4)],
            inner: Mutex::new(Inner {
                arena: KvArena::new(cfg.page_bytes, cfg.capacity_pages),
                streams: HashMap::new(),
                admitted_bytes: 0,
                clock: 0,
                stats: KvStats::default(),
            }),
            cfg,
        }
    }

    pub fn quant(&self) -> KvQuant {
        self.cfg.quant
    }

    pub fn config(&self) -> KvArenaConfig {
        self.cfg
    }

    /// Logical quantized KV bytes of one stream at `past_len`.
    pub fn stream_bytes(&self, past_len: usize) -> u64 {
        self.cross_bytes + past_len as u64 * self.per_token_bytes
    }

    /// Quantized bytes one layer's dequant pass touches for a `group`-wide
    /// step padded to depth `past_len` (0 when the mode needs no dequant).
    /// Deterministic in `(group, past_len)` so it can live inside the
    /// sim-cache entry for the step.
    pub fn dequant_bytes_per_layer(&self, group: usize, past_len: usize) -> u64 {
        if !self.cfg.quant.dequant() {
            return 0;
        }
        group as u64 * self.stream_bytes(past_len) / self.layers
    }

    /// Residency cap at a decode width (the depth the engine will clamp a
    /// stream of that class to).
    pub fn cap_for_width(&self, width: usize) -> usize {
        let idx = (width.max(1).trailing_zeros() as usize).min(2);
        self.caps[idx]
    }

    /// Admission: reserve projected arena bytes for a generate stream of a
    /// class decoding `width`-wide (its projection clamps at that class's
    /// residency cap — the depth the engine will actually allow). Returns
    /// false (and counts the rejection) when the pool's live streams
    /// already project past the oversubscription bound. A first stream is
    /// always admitted — a request bigger than the arena is the
    /// cap/overcommit paths' problem, not a deadlock.
    pub fn try_admit(
        &self,
        id: RequestId,
        prefill_len: usize,
        generate: usize,
        width: usize,
    ) -> bool {
        let cap = self.cap_for_width(width);
        let depth = (prefill_len + generate).min(cap.max(prefill_len));
        let projected = self.stream_bytes(depth);
        let limit = (self.cfg.capacity_bytes() as f64 * self.cfg.admit_oversub) as u64;
        let mut g = self.inner.lock().unwrap();
        if g.streams.contains_key(&id) {
            // Duplicate live id (client reuse while the first stream is
            // still in flight): refusing beats overwriting the live
            // stream's page/reservation accounting, which could never be
            // released again.
            g.stats.admit_rejected += 1;
            return false;
        }
        if g.admitted_bytes > 0 && g.admitted_bytes + projected > limit {
            g.stats.admit_rejected += 1;
            return false;
        }
        g.admitted_bytes += projected;
        g.clock += 1;
        let clock = g.clock;
        g.streams.insert(
            id,
            StreamEntry {
                bytes: 0,
                pages: 0,
                resident: false,
                pinned: false,
                last_used: clock,
                projected,
            },
        );
        g.stats.admitted += 1;
        true
    }

    /// A stream finished prefill: its KV becomes arena-resident (no swap
    /// charge — prefill writes the planes fresh). Auto-admits streams that
    /// skipped `try_admit` (single-engine setups without pool admission).
    pub fn register(&self, id: RequestId, prefill_len: usize) {
        let bytes = self.stream_bytes(prefill_len);
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        inner.clock += 1;
        let clock = inner.clock;
        let e = inner.streams.entry(id).or_insert(StreamEntry {
            bytes: 0,
            pages: 0,
            resident: false,
            pinned: false,
            last_used: clock,
            projected: 0,
        });
        e.last_used = clock;
        if e.projected == 0 {
            e.projected = bytes;
            inner.admitted_bytes += bytes;
            inner.stats.admitted += 1;
        }
        inner.make_resident(id, bytes, &[id]);
    }

    /// Bring every member of a decode group resident at its current depth
    /// and return the step's swap-in charge: each member that was evicted
    /// re-streams its whole KV from DRAM before the step runs. Members are
    /// protected from evicting each other AND pinned until
    /// [`KvManager::finish_group`] (or [`KvManager::release`]) — a
    /// concurrent worker's group must not evict pages an in-flight step is
    /// reading. Parked (unpinned) streams go LRU-first.
    pub fn prepare_group(&self, members: &[(RequestId, usize)]) -> StepCharge {
        let mut charge = StepCharge::default();
        let protect: Vec<RequestId> = members.iter().map(|&(id, _)| id).collect();
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        for &(id, past_len) in members {
            let bytes = self.stream_bytes(past_len);
            let known = g.streams.get(&id).copied();
            let entry = known.unwrap_or(StreamEntry {
                bytes: 0,
                pages: 0,
                resident: false,
                pinned: false,
                last_used: clock,
                projected: 0,
            });
            if known.is_none() {
                // Unregistered stream (defensive): admit + register silently.
                g.admitted_bytes += bytes;
                g.stats.admitted += 1;
                g.streams.insert(id, StreamEntry { projected: bytes, ..entry });
            }
            if !entry.resident && entry.bytes > 0 {
                // Evicted stream rejoining: its resident KV swaps back in.
                charge.swap_in_bytes += bytes;
                charge.swap_ins += 1;
                g.stats.swap_ins += 1;
                g.stats.swap_in_bytes += bytes;
            }
            g.make_resident(id, bytes, &protect);
            if let Some(e) = g.streams.get_mut(&id) {
                e.pinned = true;
            }
        }
        charge
    }

    /// A decode step finished: its members park (stay resident, become
    /// evictable again). Released/missing ids are skipped.
    pub fn finish_group(&self, members: &[(RequestId, usize)]) {
        let mut g = self.inner.lock().unwrap();
        for &(id, _) in members {
            if let Some(e) = g.streams.get_mut(&id) {
                e.pinned = false;
            }
        }
    }

    /// A stream is done (final token, cap-clamped to zero, or shed): free
    /// its pages and release its admission reservation.
    pub fn release(&self, id: RequestId) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.streams.remove(&id) {
            if e.resident {
                g.arena.free(e.pages);
            }
            g.admitted_bytes = g.admitted_bytes.saturating_sub(e.projected);
            g.stats.released += 1;
        }
    }

    pub fn stats(&self) -> KvStats {
        self.inner.lock().unwrap().stats
    }

    /// Pages currently backing resident streams.
    pub fn used_pages(&self) -> usize {
        self.inner.lock().unwrap().arena.used_pages()
    }

    /// Live (admitted, unreleased) streams.
    pub fn live_streams(&self) -> usize {
        self.inner.lock().unwrap().streams.len()
    }

    /// What the manager is still holding right now — the leak-freedom
    /// invariant the fuzzer asserts after a full drain: a pool that
    /// completed or shed every stream must leave the arena exactly as it
    /// found it ([`KvResidual::is_clean`]).
    pub fn residual(&self) -> KvResidual {
        let g = self.inner.lock().unwrap();
        KvResidual {
            live_streams: g.streams.len(),
            resident_pages: g.arena.used_pages(),
            admitted_bytes: g.admitted_bytes,
            pinned_streams: g.streams.values().filter(|e| e.pinned).count(),
        }
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        Json::obj(vec![
            ("quant", Json::str(self.cfg.quant.name().to_string())),
            ("page_bytes", Json::num(self.cfg.page_bytes as f64)),
            ("capacity_pages", Json::num(self.cfg.capacity_pages as f64)),
            ("admit_oversub", Json::num(self.cfg.admit_oversub)),
            ("used_pages", Json::num(g.arena.used_pages() as f64)),
            ("live_streams", Json::num(g.streams.len() as f64)),
            ("admitted", Json::num(g.stats.admitted as f64)),
            ("admit_rejected", Json::num(g.stats.admit_rejected as f64)),
            ("evictions", Json::num(g.stats.evictions as f64)),
            ("swap_ins", Json::num(g.stats.swap_ins as f64)),
            ("swap_in_bytes", Json::num(g.stats.swap_in_bytes as f64)),
            ("forced_overcommit", Json::num(g.stats.forced_overcommit as f64)),
            ("peak_used_pages", Json::num(g.stats.peak_used_pages as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mgr(pages: usize, quant: KvQuant, oversub: f64) -> (KvManager, u64) {
        let hw = HwConfig::default();
        let m = ModelConfig::tiny();
        let mut cfg = KvArenaConfig::for_pool(&hw, &m, quant, Some(pages));
        cfg.admit_oversub = oversub;
        let per_token = GbBudget::kv_cache_bytes_quant(&m, 1, 1, quant);
        (KvManager::new(&hw, &m, cfg), per_token)
    }

    #[test]
    fn register_evict_lru_and_charge_swap_on_rejoin() {
        // 4 × 2 KiB pages; tiny @ fp16 is 512 B/token, so an 8-token stream
        // owns 2 pages and the arena fits exactly two streams.
        let (mgr, per_token) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        assert_eq!(per_token, 512);
        mgr.register(1, 8);
        mgr.register(2, 8);
        assert_eq!(mgr.used_pages(), 4);
        // A third stream evicts the LRU (stream 1) — parked KV is never
        // free: it must be evicted, not forgotten.
        mgr.register(3, 8);
        assert_eq!(mgr.used_pages(), 4);
        assert_eq!(mgr.stats().evictions, 1);
        // Stream 1 rejoins a step: swap-in charged for its whole KV, and
        // room is made by evicting the next LRU (stream 2).
        let c = mgr.prepare_group(&[(1, 8)]);
        assert_eq!(c.swap_ins, 1);
        assert_eq!(c.swap_in_bytes, 8 * per_token);
        assert_eq!(mgr.stats().evictions, 2);
        assert_eq!(mgr.stats().peak_used_pages, 4, "residency cap held throughout");
        // Resident members never pay again.
        let c2 = mgr.prepare_group(&[(1, 9)]);
        assert_eq!(c2.swap_ins, 0);
        for id in [1, 2, 3] {
            mgr.release(id);
        }
        assert_eq!(mgr.used_pages(), 0);
        assert_eq!(mgr.live_streams(), 0);
    }

    #[test]
    fn group_members_protected_from_each_other() {
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        mgr.register(1, 8);
        mgr.register(2, 8); // arena exactly full with both
        let c = mgr.prepare_group(&[(1, 8), (2, 8)]);
        assert_eq!(c.swap_ins, 0, "both resident, neither may evict the other");
        assert_eq!(mgr.stats().evictions, 0);
    }

    #[test]
    fn pinned_in_flight_groups_are_never_evicted() {
        // Two workers decoding concurrently over one shared arena: a
        // group's pages must survive another worker's room-making for the
        // whole step — overcommit is counted instead of a spurious evict.
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        mgr.register(1, 8);
        mgr.register(2, 8); // arena full
        let _ = mgr.prepare_group(&[(1, 8)]); // worker A: stream 1 in flight
        let _ = mgr.prepare_group(&[(3, 8)]); // worker B: evicts parked 2, not pinned 1
        assert_eq!(mgr.stats().evictions, 1);
        // Stream 2 rejoins while 1 and 3 are both pinned: no victims —
        // forced overcommit, never an eviction of an in-flight group.
        let c = mgr.prepare_group(&[(2, 8)]);
        assert_eq!(c.swap_ins, 1);
        assert_eq!(mgr.stats().evictions, 1);
        assert!(mgr.stats().forced_overcommit >= 1);
        // Once worker A's step finishes, its stream parks and is evictable.
        mgr.finish_group(&[(1, 8)]);
        let _ = mgr.prepare_group(&[(4, 8)]);
        assert_eq!(mgr.stats().evictions, 2, "unpinned stream evictable again");
    }

    #[test]
    fn admission_bounds_projected_bytes() {
        // 4 pages = 8 KiB at oversub 1.0; each stream projects 8 tokens
        // (4 prefill + 4 generate) × 512 B = 4 KiB.
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 1.0);
        assert!(mgr.try_admit(1, 4, 4, 4));
        assert!(mgr.try_admit(2, 4, 4, 4), "exactly at the bound still admits");
        assert!(!mgr.try_admit(3, 4, 4, 4), "past the bound rejects");
        assert_eq!(mgr.stats().admit_rejected, 1);
        mgr.release(1);
        assert!(mgr.try_admit(3, 4, 4, 4), "released reservations free the bound");
        // A live id can't be admitted twice — overwriting would orphan the
        // first stream's pages and reservation forever.
        assert!(!mgr.try_admit(3, 4, 4, 4), "duplicate live id refused");
        mgr.release(3);
        assert!(mgr.try_admit(3, 4, 4, 4), "released id is reusable");
        // Projections clamp at the *class's* residency cap: an absurd ask
        // does not project bytes the engine will never allow, and a wide
        // class clamps tighter than a solo stream.
        let (mgr2, per_token) = tiny_mgr(1 << 16, KvQuant::Fp16, 1.0);
        assert!(mgr2.try_admit(7, 4, usize::MAX / 2, 1));
        let hw = HwConfig::default();
        let m = ModelConfig::tiny();
        let cap_b1 = GbBudget::max_decode_len_quant(&hw, &m, 1, KvQuant::Fp16);
        let cap_b4 = GbBudget::max_decode_len_quant(&hw, &m, 4, KvQuant::Fp16);
        assert!(cap_b4 < cap_b1);
        assert_eq!(mgr2.cap_for_width(1), cap_b1);
        assert_eq!(mgr2.cap_for_width(4), cap_b4);
        {
            let g = mgr2.inner.lock().unwrap();
            assert_eq!(g.admitted_bytes, cap_b1 as u64 * per_token);
        }
        assert!(mgr2.try_admit(8, 4, usize::MAX / 2, 4));
        let g = mgr2.inner.lock().unwrap();
        assert_eq!(g.admitted_bytes, (cap_b1 + cap_b4) as u64 * per_token);
    }

    #[test]
    fn oversized_group_overcommits_instead_of_deadlocking() {
        let (mgr, _) = tiny_mgr(1, KvQuant::Fp16, 8.0);
        mgr.register(1, 100); // 50 KiB into a 2 KiB arena
        assert!(mgr.stats().forced_overcommit >= 1);
        assert!(mgr.used_pages() > 1);
        mgr.release(1);
        assert_eq!(mgr.used_pages(), 0);
    }

    #[test]
    fn residual_tracks_holdings_and_is_clean_after_drain() {
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        assert!(mgr.residual().is_clean(), "fresh manager holds nothing");
        assert!(mgr.try_admit(1, 4, 4, 1));
        let r = mgr.residual();
        assert_eq!(r.live_streams, 1);
        assert!(r.admitted_bytes > 0, "admission reserves projection bytes");
        assert!(!r.is_clean());
        mgr.register(1, 8);
        let _ = mgr.prepare_group(&[(1, 8)]);
        let pinned = mgr.residual();
        assert_eq!(pinned.pinned_streams, 1, "in-flight group pins its member");
        assert!(pinned.resident_pages > 0);
        mgr.finish_group(&[(1, 8)]);
        assert_eq!(mgr.residual().pinned_streams, 0, "parked after the step");
        assert!(mgr.residual().resident_pages > 0, "parked keeps pages");
        mgr.release(1);
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn quantization_scales_stream_bytes_and_dequant() {
        let hw = HwConfig::default();
        let m = ModelConfig::s2t_small();
        let mk = |q| KvManager::new(&hw, &m, KvArenaConfig::for_pool(&hw, &m, q, None));
        let f16 = mk(KvQuant::Fp16);
        let i8_ = mk(KvQuant::Int8);
        let i4 = mk(KvQuant::Int4);
        assert_eq!(f16.stream_bytes(32), 2 * i8_.stream_bytes(32));
        assert_eq!(f16.stream_bytes(32), 4 * i4.stream_bytes(32));
        // Dequant: zero at full precision, per-layer share of the padded
        // group below it.
        assert_eq!(f16.dequant_bytes_per_layer(4, 32), 0);
        let layers = m.dec_layers as u64;
        assert_eq!(i8_.dequant_bytes_per_layer(4, 32), 4 * i8_.stream_bytes(32) / layers);
        assert!(i4.dequant_bytes_per_layer(4, 32) < i8_.dequant_bytes_per_layer(4, 32));
    }
}
