//! Pool-wide paged KV-cache manager.
//!
//! One `KvManager` is shared (via `Arc`) by every engine worker of a pool
//! plus the admission path, and owns the global-buffer KV arena: fixed-size
//! pages allocated per decode stream (self-attention KV growing with
//! `past_len`, plus the fixed cross-attention encoder memory for enc-dec
//! models), stored at a configurable [`KvQuant`] precision.
//!
//! It replaces the per-group `GbBudget::for_decode` idealization with an
//! **aggregate** residency model:
//!
//! * **Admission** — [`KvManager::try_admit`] bounds concurrent generate
//!   streams by projected arena bytes (`admit_oversub ×` capacity), so a
//!   pool can't accept more decode state than the arena can plausibly turn
//!   over.
//! * **Residency** — [`KvManager::register`] makes a freshly-prefilled
//!   stream resident; streams parked between steps *keep their pages* —
//!   parked KV is never free.
//! * **Eviction** — when a step needs pages the arena doesn't have, the
//!   least-recently-used parked stream is evicted (its pages freed, its
//!   logical bytes remembered). A group member is never evicted for its own
//!   step.
//! * **Swap-in charging** — [`KvManager::prepare_group`] returns the EMA
//!   bytes the step must pay up front: every evicted member re-streams its
//!   whole resident KV from DRAM before the step runs.
//!
//! * **Prefix sharing** — streams registered with a [`PrefixId`] attach
//!   to their group's refcounted prefix chain in the
//!   [`crate::kv::radix::RadixIndex`]: one physical copy of the shared
//!   prompt KV serves every prefix-mate, admission and registration
//!   project/allocate only the *non-shared* bytes when the prefix is warm,
//!   eviction and swap-in apply to private pages only (the shared chain
//!   stays pinned by its refcounts), and a stream decoding past an
//!   unaligned prefix boundary forks **copy-on-write**: the prefix's
//!   partial tail page is duplicated into its private region
//!   ([`KvStats::cow_forks`]) so appends never touch a shared page.
//! * **Compaction** — parked streams round their bytes up to whole pages;
//!   [`KvManager::compact`] (run automatically before eviction) packs that
//!   ceil-rounding slack so the fleet's parked total needs only
//!   `ceil(Σ bytes / page)` pages.
//!
//! If even evicting every evictable stream can't make room (a single group
//! larger than the arena, or concurrent workers' pinned in-flight groups
//! that genuinely don't co-fit), the manager *overcommits* rather than
//! deadlocks and counts it in [`KvStats::forced_overcommit`] — the
//! physical analogue is per-step spilling, which the GB budget path
//! already charges.

use crate::config::{HwConfig, ModelConfig};
use crate::coordinator::request::RequestId;
use crate::kv::arena::KvArena;
use crate::kv::quant::KvQuant;
use crate::kv::radix::{PrefixId, RadixIndex};
use crate::kv::MAX_GROUP_STREAMS;
use crate::obs::{SpanEvent, SpanKind, SpanWriter};
use crate::sim::GbBudget;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Arena geometry + policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct KvArenaConfig {
    /// Fixed page size, bytes (default: `HwConfig::kv_page_bytes`).
    pub page_bytes: u64,
    /// Aggregate residency cap, pages.
    pub capacity_pages: usize,
    /// Storage precision of the arena.
    pub quant: KvQuant,
    /// Admission head-room: new generate streams are rejected once the
    /// projected bytes of live streams exceed `admit_oversub ×` capacity.
    /// 1.0 bounds admission at exactly what fits resident; > 1.0 admits
    /// more and lets the LRU churn (rejoining streams pay swap-in EMA).
    pub admit_oversub: f64,
}

impl KvArenaConfig {
    /// Derive the arena from the hardware and model: capacity is the GB
    /// minus the fixed decode residents (W_S, both W_D slots, activations
    /// and dequant scratch at the pool's widest group). `pages_override`
    /// (the `--kv-pages` knob) replaces the derived page count.
    pub fn for_pool(
        hw: &HwConfig,
        m: &ModelConfig,
        quant: KvQuant,
        pages_override: Option<usize>,
    ) -> KvArenaConfig {
        let b = GbBudget::for_decode_quant(hw, m, 0, MAX_GROUP_STREAMS, quant);
        // Single-buffer floor, same as `max_decode_len_quant`: deep-KV decode
        // gives the prefetch slot up first, so the arena and the caps are
        // derived from the SAME fixed-resident set — a group of streams at
        // their class cap fits the arena up to page rounding. (Cross-attention
        // memory is per-stream and lives in the streams' bytes, not here.)
        let fixed = b.ws_bytes + b.wd_slot_bytes + b.activation_bytes;
        let page_bytes = (hw.kv_page_bytes as u64).max(1);
        let derived = (b.capacity.saturating_sub(fixed) / page_bytes) as usize;
        KvArenaConfig {
            page_bytes,
            capacity_pages: pages_override.unwrap_or(derived).max(1),
            quant,
            admit_oversub: 1.5,
        }
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.page_bytes * self.capacity_pages as u64
    }
}

/// Counters the manager accumulates over its lifetime.
#[derive(Debug, Default, Clone, Copy)]
pub struct KvStats {
    /// Generate streams admitted (via `try_admit` or auto-registration).
    pub admitted: u64,
    /// Generate streams refused at admission (arena projection full).
    pub admit_rejected: u64,
    /// Parked streams evicted to make room.
    pub evictions: u64,
    /// Evicted streams that rejoined a step (each paid swap-in EMA).
    pub swap_ins: u64,
    /// Total swap-in EMA bytes charged.
    pub swap_in_bytes: u64,
    /// Streams released (completed or cap-clamped to zero).
    pub released: u64,
    /// Times a group couldn't fit even after evicting every parked stream.
    pub forced_overcommit: u64,
    /// High-water mark of arena occupancy, pages.
    pub peak_used_pages: usize,
    /// Registrations that found their prefix group already (partly)
    /// resident — pages this stream shares instead of re-writing.
    pub prefix_hits: u64,
    /// Streams that forked copy-on-write at the divergence point (decode
    /// outgrew an unaligned shared prefix; its partial tail page was
    /// duplicated privately).
    pub cow_forks: u64,
    /// Compaction passes that reclaimed at least one page.
    pub compactions: u64,
    /// Ceil-rounding slack pages reclaimed by compaction.
    pub compacted_pages: u64,
    /// Streams migrated **into** this arena from another chip's
    /// ([`KvManager::migrate_in`] — fleet mode only).
    pub migrations: u64,
    /// Total bytes chip-to-chip migrations streamed into this arena
    /// (private KV always; a shared prefix chain once per chain).
    pub migrated_bytes: u64,
    /// Shared prefix chains physically moved here by a migration — each
    /// chain is charged exactly once; follower mates attach warm.
    pub chain_migrations: u64,
    /// Cold (zero-ref) chain pages reclaimed under pressure or drain.
    pub cold_reclaimed_pages: u64,
}

/// Point-in-time occupancy snapshot: what the manager still holds. After a
/// pool drains (every admitted stream completed or shed), every field
/// must be zero — any nonzero field is a leaked reservation, pinned group,
/// orphaned page, or dangling prefix refcount. Checked by the scenario
/// fuzzer after every drain.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct KvResidual {
    /// Admitted, unreleased streams.
    pub live_streams: usize,
    /// Arena pages still backing resident streams.
    pub resident_pages: usize,
    /// Admission-projection bytes still reserved.
    pub admitted_bytes: u64,
    /// Streams pinned by an in-flight decode group.
    pub pinned_streams: usize,
    /// Arena pages still backing shared prefix chains.
    pub shared_pages: usize,
    /// Stream references still held on prefix-chain spans.
    pub prefix_refs: usize,
}

impl KvResidual {
    /// Nothing held: the drained-pool leak-freedom invariant. Shared pages
    /// and prefix refcounts must both be zero too — a drained pool may not
    /// keep a zero-stream prefix cache or a dangling refcount.
    pub fn is_clean(&self) -> bool {
        self.live_streams == 0
            && self.resident_pages == 0
            && self.admitted_bytes == 0
            && self.pinned_streams == 0
            && self.shared_pages == 0
            && self.prefix_refs == 0
    }
}

/// What one decode step owes the EMA ledger before it runs.
#[derive(Debug, Default, Clone, Copy)]
pub struct StepCharge {
    /// KV bytes re-streamed from DRAM for evicted members rejoining.
    pub swap_in_bytes: u64,
    /// How many members were swapped in.
    pub swap_ins: u64,
}

/// What travels when a stream moves between chips' arenas (fleet mode:
/// prefill finishes on chip A, decode runs on chip B). Produced by
/// [`KvManager::migrate_out`] on the source, consumed by
/// [`KvManager::migrate_in`] on the target — the target returns the bytes
/// that physically streamed, which the caller prices like a `KvSwap`.
#[derive(Debug, Clone, Copy)]
pub struct KvMigration {
    /// The stream's private quantized KV — always streams.
    pub private_bytes: u64,
    /// Shared-prefix bytes the stream had attached — streams **once per
    /// chain**; follower mates find it resident and attach warm.
    pub shared_bytes: u64,
    /// Prefix group the shared bytes belong to.
    pub prefix: Option<PrefixId>,
    /// Admission projection to carry to the target (re-reserved there if
    /// the stream wasn't already admitted against the target's budget).
    pub projected: u64,
}

/// Per-stream arena bookkeeping. `bytes` is the stream's **private**
/// quantized KV — everything its own pages must back: cross-attention
/// memory, decode tokens past the shared prefix, and (for streams with no
/// prefix group) the whole self-attention prefix. `pages` backs it while
/// resident and is 0 after eviction (the bytes are remembered — they are
/// exactly what a rejoin must swap back in; shared-prefix pages never
/// evict, so they are never part of the charge).
#[derive(Debug, Clone, Copy)]
struct StreamEntry {
    bytes: u64,
    pages: usize,
    resident: bool,
    /// In a decode step right now ([`KvManager::prepare_group`] …
    /// [`KvManager::finish_group`]): never evictable — a concurrent
    /// worker's group must not pull pages an in-flight step is reading.
    pinned: bool,
    last_used: u64,
    /// Projected lifetime bytes held against the admission bound.
    projected: u64,
    /// Prefix group this stream shares its prompt KV with (admission
    /// records it; registration attaches).
    prefix: Option<PrefixId>,
    /// Shared-prefix bytes attached in the radix chain (0 = detached;
    /// exactly what release must detach).
    shared_bytes: u64,
    /// Decode outgrew an unaligned shared prefix: the prefix's partial
    /// tail page is duplicated in this stream's private bytes.
    cow_forked: bool,
}

impl StreamEntry {
    fn fresh(clock: u64) -> StreamEntry {
        StreamEntry {
            bytes: 0,
            pages: 0,
            resident: false,
            pinned: false,
            last_used: clock,
            projected: 0,
            prefix: None,
            shared_bytes: 0,
            cow_forked: false,
        }
    }
}

#[derive(Debug)]
struct Inner {
    arena: KvArena,
    /// Refcounted shared-prefix chains (pages counted in `arena`'s shared
    /// gauge, never in any stream's private pages).
    radix: RadixIndex,
    streams: HashMap<RequestId, StreamEntry>,
    /// Sum of live streams' projected bytes (the admission ledger).
    admitted_bytes: u64,
    /// LRU clock (incremented per step / registration).
    clock: u64,
    stats: KvStats,
    /// Victims evicted since the last public-entry drain — the flight
    /// recorder's KvEvict markers name the victim streams. Drained (and
    /// dropped when tracing is off) by every entry point that can evict.
    evicted: Vec<RequestId>,
}

impl Inner {
    /// Free up to `max_pages` of cold-chain pages (zero-ref prefix tails
    /// retained by release for warm re-attachment), coldest chain first,
    /// and return them to the arena's shared ledger. Runs before
    /// compaction and eviction in [`Inner::make_room`]: reclaiming a cold
    /// chain costs a future prefix-mate a re-prefill, which is cheaper
    /// than the swap-in an evicted *live* stream is guaranteed to pay.
    fn reclaim_cold(&mut self, max_pages: usize) -> usize {
        let freed = self.radix.reclaim_cold(max_pages);
        if freed > 0 {
            self.arena.free_shared(freed);
            self.stats.cold_reclaimed_pages += freed as u64;
        }
        freed
    }

    /// Pack parked streams' ceil-rounding slack: each parked stream rounds
    /// its private bytes up to whole pages, but laid end-to-end (coldest
    /// first, so the LRU order eviction would use is the order tails move
    /// in) the parked set needs only `ceil(Σ bytes / page)` pages. Runs
    /// before eviction in [`Inner::make_room`] and on demand via
    /// [`KvManager::compact`]; no background thread — the pass is O(parked)
    /// under the same lock every step takes. Compacted streams stay
    /// resident (no swap charge); the next step's `make_resident` re-grows
    /// their page count in place.
    fn compact_parked(&mut self, protect: &[RequestId]) -> usize {
        let pb = self.arena.page_bytes();
        let mut parked: Vec<(RequestId, u64, usize, u64)> = self
            .streams
            .iter()
            .filter(|(id, e)| {
                e.resident && !e.pinned && e.pages > 0 && !protect.contains(id)
            })
            .map(|(id, e)| (*id, e.bytes, e.pages, e.last_used))
            .collect();
        if parked.len() < 2 {
            return 0; // a lone stream's ceil page is not reclaimable slack
        }
        parked.sort_by_key(|&(_, _, _, used)| used);
        let mut carry = 0u64; // spare bytes open in the pack's last page
        let mut freed = 0usize;
        for (id, bytes, pages, _) in parked {
            let packed = if bytes <= carry {
                carry -= bytes;
                0
            } else {
                let need = (bytes - carry).div_ceil(pb) as usize;
                carry = need as u64 * pb - (bytes - carry);
                need
            };
            if packed < pages {
                self.arena.free(pages - packed);
                freed += pages - packed;
                self.streams.get_mut(&id).expect("parked id").pages = packed;
            }
        }
        if freed > 0 {
            self.stats.compactions += 1;
            self.stats.compacted_pages += freed as u64;
        }
        freed
    }

    /// Evict LRU parked streams until `pages` are free (never a `protect`
    /// member, never a pinned stream — some worker's in-flight step is
    /// reading those pages). Cold-chain reclamation and compaction run
    /// first — a cold chain nobody references and rounding slack are both
    /// cheaper than an eviction, which costs a future swap-in. Returns
    /// false when room could not be made — the caller proceeds
    /// overcommitted.
    fn make_room(&mut self, pages: usize, protect: &[RequestId]) -> bool {
        if self.arena.free_pages() < pages {
            let want = pages - self.arena.free_pages();
            self.reclaim_cold(want);
        }
        if self.arena.free_pages() < pages {
            self.compact_parked(protect);
        }
        while self.arena.free_pages() < pages {
            let victim = self
                .streams
                .iter()
                .filter(|(id, e)| {
                    e.resident && e.pages > 0 && !e.pinned && !protect.contains(id)
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    let e = self.streams.get_mut(&id).expect("victim exists");
                    self.arena.free(e.pages);
                    e.pages = 0;
                    e.resident = false;
                    self.stats.evictions += 1;
                    self.evicted.push(id);
                }
                None => return false,
            }
        }
        true
    }

    /// Make `id` resident with `bytes` of **private** KV (the shared
    /// prefix, if any, lives in the radix chain and needs no pages here),
    /// growing/shrinking its pages; evicts others as needed. Assumes the
    /// entry exists.
    fn make_resident(&mut self, id: RequestId, bytes: u64, protect: &[RequestId]) {
        let entry = *self.streams.get(&id).expect("entry exists");
        let needed = self.arena.pages_for(bytes);
        let grow = needed.saturating_sub(entry.pages);
        if grow > 0 && !self.make_room(grow, protect) {
            self.stats.forced_overcommit += 1;
        }
        if needed >= entry.pages {
            self.arena.alloc(needed - entry.pages);
        } else {
            self.arena.free(entry.pages - needed);
        }
        let e = self.streams.get_mut(&id).expect("entry exists");
        e.bytes = bytes;
        e.pages = needed;
        e.resident = true;
        e.last_used = self.clock;
        self.stats.peak_used_pages = self.stats.peak_used_pages.max(self.arena.used_pages());
    }
}

/// Pool-wide paged KV-cache manager (see module docs). All methods take
/// `&self`; the state sits behind one mutex — decode steps touch it once
/// per step, far off any per-token hot path.
#[derive(Debug)]
pub struct KvManager {
    cfg: KvArenaConfig,
    /// Self-attention KV bytes one token adds for one stream.
    per_token_bytes: u64,
    /// Fixed cross-attention encoder-memory bytes per stream (enc-dec only).
    cross_bytes: u64,
    /// Decode-stack depth (per-layer dequant accounting).
    layers: u64,
    /// Residency caps per decode width (1/2/4-wide), indexed by
    /// `width.trailing_zeros()` — they clamp admission projections so an
    /// over-asking `generate` doesn't project bytes the engine's class cap
    /// will never let it grow to.
    caps: [usize; 3],
    inner: Mutex<Inner>,
    /// Flight-recorder writer on the pool's KV lane (set once by the pool
    /// when tracing is on; `None` costs one branch per arena event).
    obs: OnceLock<SpanWriter>,
}

impl KvManager {
    pub fn new(hw: &HwConfig, m: &ModelConfig, cfg: KvArenaConfig) -> KvManager {
        let stack = if m.dec_layers > 0 { m.dec_layers } else { m.enc_layers };
        let layers = (stack as u64).max(1);
        let cap = |w: usize| GbBudget::max_decode_len_quant(hw, m, w, cfg.quant);
        KvManager {
            per_token_bytes: GbBudget::kv_cache_bytes_quant(m, 1, 1, cfg.quant),
            cross_bytes: GbBudget::cross_kv_bytes_quant(m, 1, cfg.quant),
            layers,
            caps: [cap(1), cap(2), cap(4)],
            inner: Mutex::new(Inner {
                arena: KvArena::new(cfg.page_bytes, cfg.capacity_pages),
                radix: RadixIndex::new(cfg.page_bytes),
                streams: HashMap::new(),
                admitted_bytes: 0,
                clock: 0,
                stats: KvStats::default(),
                evicted: Vec::new(),
            }),
            obs: OnceLock::new(),
            cfg,
        }
    }

    /// Bind the recorder's KV-arena lane to this manager. First caller
    /// wins (workers race to attach the shared fallback manager); callable
    /// any number of times.
    pub fn attach_span_writer(&self, w: SpanWriter) {
        let _ = self.obs.set(w);
    }

    pub fn quant(&self) -> KvQuant {
        self.cfg.quant
    }

    pub fn config(&self) -> KvArenaConfig {
        self.cfg
    }

    /// Logical quantized KV bytes of one stream at `past_len`.
    pub fn stream_bytes(&self, past_len: usize) -> u64 {
        self.cross_bytes + past_len as u64 * self.per_token_bytes
    }

    /// Bytes one token of self-attention KV adds (the unit of the shared
    /// prefix — cross-attention memory is per-stream and never shared).
    pub fn per_token_bytes(&self) -> u64 {
        self.per_token_bytes
    }

    /// The stream's **private** bytes at `past_len`: its full logical KV
    /// minus the span its shared prefix chain backs. Before a COW fork the
    /// whole attached prefix is discounted; after the fork the prefix's
    /// partial tail page is duplicated privately, so only the page-aligned
    /// floor stays discounted. Streams without a prefix own everything —
    /// this degenerates to [`KvManager::stream_bytes`], the pre-sharing
    /// behavior, bit for bit.
    fn private_bytes(&self, past_len: usize, e: &StreamEntry) -> u64 {
        let total = self.stream_bytes(past_len);
        if e.shared_bytes == 0 {
            return total;
        }
        let discount = if e.cow_forked {
            e.shared_bytes - (e.shared_bytes % self.cfg.page_bytes)
        } else {
            e.shared_bytes
        };
        total.saturating_sub(discount)
    }

    /// Quantized bytes one layer's dequant pass touches for a `group`-wide
    /// step padded to depth `past_len` (0 when the mode needs no dequant).
    /// Deterministic in `(group, past_len)` so it can live inside the
    /// sim-cache entry for the step.
    pub fn dequant_bytes_per_layer(&self, group: usize, past_len: usize) -> u64 {
        if !self.cfg.quant.dequant() {
            return 0;
        }
        group as u64 * self.stream_bytes(past_len) / self.layers
    }

    /// Residency cap at a decode width (the depth the engine will clamp a
    /// stream of that class to).
    pub fn cap_for_width(&self, width: usize) -> usize {
        let idx = (width.max(1).trailing_zeros() as usize).min(2);
        self.caps[idx]
    }

    /// Admission: reserve projected arena bytes for a generate stream of a
    /// class decoding `width`-wide (its projection clamps at that class's
    /// residency cap — the depth the engine will actually allow). Returns
    /// false (and counts the rejection) when the pool's live streams
    /// already project past the oversubscription bound. A first stream is
    /// always admitted — a request bigger than the arena is the
    /// cap/overcommit paths' problem, not a deadlock.
    ///
    /// A `prefix` whose group chain is already resident projects only the
    /// *non-shared* bytes: the warm span is a prefix-mate's cost, not this
    /// stream's — N streams of one prompt admit like 1 prompt + N decode
    /// tails.
    pub fn try_admit(
        &self,
        id: RequestId,
        prefill_len: usize,
        generate: usize,
        width: usize,
        prefix: Option<PrefixId>,
    ) -> bool {
        let cap = self.cap_for_width(width);
        let depth = (prefill_len + generate).min(cap.max(prefill_len));
        let limit = (self.cfg.capacity_bytes() as f64 * self.cfg.admit_oversub) as u64;
        let mut g = self.inner.lock().unwrap();
        if g.streams.contains_key(&id) {
            // Duplicate live id (client reuse while the first stream is
            // still in flight): refusing beats overwriting the live
            // stream's page/reservation accounting, which could never be
            // released again.
            g.stats.admit_rejected += 1;
            return false;
        }
        let warm = prefix
            .map(|gid| {
                g.radix
                    .coverage_bytes(gid)
                    .min(prefill_len as u64 * self.per_token_bytes)
            })
            .unwrap_or(0);
        let projected = self.stream_bytes(depth).saturating_sub(warm);
        if g.admitted_bytes > 0 && g.admitted_bytes + projected > limit {
            g.stats.admit_rejected += 1;
            return false;
        }
        g.admitted_bytes += projected;
        g.clock += 1;
        let clock = g.clock;
        g.streams.insert(id, StreamEntry { projected, prefix, ..StreamEntry::fresh(clock) });
        g.stats.admitted += 1;
        true
    }

    /// A stream finished prefill: its KV becomes arena-resident (no swap
    /// charge — prefill writes the planes fresh). Auto-admits streams that
    /// skipped `try_admit` (single-engine setups without pool admission).
    ///
    /// With a `prefix`, the stream first attaches its prompt span in the
    /// group's radix chain: pages a prefix-mate already faulted in are
    /// referenced (a **prefix hit** — this stream never re-writes them),
    /// only the chain extension allocates, and the stream's own pages back
    /// just the private remainder (cross-attention memory, and later its
    /// decode tail).
    pub fn register(&self, id: RequestId, prefill_len: usize, prefix: Option<PrefixId>) {
        let total = self.stream_bytes(prefill_len);
        let shared = prefill_len as u64 * self.per_token_bytes;
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        inner.clock += 1;
        let clock = inner.clock;
        let e = inner.streams.entry(id).or_insert_with(|| StreamEntry::fresh(clock));
        e.last_used = clock;
        if e.projected == 0 {
            e.projected = total;
            inner.admitted_bytes += total;
            inner.stats.admitted += 1;
        }
        let attach = match prefix {
            // Re-registration of an already-attached stream must not
            // double-reference its chain.
            Some(gid) if e.shared_bytes == 0 && shared > 0 => {
                e.prefix = Some(gid);
                e.shared_bytes = shared;
                Some(gid)
            }
            _ => None,
        };
        if let Some(gid) = attach {
            let need = inner.radix.pages_needed(gid, shared);
            if need > 0 && !inner.make_room(need, &[id]) {
                inner.stats.forced_overcommit += 1;
            }
            let att = inner.radix.attach(gid, shared);
            inner.arena.alloc_shared(att.new_pages);
            if att.hit_pages > 0 {
                inner.stats.prefix_hits += 1;
            }
            inner.stats.peak_used_pages =
                inner.stats.peak_used_pages.max(inner.arena.used_pages());
        }
        let entry = *inner.streams.get(&id).expect("just inserted");
        let private = self.private_bytes(prefill_len, &entry);
        inner.make_resident(id, private, &[id]);
        let evicted = std::mem::take(&mut inner.evicted);
        drop(g);
        if let Some(w) = self.obs.get() {
            let t = w.now_us();
            for victim in evicted {
                w.record(SpanEvent::marker(SpanKind::KvEvict, victim, t));
            }
        }
    }

    /// Bring every member of a decode group resident at its current depth
    /// and return the step's swap-in charge: each member that was evicted
    /// re-streams its whole **private** KV from DRAM before the step runs
    /// (shared prefix pages are refcount-pinned and never evicted, so a
    /// warm prefix is never re-streamed). Members are
    /// protected from evicting each other AND pinned until
    /// [`KvManager::finish_group`] (or [`KvManager::release`]) — a
    /// concurrent worker's group must not evict pages an in-flight step is
    /// reading. Parked (unpinned) streams go LRU-first.
    pub fn prepare_group(&self, members: &[(RequestId, usize)]) -> StepCharge {
        let mut charge = StepCharge::default();
        let protect: Vec<RequestId> = members.iter().map(|&(id, _)| id).collect();
        // (id, private bytes, depth) per swap-in and forked ids, recorded
        // after the lock drops; empty Vecs never allocate when tracing is
        // off and nothing swaps/forks.
        let mut swapped: Vec<(RequestId, u64, usize)> = Vec::new();
        let mut forked_ids: Vec<RequestId> = Vec::new();
        let trace = self.obs.get().is_some();
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        for &(id, past_len) in members {
            if !g.streams.contains_key(&id) {
                // Unregistered stream (defensive): admit + register silently.
                let bytes = self.stream_bytes(past_len);
                g.admitted_bytes += bytes;
                g.stats.admitted += 1;
                g.streams
                    .insert(id, StreamEntry { projected: bytes, ..StreamEntry::fresh(clock) });
            }
            // Copy-on-write at the divergence point: the first step whose
            // depth outgrows an unaligned shared prefix duplicates the
            // prefix's partial tail page into the private region (appends
            // must never touch a page prefix-mates are reading). A
            // page-aligned prefix appends in place and never forks.
            let forked = {
                let e = g.streams.get_mut(&id).expect("ensured above");
                if e.shared_bytes > 0
                    && !e.cow_forked
                    && past_len as u64 * self.per_token_bytes > e.shared_bytes
                    && e.shared_bytes % self.cfg.page_bytes != 0
                {
                    e.cow_forked = true;
                    true
                } else {
                    false
                }
            };
            if forked {
                g.stats.cow_forks += 1;
                if trace {
                    forked_ids.push(id);
                }
            }
            let entry = *g.streams.get(&id).expect("ensured above");
            // Only the private span needs this stream's pages; the shared
            // prefix sits in its chain, pinned by refcounts and immune to
            // eviction — which is also why a rejoining stream's swap-in
            // charge covers private bytes alone: pages a prefix-mate
            // faulted in are still resident and are never re-streamed.
            let private = self.private_bytes(past_len, &entry);
            if !entry.resident && entry.bytes > 0 {
                // Evicted stream rejoining: its private KV swaps back in.
                charge.swap_in_bytes += private;
                charge.swap_ins += 1;
                g.stats.swap_ins += 1;
                g.stats.swap_in_bytes += private;
                if trace {
                    swapped.push((id, private, past_len));
                }
            }
            g.make_resident(id, private, &protect);
            if let Some(e) = g.streams.get_mut(&id) {
                e.pinned = true;
            }
        }
        let evicted = std::mem::take(&mut g.evicted);
        drop(g);
        if let Some(w) = self.obs.get() {
            let t = w.now_us();
            for victim in evicted {
                w.record(SpanEvent::marker(SpanKind::KvEvict, victim, t));
            }
            for (id, bytes, depth) in swapped {
                let mut ev = SpanEvent::marker(SpanKind::KvSwap, id, t);
                ev.ema_kv_bytes = bytes;
                ev.ema_bytes = bytes;
                ev.past_len = depth as u32;
                w.record(ev);
            }
            for id in forked_ids {
                w.record(SpanEvent::marker(SpanKind::KvCowFork, id, t));
            }
        }
        charge
    }

    /// A decode step finished: its members park (stay resident, become
    /// evictable again). Released/missing ids are skipped.
    pub fn finish_group(&self, members: &[(RequestId, usize)]) {
        let mut g = self.inner.lock().unwrap();
        for &(id, _) in members {
            if let Some(e) = g.streams.get_mut(&id) {
                e.pinned = false;
            }
        }
    }

    /// A stream is done (final token, cap-clamped to zero, or shed): free
    /// its private pages, detach from its prefix chain, and release its
    /// admission reservation. A chain whose **last** reference drops is
    /// kept resident as a *cold chain* ([`RadixIndex::detach_retain`]):
    /// the next prefix-mate re-attaches warm, and the pages return to the
    /// arena LRU-first under allocation pressure (`make_room`), via
    /// [`KvManager::compact`], or — so a drained pool holds nothing —
    /// when the last live stream leaves.
    ///
    /// Idempotent by construction: the entry is removed first, so a
    /// mid-prefill shed racing a prefix-mate's release (both paths call
    /// this) can never double-free pages or double-detach the chain — the
    /// second call finds nothing. Below that, the radix detach and the
    /// arena's shared ledger saturate + `debug_assert` as a second line.
    pub fn release(&self, id: RequestId) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.streams.remove(&id) {
            if e.resident {
                g.arena.free(e.pages);
            }
            if let Some(gid) = e.prefix {
                if e.shared_bytes > 0 {
                    g.clock += 1;
                    let stamp = g.clock;
                    g.radix.detach_retain(gid, e.shared_bytes, stamp);
                }
            }
            g.admitted_bytes = g.admitted_bytes.saturating_sub(e.projected);
            g.stats.released += 1;
            if g.streams.is_empty() {
                g.reclaim_cold(usize::MAX);
            }
        }
    }

    /// Move a stream **off** this chip's arena: its entry leaves (private
    /// pages freed, projection released) and its shared prefix span, if
    /// any, detaches into a cold chain — a later mate prefilling here
    /// re-attaches warm. Returns what must travel to the target chip
    /// (consumed by [`KvManager::migrate_in`] there); `None` if the
    /// stream isn't held here (already released — e.g. shed mid-flight).
    pub fn migrate_out(&self, id: RequestId) -> Option<KvMigration> {
        let mut g = self.inner.lock().unwrap();
        let e = g.streams.remove(&id)?;
        if e.resident {
            g.arena.free(e.pages);
        }
        if let Some(gid) = e.prefix {
            if e.shared_bytes > 0 {
                g.clock += 1;
                let stamp = g.clock;
                g.radix.detach_retain(gid, e.shared_bytes, stamp);
            }
        }
        g.admitted_bytes = g.admitted_bytes.saturating_sub(e.projected);
        if g.streams.is_empty() {
            g.reclaim_cold(usize::MAX);
        }
        Some(KvMigration {
            private_bytes: e.bytes,
            shared_bytes: e.shared_bytes,
            prefix: e.prefix,
            projected: e.projected,
        })
    }

    /// Land a migrating stream ([`KvManager::migrate_out`] on the source)
    /// in this chip's arena and return the bytes the transfer actually
    /// streamed chip-to-chip — what the caller prices like a `KvSwap`
    /// (DRAM wall-stall + EMA energy at the source's operating point):
    ///
    /// * the stream's **private** KV always moves;
    /// * its shared prefix chain moves **once per chain**: the first mate
    ///   to land pays the chain pages it physically copies
    ///   ([`KvStats::chain_migrations`]); every follower finds the chain
    ///   resident and attaches warm, paying nothing for it.
    ///
    /// The stream may already hold an admission entry here (the door
    /// admits against the **decode-target** chip in fleet mode); a stream
    /// that doesn't is auto-admitted with the source's projection.
    pub fn migrate_in(&self, id: RequestId, m: &KvMigration) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        inner.clock += 1;
        let clock = inner.clock;
        let e = inner.streams.entry(id).or_insert_with(|| StreamEntry::fresh(clock));
        e.last_used = clock;
        if e.projected == 0 {
            e.projected = m.projected.max(m.private_bytes + m.shared_bytes);
            inner.admitted_bytes += e.projected;
            inner.stats.admitted += 1;
        }
        let attach = match m.prefix {
            Some(gid) if e.shared_bytes == 0 && m.shared_bytes > 0 => {
                e.prefix = Some(gid);
                e.shared_bytes = m.shared_bytes;
                Some(gid)
            }
            _ => None,
        };
        let mut moved = m.private_bytes;
        let mut chain_moved = false;
        if let Some(gid) = attach {
            let need = inner.radix.pages_needed(gid, m.shared_bytes);
            if need > 0 && !inner.make_room(need, &[id]) {
                inner.stats.forced_overcommit += 1;
            }
            let att = inner.radix.attach(gid, m.shared_bytes);
            inner.arena.alloc_shared(att.new_pages);
            if att.hit_pages > 0 {
                // An earlier mate (or a local prefill) already faulted the
                // chain in here: warm attach, nothing streams for it.
                inner.stats.prefix_hits += 1;
            }
            if att.new_pages > 0 {
                moved += att.new_pages as u64 * self.cfg.page_bytes;
                chain_moved = att.hit_pages == 0;
            }
            inner.stats.peak_used_pages =
                inner.stats.peak_used_pages.max(inner.arena.used_pages());
        }
        inner.make_resident(id, m.private_bytes, &[id]);
        inner.stats.migrations += 1;
        inner.stats.migrated_bytes += moved;
        if chain_moved {
            inner.stats.chain_migrations += 1;
        }
        let evicted = std::mem::take(&mut inner.evicted);
        drop(g);
        if let Some(w) = self.obs.get() {
            let t = w.now_us();
            for victim in evicted {
                w.record(SpanEvent::marker(SpanKind::KvEvict, victim, t));
            }
            let mut ev = SpanEvent::marker(SpanKind::KvMigrate, id, t);
            ev.ema_bytes = moved;
            ev.ema_kv_bytes = moved;
            w.record(ev);
        }
        moved
    }

    /// Pack parked streams' ceil-rounding page slack — after returning
    /// every cold chain's pages to the arena — and report the pages
    /// reclaimed (`make_room` also runs both automatically before
    /// resorting to eviction).
    pub fn compact(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        let cold = g.reclaim_cold(usize::MAX);
        cold + g.compact_parked(&[])
    }

    /// Arena pages currently backing shared prefix chains.
    pub fn shared_pages(&self) -> usize {
        self.inner.lock().unwrap().arena.shared_pages()
    }

    pub fn stats(&self) -> KvStats {
        self.inner.lock().unwrap().stats
    }

    /// Pages currently backing resident streams.
    pub fn used_pages(&self) -> usize {
        self.inner.lock().unwrap().arena.used_pages()
    }

    /// Total arena capacity, pages (used / capacity is the occupancy
    /// fraction the DVFS governor gates drops on).
    pub fn capacity_pages(&self) -> usize {
        self.cfg.capacity_pages
    }

    /// Live (admitted, unreleased) streams.
    pub fn live_streams(&self) -> usize {
        self.inner.lock().unwrap().streams.len()
    }

    /// What the manager is still holding right now — the leak-freedom
    /// invariant the fuzzer asserts after a full drain: a pool that
    /// completed or shed every stream must leave the arena exactly as it
    /// found it ([`KvResidual::is_clean`]).
    pub fn residual(&self) -> KvResidual {
        let g = self.inner.lock().unwrap();
        debug_assert_eq!(
            g.arena.shared_pages(),
            g.radix.shared_pages(),
            "arena shared gauge diverged from the radix chains"
        );
        KvResidual {
            live_streams: g.streams.len(),
            resident_pages: g.arena.used_pages(),
            admitted_bytes: g.admitted_bytes,
            pinned_streams: g.streams.values().filter(|e| e.pinned).count(),
            shared_pages: g.arena.shared_pages(),
            prefix_refs: g.radix.total_refs(),
        }
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        Json::obj(vec![
            ("quant", Json::str(self.cfg.quant.name().to_string())),
            ("page_bytes", Json::num(self.cfg.page_bytes as f64)),
            ("capacity_pages", Json::num(self.cfg.capacity_pages as f64)),
            ("admit_oversub", Json::num(self.cfg.admit_oversub)),
            ("used_pages", Json::num(g.arena.used_pages() as f64)),
            ("live_streams", Json::num(g.streams.len() as f64)),
            ("admitted", Json::num(g.stats.admitted as f64)),
            ("admit_rejected", Json::num(g.stats.admit_rejected as f64)),
            ("evictions", Json::num(g.stats.evictions as f64)),
            ("swap_ins", Json::num(g.stats.swap_ins as f64)),
            ("swap_in_bytes", Json::num(g.stats.swap_in_bytes as f64)),
            ("forced_overcommit", Json::num(g.stats.forced_overcommit as f64)),
            ("peak_used_pages", Json::num(g.stats.peak_used_pages as f64)),
            // Prefix-sharing gauges/counters (ISSUE-named for report
            // consumers; `kv_shared_pages` is current occupancy).
            ("kv_prefix_hits", Json::num(g.stats.prefix_hits as f64)),
            ("kv_shared_pages", Json::num(g.arena.shared_pages() as f64)),
            ("kv_cow_forks", Json::num(g.stats.cow_forks as f64)),
            ("compacted_pages", Json::num(g.stats.compacted_pages as f64)),
            // Fleet-mode migration + cold-chain gauges (zero off-fleet).
            ("kv_migrations", Json::num(g.stats.migrations as f64)),
            ("kv_migrated_bytes", Json::num(g.stats.migrated_bytes as f64)),
            ("kv_chain_migrations", Json::num(g.stats.chain_migrations as f64)),
            ("kv_cold_pages", Json::num(g.radix.cold_pages() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mgr(pages: usize, quant: KvQuant, oversub: f64) -> (KvManager, u64) {
        let hw = HwConfig::default();
        let m = ModelConfig::tiny();
        let mut cfg = KvArenaConfig::for_pool(&hw, &m, quant, Some(pages));
        cfg.admit_oversub = oversub;
        let per_token = GbBudget::kv_cache_bytes_quant(&m, 1, 1, quant);
        (KvManager::new(&hw, &m, cfg), per_token)
    }

    #[test]
    fn register_evict_lru_and_charge_swap_on_rejoin() {
        // 4 × 2 KiB pages; tiny @ fp16 is 512 B/token, so an 8-token stream
        // owns 2 pages and the arena fits exactly two streams.
        let (mgr, per_token) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        assert_eq!(per_token, 512);
        mgr.register(1, 8, None);
        mgr.register(2, 8, None);
        assert_eq!(mgr.used_pages(), 4);
        // A third stream evicts the LRU (stream 1) — parked KV is never
        // free: it must be evicted, not forgotten.
        mgr.register(3, 8, None);
        assert_eq!(mgr.used_pages(), 4);
        assert_eq!(mgr.stats().evictions, 1);
        // Stream 1 rejoins a step: swap-in charged for its whole KV, and
        // room is made by evicting the next LRU (stream 2).
        let c = mgr.prepare_group(&[(1, 8)]);
        assert_eq!(c.swap_ins, 1);
        assert_eq!(c.swap_in_bytes, 8 * per_token);
        assert_eq!(mgr.stats().evictions, 2);
        assert_eq!(mgr.stats().peak_used_pages, 4, "residency cap held throughout");
        // Resident members never pay again.
        let c2 = mgr.prepare_group(&[(1, 9)]);
        assert_eq!(c2.swap_ins, 0);
        for id in [1, 2, 3] {
            mgr.release(id);
        }
        assert_eq!(mgr.used_pages(), 0);
        assert_eq!(mgr.live_streams(), 0);
    }

    #[test]
    fn group_members_protected_from_each_other() {
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        mgr.register(1, 8, None);
        mgr.register(2, 8, None); // arena exactly full with both
        let c = mgr.prepare_group(&[(1, 8), (2, 8)]);
        assert_eq!(c.swap_ins, 0, "both resident, neither may evict the other");
        assert_eq!(mgr.stats().evictions, 0);
    }

    #[test]
    fn pinned_in_flight_groups_are_never_evicted() {
        // Two workers decoding concurrently over one shared arena: a
        // group's pages must survive another worker's room-making for the
        // whole step — overcommit is counted instead of a spurious evict.
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        mgr.register(1, 8, None);
        mgr.register(2, 8, None); // arena full
        let _ = mgr.prepare_group(&[(1, 8)]); // worker A: stream 1 in flight
        let _ = mgr.prepare_group(&[(3, 8)]); // worker B: evicts parked 2, not pinned 1
        assert_eq!(mgr.stats().evictions, 1);
        // Stream 2 rejoins while 1 and 3 are both pinned: no victims —
        // forced overcommit, never an eviction of an in-flight group.
        let c = mgr.prepare_group(&[(2, 8)]);
        assert_eq!(c.swap_ins, 1);
        assert_eq!(mgr.stats().evictions, 1);
        assert!(mgr.stats().forced_overcommit >= 1);
        // Once worker A's step finishes, its stream parks and is evictable.
        mgr.finish_group(&[(1, 8)]);
        let _ = mgr.prepare_group(&[(4, 8)]);
        assert_eq!(mgr.stats().evictions, 2, "unpinned stream evictable again");
    }

    #[test]
    fn admission_bounds_projected_bytes() {
        // 4 pages = 8 KiB at oversub 1.0; each stream projects 8 tokens
        // (4 prefill + 4 generate) × 512 B = 4 KiB.
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 1.0);
        assert!(mgr.try_admit(1, 4, 4, 4, None));
        assert!(mgr.try_admit(2, 4, 4, 4, None), "exactly at the bound still admits");
        assert!(!mgr.try_admit(3, 4, 4, 4, None), "past the bound rejects");
        assert_eq!(mgr.stats().admit_rejected, 1);
        mgr.release(1);
        assert!(mgr.try_admit(3, 4, 4, 4, None), "released reservations free the bound");
        // A live id can't be admitted twice — overwriting would orphan the
        // first stream's pages and reservation forever.
        assert!(!mgr.try_admit(3, 4, 4, 4, None), "duplicate live id refused");
        mgr.release(3);
        assert!(mgr.try_admit(3, 4, 4, 4, None), "released id is reusable");
        // Projections clamp at the *class's* residency cap: an absurd ask
        // does not project bytes the engine will never allow, and a wide
        // class clamps tighter than a solo stream.
        let (mgr2, per_token) = tiny_mgr(1 << 16, KvQuant::Fp16, 1.0);
        assert!(mgr2.try_admit(7, 4, usize::MAX / 2, 1, None));
        let hw = HwConfig::default();
        let m = ModelConfig::tiny();
        let cap_b1 = GbBudget::max_decode_len_quant(&hw, &m, 1, KvQuant::Fp16);
        let cap_b4 = GbBudget::max_decode_len_quant(&hw, &m, 4, KvQuant::Fp16);
        assert!(cap_b4 < cap_b1);
        assert_eq!(mgr2.cap_for_width(1), cap_b1);
        assert_eq!(mgr2.cap_for_width(4), cap_b4);
        {
            let g = mgr2.inner.lock().unwrap();
            assert_eq!(g.admitted_bytes, cap_b1 as u64 * per_token);
        }
        assert!(mgr2.try_admit(8, 4, usize::MAX / 2, 4, None));
        let g = mgr2.inner.lock().unwrap();
        assert_eq!(g.admitted_bytes, (cap_b1 + cap_b4) as u64 * per_token);
    }

    #[test]
    fn oversized_group_overcommits_instead_of_deadlocking() {
        let (mgr, _) = tiny_mgr(1, KvQuant::Fp16, 8.0);
        mgr.register(1, 100, None); // 50 KiB into a 2 KiB arena
        assert!(mgr.stats().forced_overcommit >= 1);
        assert!(mgr.used_pages() > 1);
        mgr.release(1);
        assert_eq!(mgr.used_pages(), 0);
    }

    #[test]
    fn residual_tracks_holdings_and_is_clean_after_drain() {
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 8.0);
        assert!(mgr.residual().is_clean(), "fresh manager holds nothing");
        assert!(mgr.try_admit(1, 4, 4, 1, None));
        let r = mgr.residual();
        assert_eq!(r.live_streams, 1);
        assert!(r.admitted_bytes > 0, "admission reserves projection bytes");
        assert!(!r.is_clean());
        mgr.register(1, 8, None);
        let _ = mgr.prepare_group(&[(1, 8)]);
        let pinned = mgr.residual();
        assert_eq!(pinned.pinned_streams, 1, "in-flight group pins its member");
        assert!(pinned.resident_pages > 0);
        mgr.finish_group(&[(1, 8)]);
        assert_eq!(mgr.residual().pinned_streams, 0, "parked after the step");
        assert!(mgr.residual().resident_pages > 0, "parked keeps pages");
        mgr.release(1);
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn prefix_mates_share_one_physical_prefix() {
        use crate::kv::radix::prefix_id;
        // tiny @ fp16: 512 B/token, 2 KiB pages, no cross-attention. An
        // 8-token prefix is exactly 2 pages.
        let (mgr, _) = tiny_mgr(64, KvQuant::Fp16, 8.0);
        let g = prefix_id("sys");
        for id in 0..8 {
            mgr.register(id, 8, Some(g));
        }
        // One shared copy + 8 one-page private floors: ~O(unique tokens),
        // not O(streams).
        assert_eq!(mgr.shared_pages(), 2);
        assert_eq!(mgr.used_pages(), 2 + 8);
        assert_eq!(mgr.stats().prefix_hits, 7, "every mate after the first is warm");
        // No-share baseline: the same fleet pays 8 full copies.
        let (base, _) = tiny_mgr(64, KvQuant::Fp16, 8.0);
        for id in 0..8 {
            base.register(id, 8, None);
        }
        assert_eq!(base.used_pages(), 16);
        // Shared pages free only when the LAST mate releases.
        for id in 0..7 {
            mgr.release(id);
        }
        assert_eq!(mgr.shared_pages(), 2, "one mate still pins the chain");
        mgr.release(7);
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn cow_forks_only_past_an_unaligned_prefix() {
        use crate::kv::radix::prefix_id;
        let (mgr, per_token) = tiny_mgr(64, KvQuant::Fp16, 8.0);
        let g = prefix_id("sys");
        // 5-token prefix = 2560 B: 1.25 pages — the boundary is unaligned.
        mgr.register(1, 5, Some(g));
        mgr.register(2, 5, Some(g));
        assert_eq!(mgr.shared_pages(), 2);
        // Depth 5 hasn't outgrown the prefix: no fork yet.
        let c = mgr.prepare_group(&[(1, 5)]);
        assert_eq!(c.swap_ins, 0);
        mgr.finish_group(&[(1, 5)]);
        assert_eq!(mgr.stats().cow_forks, 0);
        // Depth 6 outgrows it: stream 1 forks; its private bytes cover the
        // duplicated fragment + the new token (6×512 − floor_page(2560)).
        let _ = mgr.prepare_group(&[(1, 6)]);
        mgr.finish_group(&[(1, 6)]);
        assert_eq!(mgr.stats().cow_forks, 1);
        let fragment_and_token = 6 * per_token - 2048;
        assert_eq!(fragment_and_token, 1024);
        // Stream 2 hasn't diverged; the chain is untouched by the fork.
        assert_eq!(mgr.shared_pages(), 2);
        // A page-aligned prefix appends in place and never forks.
        let (mgr2, _) = tiny_mgr(64, KvQuant::Fp16, 8.0);
        mgr2.register(3, 8, Some(prefix_id("aligned"))); // 4096 B = 2 pages
        let _ = mgr2.prepare_group(&[(3, 12)]);
        mgr2.finish_group(&[(3, 12)]);
        assert_eq!(mgr2.stats().cow_forks, 0);
    }

    #[test]
    fn evicted_prefix_mate_swaps_in_private_bytes_only() {
        use crate::kv::radix::prefix_id;
        // 8 pages: shared prefix (2) + both mates' tails can't all fit
        // once the tails grow — the tails churn, the chain never does.
        let (mgr, per_token) = tiny_mgr(8, KvQuant::Fp16, 16.0);
        let g = prefix_id("sys");
        mgr.register(1, 8, Some(g)); // 2 shared pages + 1 private floor
        mgr.register(2, 8, Some(g)); // + 1 private floor
        assert_eq!(mgr.used_pages(), 4);
        // Stream 2 decodes to depth 16: its private tail is (16−8)×512 =
        // 2 pages. Then stream 1's tail grows until stream 2 is evicted.
        let _ = mgr.prepare_group(&[(2, 16)]);
        mgr.finish_group(&[(2, 16)]);
        let _ = mgr.prepare_group(&[(1, 24)]);
        mgr.finish_group(&[(1, 24)]);
        let _ = mgr.prepare_group(&[(1, 26)]);
        mgr.finish_group(&[(1, 26)]);
        assert!(mgr.stats().evictions >= 1, "{:?}", mgr.stats());
        // Stream 2 rejoins at its parked depth: swap-in covers its PRIVATE
        // tail only — the 8-token shared prefix stayed resident throughout.
        let c = mgr.prepare_group(&[(2, 16)]);
        assert_eq!(c.swap_ins, 1);
        assert_eq!(c.swap_in_bytes, (16 - 8) * per_token);
        assert_eq!(mgr.shared_pages(), 2, "the chain never evicts");
        mgr.finish_group(&[(2, 16)]);
        mgr.release(1);
        mgr.release(2);
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn warm_prefix_admission_projects_private_bytes_only() {
        use crate::kv::radix::prefix_id;
        // 4 pages = 8 KiB at oversub 1.0; a full stream projects 8 tokens
        // (4 prefill + 4 generate) × 512 B = 4 KiB, so only 2 cold streams
        // fit — but warm prefix-mates discount the resident 2 KiB prompt.
        let (mgr, _) = tiny_mgr(4, KvQuant::Fp16, 1.0);
        let g = prefix_id("sys");
        assert!(mgr.try_admit(1, 4, 4, 4, Some(g)), "cold: projects full bytes");
        mgr.register(1, 4, Some(g));
        assert!(mgr.try_admit(2, 4, 4, 4, Some(g)));
        assert!(mgr.try_admit(3, 4, 4, 4, Some(g)), "warm mates project tails only");
        assert!(!mgr.try_admit(4, 4, 4, 4, Some(g)), "the bound still binds");
        // Without the prefix the third stream would have been refused
        // (`admission_bounds_projected_bytes` pins that baseline).
        for id in 1..=3 {
            mgr.release(id);
        }
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn compactor_reclaims_ceil_rounding_slack() {
        let (mgr, _) = tiny_mgr(64, KvQuant::Fp16, 8.0);
        // Three parked 5-token streams: 2560 B each rounds to 2 pages (6
        // total), but packed end-to-end 7680 B needs only 4.
        for id in 0..3 {
            mgr.register(id, 5, None);
        }
        assert_eq!(mgr.used_pages(), 6);
        assert_eq!(mgr.compact(), 2);
        assert_eq!(mgr.used_pages(), 4);
        assert_eq!(mgr.stats().compacted_pages, 2);
        // A compacted stream is still resident: rejoining charges no
        // swap-in and re-grows its page count in place.
        let c = mgr.prepare_group(&[(0, 5)]);
        assert_eq!(c.swap_ins, 0);
        mgr.finish_group(&[(0, 5)]);
        for id in 0..3 {
            mgr.release(id);
        }
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn double_release_of_a_prefix_mate_is_harmless() {
        use crate::kv::radix::prefix_id;
        // A mid-prefill shed racing the normal release path calls
        // `release` twice for one id; the second must be a no-op, never a
        // double-free of the shared chain.
        let (mgr, _) = tiny_mgr(16, KvQuant::Fp16, 8.0);
        let g = prefix_id("sys");
        mgr.register(1, 8, Some(g));
        mgr.register(2, 8, Some(g));
        mgr.release(1);
        mgr.release(1);
        assert_eq!(mgr.shared_pages(), 2, "mate 2 still pins the chain");
        assert_eq!(mgr.stats().released, 1, "second release found nothing");
        mgr.release(2);
        mgr.release(2);
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn cold_chain_reclaims_before_evicting_live_streams() {
        use crate::kv::radix::prefix_id;
        // 6 pages. Mates 1,2 share a 2-page chain (8-token prompt = 4 KiB)
        // plus a 1-page private floor each; stream 3 owns 1 page. Releasing
        // both mates leaves the chain *cold* (2 pages, zero refs) — a new
        // 4-page stream must reclaim it instead of evicting stream 3.
        let (mgr, _) = tiny_mgr(6, KvQuant::Fp16, 16.0);
        let g = prefix_id("sys");
        mgr.register(1, 8, Some(g));
        mgr.register(2, 8, Some(g));
        mgr.register(3, 4, None);
        assert_eq!(mgr.used_pages(), 5);
        mgr.release(1);
        mgr.release(2);
        // The chain is cold but retained (stream 3 keeps the pool live).
        assert_eq!(mgr.shared_pages(), 2, "cold chain still resident");
        mgr.register(4, 16, None); // needs 4 pages; only 3 are free
        assert_eq!(mgr.stats().evictions, 0, "cold pages covered the shortfall");
        assert_eq!(mgr.stats().cold_reclaimed_pages, 2);
        assert_eq!(mgr.shared_pages(), 0);
        let c = mgr.prepare_group(&[(3, 4)]);
        assert_eq!(c.swap_ins, 0, "live stream 3 was never touched");
        mgr.finish_group(&[(3, 4)]);
        mgr.release(3);
        mgr.release(4);
        assert!(mgr.residual().is_clean(), "{:?}", mgr.residual());
    }

    #[test]
    fn migration_moves_private_and_chain_once() {
        use crate::kv::radix::prefix_id;
        // Two chips. Mates 1,2 prefill an 8-token shared prompt on chip A
        // (2 KiB pages → the 4 KiB chain spans 2 pages; private bytes 0).
        let (src, per_token) = tiny_mgr(16, KvQuant::Fp16, 8.0);
        let (dst, _) = tiny_mgr(16, KvQuant::Fp16, 8.0);
        let g = prefix_id("sys");
        src.register(1, 8, Some(g));
        src.register(2, 8, Some(g));

        // First mate lands on chip B: its private KV plus the whole chain
        // stream over — exactly one chain charge.
        let m1 = src.migrate_out(1).expect("stream 1 held on src");
        assert_eq!(m1.shared_bytes, 8 * per_token);
        let moved1 = dst.migrate_in(1, &m1);
        assert_eq!(moved1, m1.private_bytes + 8 * per_token);
        assert_eq!(dst.stats().chain_migrations, 1);

        // Second mate follows: the chain is already resident on B, so only
        // its private bytes move — the chain is charged once per chain,
        // not once per mate.
        let m2 = src.migrate_out(2).expect("stream 2 held on src");
        let moved2 = dst.migrate_in(2, &m2);
        assert_eq!(moved2, m2.private_bytes);
        assert_eq!(dst.stats().chain_migrations, 1, "chain charged exactly once");
        assert_eq!(dst.stats().prefix_hits, 1, "mate 2 attached warm");
        assert_eq!(dst.stats().migrations, 2);
        assert_eq!(dst.shared_pages(), 2);

        // Chip A drained with the last mate: its cold chain was purged.
        assert!(src.residual().is_clean(), "{:?}", src.residual());
        // Migrating a stream nobody holds (already shed) is a no-op.
        assert!(src.migrate_out(1).is_none());

        dst.release(1);
        dst.release(2);
        assert!(dst.residual().is_clean(), "{:?}", dst.residual());
    }

    #[test]
    fn quantization_scales_stream_bytes_and_dequant() {
        let hw = HwConfig::default();
        let m = ModelConfig::s2t_small();
        let mk = |q| KvManager::new(&hw, &m, KvArenaConfig::for_pool(&hw, &m, q, None));
        let f16 = mk(KvQuant::Fp16);
        let i8_ = mk(KvQuant::Int8);
        let i4 = mk(KvQuant::Int4);
        assert_eq!(f16.stream_bytes(32), 2 * i8_.stream_bytes(32));
        assert_eq!(f16.stream_bytes(32), 4 * i4.stream_bytes(32));
        // Dequant: zero at full precision, per-layer share of the padded
        // group below it.
        assert_eq!(f16.dequant_bytes_per_layer(4, 32), 0);
        let layers = m.dec_layers as u64;
        assert_eq!(i8_.dequant_bytes_per_layer(4, 32), 4 * i8_.stream_bytes(32) / layers);
        assert!(i4.dequant_bytes_per_layer(4, 32) < i8_.dequant_bytes_per_layer(4, 32));
    }
}
