//! Fixed-size page accounting for the global-buffer KV arena.
//!
//! The arena is a byte-capacity carved out of the GB (everything left after
//! W_S, the W_D slot(s) and the activation planes) and divided into
//! fixed-size pages. Streams are allocated whole pages, so a stream's
//! footprint is `ceil(kv_bytes / page_bytes)` — the page granularity is what
//! makes eviction and swap-in O(1) bookkeeping instead of a byte-range
//! compactor. Policy (who to evict, when to refuse) lives in
//! [`super::manager::KvManager`]; this type only counts pages, and it
//! deliberately *allows* `used > capacity` so the manager can choose forced
//! overcommit over deadlock (recorded in its stats).

/// Page-granular occupancy counter for the KV arena.
///
/// Pages come in two flavors with one occupancy total: **private** pages
/// back exactly one stream (alloc/free), **shared** pages back a
/// refcounted prefix chain (`alloc_shared`/`free_shared`) and are tracked
/// separately so the drained-pool invariant can demand both gauges hit
/// zero. Refcounting itself lives in [`super::radix::RadixIndex`]; the
/// arena only guards the counters — shared frees saturate and
/// `debug_assert` rather than underflow when a shed races a prefix-mate's
/// release.
#[derive(Debug, Clone, Copy)]
pub struct KvArena {
    page_bytes: u64,
    capacity_pages: usize,
    used_pages: usize,
    shared_pages: usize,
}

impl KvArena {
    pub fn new(page_bytes: u64, capacity_pages: usize) -> KvArena {
        KvArena { page_bytes: page_bytes.max(1), capacity_pages, used_pages: 0, shared_pages: 0 }
    }

    /// Pages needed to back `bytes` of KV (at least one for a live stream).
    pub fn pages_for(&self, bytes: u64) -> usize {
        (bytes.div_ceil(self.page_bytes) as usize).max(1)
    }

    /// Claim `pages` (the manager has already made room — or has chosen
    /// forced overcommit, which this accounting permits).
    pub fn alloc(&mut self, pages: usize) {
        self.used_pages += pages;
    }

    pub fn free(&mut self, pages: usize) {
        self.used_pages = self.used_pages.saturating_sub(pages);
    }

    /// Claim `pages` for a refcounted prefix chain (counted in both the
    /// occupancy total and the shared gauge).
    pub fn alloc_shared(&mut self, pages: usize) {
        self.used_pages += pages;
        self.shared_pages += pages;
    }

    /// Return prefix-chain pages whose last reference dropped. Saturates
    /// (and `debug_assert`s) instead of double-freeing: a mid-prefill shed
    /// racing a prefix-mate's release must never drive either gauge
    /// negative.
    pub fn free_shared(&mut self, pages: usize) {
        debug_assert!(
            pages <= self.shared_pages,
            "shared free of {pages} pages exceeds the {} shared-resident",
            self.shared_pages
        );
        self.used_pages = self.used_pages.saturating_sub(pages);
        self.shared_pages = self.shared_pages.saturating_sub(pages);
    }

    /// Pages currently backing refcounted prefix chains.
    pub fn shared_pages(&self) -> usize {
        self.shared_pages
    }

    pub fn free_pages(&self) -> usize {
        self.capacity_pages.saturating_sub(self.used_pages)
    }

    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.page_bytes * self.capacity_pages as u64
    }

    pub fn occupancy(&self) -> f64 {
        if self.capacity_pages == 0 {
            return 0.0;
        }
        self.used_pages as f64 / self.capacity_pages as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_math() {
        let a = KvArena::new(2048, 16);
        assert_eq!(a.pages_for(1), 1);
        assert_eq!(a.pages_for(2048), 1);
        assert_eq!(a.pages_for(2049), 2);
        assert_eq!(a.pages_for(0), 1, "a live stream owns at least one page");
        assert_eq!(a.capacity_bytes(), 32768);
    }

    #[test]
    fn alloc_free_and_overcommit() {
        let mut a = KvArena::new(2048, 4);
        a.alloc(3);
        assert_eq!(a.free_pages(), 1);
        a.alloc(3); // forced overcommit is the manager's call; counting allows it
        assert_eq!(a.used_pages(), 6);
        assert_eq!(a.free_pages(), 0);
        a.free(6);
        assert_eq!(a.used_pages(), 0);
        a.free(1); // saturates, never underflows
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn shared_pages_track_separately_and_saturate() {
        let mut a = KvArena::new(2048, 8);
        a.alloc(2);
        a.alloc_shared(3);
        assert_eq!(a.used_pages(), 5);
        assert_eq!(a.shared_pages(), 3);
        assert_eq!(a.free_pages(), 3);
        a.free_shared(3);
        assert_eq!(a.used_pages(), 2);
        assert_eq!(a.shared_pages(), 0);
        a.free(2);
        assert_eq!(a.used_pages(), 0);
    }
}
