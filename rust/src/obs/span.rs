//! The flight recorder: a fixed-capacity, lock-minimal ring of lifecycle
//! span events.
//!
//! Every stage a request passes through — admit, queue residency, prefill
//! (chunk by chunk), each decode step, KV swap/evict/COW-fork, completion
//! or shed — is one fixed-size [`SpanEvent`], written into a per-lane ring
//! buffer that keeps the **last** `capacity` events per lane (old events
//! are overwritten, never reallocated: steady-state recording allocates
//! nothing). Each worker thread writes its own lane, so the only
//! contention is a short per-lane mutex shared with the occasional
//! snapshot; nothing in the pool ever blocks on another writer's lane.
//!
//! Tracing is **off by default**: the pool carries an
//! `Option<Arc<FlightRecorder>>` and every record site is a branch on
//! `None` — the disabled hot path adds no locks and no allocations (the
//! `hotpath_micro` bench gates this in CI).

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// What a span covers. Duration spans (`Queue`/`Prefill`/`PrefillChunk`/
/// `DecodeStep`) tile a request's lifetime — per request they sum to the
/// reported e2e latency; marker spans (`Admit`, the KV events,
/// `Complete`/`Shed`/`DoorShed`) are zero-duration instants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Request accepted at the door (marker, admit lane).
    Admit,
    /// Request rejected at the door (marker, admit lane).
    DoorShed,
    /// Arrival → the instant an engine began serving the request's batch
    /// (batcher + work-queue residency).
    Queue,
    /// Serve start → prefill finished (covers every chunk and any parked
    /// gaps; `group` = chunks executed).
    Prefill,
    /// One executed prefill chunk (worker-lane detail, batch-scoped:
    /// `id` = 0, `group` = chunk index).
    PrefillChunk,
    /// End of the stream's previous span → this decode step's completion
    /// (includes the between-step queue residency, so steps tile).
    DecodeStep,
    /// KV pages for a stream re-streamed into the arena (marker).
    KvSwap,
    /// A victim stream's KV pages evicted (marker; `id` = victim).
    KvEvict,
    /// A shared KV prefix copy-on-write-forked at divergence (marker).
    KvCowFork,
    /// KV pages migrated between chips' arenas (marker; fleet mode —
    /// `ema_bytes` carries the priced transfer).
    KvMigrate,
    /// A chip's DVFS governor re-pointed its operating point (marker,
    /// admit lane; `id`/`group` = chip, `chip_us` = old VDD, `chip_uj` =
    /// new VDD).
    DvfsRepoint,
    /// Response built (marker; terminal).
    Complete,
    /// Admitted request shed post-admission (marker; terminal).
    Shed,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::DoorShed => "door_shed",
            SpanKind::Queue => "queue",
            SpanKind::Prefill => "prefill",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::KvSwap => "kv_swap",
            SpanKind::KvEvict => "kv_evict",
            SpanKind::KvCowFork => "kv_cow_fork",
            SpanKind::KvMigrate => "kv_migrate",
            SpanKind::DvfsRepoint => "dvfs_repoint",
            SpanKind::Complete => "complete",
            SpanKind::Shed => "shed",
        }
    }

    pub fn from_name(name: &str) -> Option<SpanKind> {
        Some(match name {
            "admit" => SpanKind::Admit,
            "door_shed" => SpanKind::DoorShed,
            "queue" => SpanKind::Queue,
            "prefill" => SpanKind::Prefill,
            "prefill_chunk" => SpanKind::PrefillChunk,
            "decode_step" => SpanKind::DecodeStep,
            "kv_swap" => SpanKind::KvSwap,
            "kv_evict" => SpanKind::KvEvict,
            "kv_cow_fork" => SpanKind::KvCowFork,
            "kv_migrate" => SpanKind::KvMigrate,
            "dvfs_repoint" => SpanKind::DvfsRepoint,
            "complete" => SpanKind::Complete,
            "shed" => SpanKind::Shed,
            _ => return None,
        })
    }

    /// True for the per-request lifecycle spans that appear on a stream's
    /// track and participate in the spans-sum-to-e2e invariant.
    pub fn is_lifecycle(self) -> bool {
        matches!(
            self,
            SpanKind::Queue | SpanKind::Prefill | SpanKind::DecodeStep | SpanKind::Complete
        )
    }
}

/// One recorded event. Fixed-size and `Copy` — recording is a struct store
/// into a preallocated ring slot, never an allocation.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    /// Request id (0 for batch- or arena-scoped events).
    pub id: u64,
    pub kind: SpanKind,
    /// Writer lane (worker index; service lanes above the workers).
    pub lane: u32,
    /// Wall-clock µs since the recorder's epoch.
    pub t_start_us: f64,
    pub t_end_us: f64,
    /// Sim-clock µs attributed to this span (per token for decode steps).
    pub chip_us: f64,
    /// Energy attributed to this span, µJ (per token for decode steps).
    pub chip_uj: f64,
    /// External-memory bytes the span moved (per token for decode steps).
    pub ema_bytes: u64,
    /// KV share of `ema_bytes` (swap-in re-streams + dequant passes).
    pub ema_kv_bytes: u64,
    /// KV depth at the span (decode) or prompt length (prefill).
    pub past_len: u32,
    /// Group width (decode), chunk count/index (prefill), or 0.
    pub group: u32,
}

impl SpanEvent {
    /// A zero-duration marker at `t_us`.
    pub fn marker(kind: SpanKind, id: u64, t_us: f64) -> SpanEvent {
        SpanEvent {
            id,
            kind,
            lane: 0,
            t_start_us: t_us,
            t_end_us: t_us,
            chip_us: 0.0,
            chip_uj: 0.0,
            ema_bytes: 0,
            ema_kv_bytes: 0,
            past_len: 0,
            group: 0,
        }
    }

    pub fn dur_us(&self) -> f64 {
        (self.t_end_us - self.t_start_us).max(0.0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("kind", Json::str(self.kind.name())),
            ("lane", Json::num(self.lane as f64)),
            ("ts_us", Json::num(self.t_start_us)),
            ("dur_us", Json::num(self.dur_us())),
            ("chip_us", Json::num(self.chip_us)),
            ("chip_uj", Json::num(self.chip_uj)),
            ("ema_bytes", Json::num(self.ema_bytes as f64)),
            ("ema_kv_bytes", Json::num(self.ema_kv_bytes as f64)),
            ("past_len", Json::num(self.past_len as f64)),
            ("group", Json::num(self.group as f64)),
        ])
    }
}

/// One writer's ring: keeps the last `cap` events in arrival order.
#[derive(Debug)]
struct Lane {
    buf: Vec<SpanEvent>,
    /// Next slot to overwrite once the ring is full.
    next: usize,
    /// Total events ever written to this lane.
    written: u64,
}

impl Lane {
    fn push(&mut self, ev: SpanEvent, cap: usize) {
        if self.buf.len() < cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % cap;
        }
        self.written += 1;
    }

    fn snapshot_into(&self, out: &mut Vec<SpanEvent>) {
        out.extend_from_slice(&self.buf[self.next..]);
        out.extend_from_slice(&self.buf[..self.next]);
    }
}

/// Fixed-capacity multi-lane span ring — the flight recorder.
///
/// Lane convention for a serving pool ([`FlightRecorder::for_pool`]):
/// lanes `0..workers` belong to the engine workers, lane `workers` to the
/// admission door, lane `workers + 1` to the KV arena. Any lane index is
/// accepted (clamped by modulo), so writers never have to bounds-check.
#[derive(Debug)]
pub struct FlightRecorder {
    epoch: Instant,
    cap: usize,
    lanes: Vec<Mutex<Lane>>,
}

/// Default events retained per lane ("last N-thousand events").
pub const DEFAULT_LANE_CAPACITY: usize = 16 * 1024;

impl FlightRecorder {
    pub fn new(lanes: usize, capacity_per_lane: usize) -> FlightRecorder {
        let cap = capacity_per_lane.max(16);
        FlightRecorder {
            epoch: Instant::now(),
            cap,
            lanes: (0..lanes.max(1))
                .map(|_| Mutex::new(Lane { buf: Vec::with_capacity(cap), next: 0, written: 0 }))
                .collect(),
        }
    }

    /// Recorder shaped for an `n_workers`-worker pool: one lane per worker
    /// plus the admission and KV service lanes.
    pub fn for_pool(n_workers: usize, capacity_per_lane: usize) -> FlightRecorder {
        FlightRecorder::new(n_workers.max(1) + 2, capacity_per_lane)
    }

    /// Admission-door lane index (second to last).
    pub fn admit_lane(&self) -> usize {
        self.lanes.len() - 2
    }

    /// KV-arena lane index (last).
    pub fn kv_lane(&self) -> usize {
        self.lanes.len() - 1
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn capacity_per_lane(&self) -> usize {
        self.cap
    }

    /// Wall-clock µs since the recorder epoch.
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// Record `ev` on `lane` (clamped). One short mutex on the writer's own
    /// lane, one struct store — no allocation once the ring is warm.
    pub fn record(&self, lane: usize, mut ev: SpanEvent) {
        let idx = lane % self.lanes.len();
        ev.lane = idx as u32;
        self.lanes[idx].lock().unwrap().push(ev, self.cap);
    }

    /// Total events ever recorded (including ones the rings have since
    /// overwritten).
    pub fn total_recorded(&self) -> u64 {
        self.lanes.iter().map(|l| l.lock().unwrap().written).sum()
    }

    /// Copy out every retained event, ordered by start time. Non-draining:
    /// the rings keep recording.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for lane in &self.lanes {
            lane.lock().unwrap().snapshot_into(&mut out);
        }
        out.sort_by(|a, b| {
            a.t_start_us.total_cmp(&b.t_start_us).then(a.t_end_us.total_cmp(&b.t_end_us))
        });
        out
    }
}

/// A cloneable handle binding a recorder to one writer's lane. `None`-able
/// at every call site: the pool stores `Option<SpanWriter>` and skips the
/// whole body when tracing is off.
#[derive(Debug, Clone)]
pub struct SpanWriter {
    rec: Arc<FlightRecorder>,
    lane: usize,
}

impl SpanWriter {
    pub fn new(rec: Arc<FlightRecorder>, lane: usize) -> SpanWriter {
        SpanWriter { rec, lane }
    }

    pub fn now_us(&self) -> f64 {
        self.rec.now_us()
    }

    pub fn record(&self, ev: SpanEvent) {
        self.rec.record(self.lane, ev);
    }

    pub fn lane(&self) -> usize {
        self.lane
    }

    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.rec
    }
}

/// Latch used by anomaly detectors (shed-storm sampler, ledger audit) so a
/// sustained anomaly dumps the recorder exactly once.
#[derive(Debug, Default)]
pub struct DumpOnce {
    fired: AtomicU64,
}

impl DumpOnce {
    pub fn new() -> DumpOnce {
        DumpOnce::default()
    }

    /// True exactly once.
    pub fn arm(&self) -> bool {
        self.fired.fetch_add(1, Ordering::Relaxed) == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, t: f64) -> SpanEvent {
        SpanEvent::marker(SpanKind::Admit, id, t)
    }

    #[test]
    fn ring_keeps_the_last_n_events_per_lane() {
        let rec = FlightRecorder::new(1, 16);
        for i in 0..100u64 {
            rec.record(0, ev(i, i as f64));
        }
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 16, "ring holds exactly its capacity");
        assert_eq!(rec.total_recorded(), 100);
        let ids: Vec<u64> = snap.iter().map(|e| e.id).collect();
        let want: Vec<u64> = (84..100).collect();
        assert_eq!(ids, want, "the LAST events survive, in order");
    }

    #[test]
    fn snapshot_merges_lanes_in_time_order() {
        let rec = FlightRecorder::for_pool(2, 64);
        assert_eq!(rec.lanes(), 4);
        assert_eq!(rec.admit_lane(), 2);
        assert_eq!(rec.kv_lane(), 3);
        rec.record(1, ev(10, 5.0));
        rec.record(0, ev(11, 1.0));
        rec.record(rec.admit_lane(), ev(12, 3.0));
        let snap = rec.snapshot();
        let ts: Vec<f64> = snap.iter().map(|e| e.t_start_us).collect();
        assert_eq!(ts, vec![1.0, 3.0, 5.0]);
        assert_eq!(snap[1].lane, 2, "record stamps the clamped lane index");
    }

    #[test]
    fn writer_binds_a_lane_and_markers_are_zero_duration() {
        let rec = Arc::new(FlightRecorder::new(3, 32));
        let w = SpanWriter::new(Arc::clone(&rec), 2);
        let t = w.now_us();
        w.record(SpanEvent::marker(SpanKind::Complete, 7, t));
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].lane, 2);
        assert_eq!(snap[0].dur_us(), 0.0);
        assert_eq!(snap[0].kind, SpanKind::Complete);
    }

    #[test]
    fn kind_names_round_trip() {
        for k in [
            SpanKind::Admit,
            SpanKind::DoorShed,
            SpanKind::Queue,
            SpanKind::Prefill,
            SpanKind::PrefillChunk,
            SpanKind::DecodeStep,
            SpanKind::KvSwap,
            SpanKind::KvEvict,
            SpanKind::KvCowFork,
            SpanKind::KvMigrate,
            SpanKind::DvfsRepoint,
            SpanKind::Complete,
            SpanKind::Shed,
        ] {
            assert_eq!(SpanKind::from_name(k.name()), Some(k));
        }
        assert_eq!(SpanKind::from_name("nope"), None);
    }

    #[test]
    fn dump_once_latches() {
        let d = DumpOnce::new();
        assert!(d.arm());
        assert!(!d.arm());
        assert!(!d.arm());
    }
}
