//! Time-series telemetry: periodic pool snapshots and the bucketed shed
//! timeline.
//!
//! A sampler thread (spawned by the pool when
//! [`crate::coordinator::PoolConfig::telemetry`] is set) captures one
//! [`Snapshot`] per interval — queue depth, in-flight count, KV occupancy
//! and sharing, interleave ratio, coalesce wait, us/µJ-per-token
//! percentiles — into a bounded in-memory ring ([`Telemetry`]) and,
//! optionally, an append-only JSONL stream. The same thread watches for
//! **shed storms** (door rejections + execute errors crossing a threshold
//! within one interval) and drains the flight recorder to an anomaly dump
//! when one hits.

use crate::coordinator::REPORT_SCHEMA_VERSION;
use crate::util::json::Json;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

/// Sampler knobs. `Default` samples every 10 ms, retains the last 4096
/// snapshots, and never dumps (storm detection off).
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Sampling period.
    pub interval: Duration,
    /// Snapshots retained in memory (ring; the JSONL stream keeps all).
    pub capacity: usize,
    /// Append every snapshot to this JSONL file.
    pub out: Option<PathBuf>,
    /// Door-sheds + execute-errors within one interval at or above this
    /// count is a shed storm (0 disables detection).
    pub shed_storm_threshold: u64,
    /// Where a shed-storm anomaly dump goes (requires a recorder).
    pub anomaly_dump: Option<PathBuf>,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            interval: Duration::from_millis(10),
            capacity: 4096,
            out: None,
            shed_storm_threshold: 0,
            anomaly_dump: None,
        }
    }
}

/// One periodic observation of the pool.
#[derive(Debug, Clone, Copy, Default)]
pub struct Snapshot {
    /// Wall-clock µs since the pool started.
    pub t_us: f64,
    /// Work items queued (decode pool + parked chunks + fresh batches).
    pub queue_depth: usize,
    /// Admitted requests not yet answered.
    pub inflight: usize,
    pub kv_used_pages: usize,
    pub kv_shared_pages: usize,
    pub kv_live_streams: usize,
    pub completed: u64,
    pub rejected: u64,
    pub execute_errors: u64,
    pub tokens_decoded: u64,
    /// Decode steps that ran between prefill chunks / total decode steps.
    pub interleave_ratio: f64,
    pub coalesce_wait_us_mean: f64,
    pub us_per_token_p50: f64,
    pub us_per_token_p95: f64,
    pub uj_per_token_p50: f64,
    pub uj_per_token_p95: f64,
    /// Decode tokens recorded within THIS sampling interval (the us/µJ
    /// percentiles above are cumulative; these three are one interval
    /// wide — the DVFS governor's observation signal).
    pub interval_tokens: u64,
    pub interval_us_p50: f64,
    pub interval_us_p95: f64,
    /// Cumulative DVFS re-points across all chips (0 with the governor
    /// off or absent).
    pub dvfs_repoints: u64,
    /// The SLO admission gate was shedding generate traffic when this
    /// snapshot was taken.
    pub slo_shedding: bool,
    /// Cumulative generate requests shed at the door by the SLO gate.
    pub slo_door_sheds: u64,
}

impl Snapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(REPORT_SCHEMA_VERSION as f64)),
            ("t_us", Json::num(self.t_us)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("inflight", Json::num(self.inflight as f64)),
            ("kv_used_pages", Json::num(self.kv_used_pages as f64)),
            ("kv_shared_pages", Json::num(self.kv_shared_pages as f64)),
            ("kv_live_streams", Json::num(self.kv_live_streams as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("execute_errors", Json::num(self.execute_errors as f64)),
            ("tokens_decoded", Json::num(self.tokens_decoded as f64)),
            ("interleave_ratio", Json::num(self.interleave_ratio)),
            ("coalesce_wait_us_mean", Json::num(self.coalesce_wait_us_mean)),
            ("us_per_token_p50", Json::num(self.us_per_token_p50)),
            ("us_per_token_p95", Json::num(self.us_per_token_p95)),
            ("uj_per_token_p50", Json::num(self.uj_per_token_p50)),
            ("uj_per_token_p95", Json::num(self.uj_per_token_p95)),
            ("interval_tokens", Json::num(self.interval_tokens as f64)),
            ("interval_us_p50", Json::num(self.interval_us_p50)),
            ("interval_us_p95", Json::num(self.interval_us_p95)),
            ("dvfs_repoints", Json::num(self.dvfs_repoints as f64)),
            ("slo_shedding", Json::num(if self.slo_shedding { 1.0 } else { 0.0 })),
            ("slo_door_sheds", Json::num(self.slo_door_sheds as f64)),
        ])
    }
}

/// Bounded in-memory snapshot ring the sampler fills and reports read.
#[derive(Debug)]
pub struct Telemetry {
    cap: usize,
    inner: Mutex<TelemetryInner>,
}

#[derive(Debug, Default)]
struct TelemetryInner {
    snaps: Vec<Snapshot>,
    next: usize,
    taken: u64,
}

impl Telemetry {
    pub fn new(capacity: usize) -> Telemetry {
        Telemetry { cap: capacity.max(4), inner: Mutex::new(TelemetryInner::default()) }
    }

    pub fn push(&self, s: Snapshot) {
        let mut inner = self.inner.lock().unwrap();
        if inner.snaps.len() < self.cap {
            inner.snaps.push(s);
        } else {
            let slot = inner.next;
            inner.snaps[slot] = s;
            inner.next = (slot + 1) % self.cap;
        }
        inner.taken += 1;
    }

    /// Snapshots taken over the sampler's lifetime (not retained).
    pub fn taken(&self) -> u64 {
        self.inner.lock().unwrap().taken
    }

    /// Retained snapshots in capture order.
    pub fn snapshots(&self) -> Vec<Snapshot> {
        let inner = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(inner.snaps.len());
        out.extend_from_slice(&inner.snaps[inner.next..]);
        out.extend_from_slice(&inner.snaps[..inner.next]);
        out
    }

    /// The most recent snapshot, if any.
    pub fn last(&self) -> Option<Snapshot> {
        let inner = self.inner.lock().unwrap();
        if inner.snaps.is_empty() {
            return None;
        }
        let idx = (inner.next + self.cap - 1) % self.cap;
        Some(if inner.snaps.len() < self.cap {
            *inner.snaps.last().unwrap()
        } else {
            inner.snaps[idx]
        })
    }
}

/// Door- and late-shed counts bucketed over a run's wall span — the shape
/// both the replay summary and `trex inspect` print. Buckets are
/// fixed-width; the last bucket absorbs the closing edge.
#[derive(Debug, Clone, PartialEq)]
pub struct ShedTimeline {
    /// Bucket width, µs.
    pub bucket_us: f64,
    pub door: Vec<u64>,
    pub late: Vec<u64>,
}

impl ShedTimeline {
    /// Timeline spanning `span_us` with `buckets` fixed-width buckets.
    pub fn new(span_us: f64, buckets: usize) -> ShedTimeline {
        let n = buckets.max(1);
        ShedTimeline {
            bucket_us: (span_us.max(1.0)) / n as f64,
            door: vec![0; n],
            late: vec![0; n],
        }
    }

    /// Bucket both series of shed timestamps (µs from run start) over the
    /// maximum observed time.
    pub fn from_instants(door_us: &[f64], late_us: &[f64], buckets: usize) -> ShedTimeline {
        let span = door_us
            .iter()
            .chain(late_us.iter())
            .copied()
            .filter(|t| t.is_finite())
            .fold(0.0f64, f64::max);
        let mut tl = ShedTimeline::new(span, buckets);
        for &t in door_us {
            tl.add_door(t);
        }
        for &t in late_us {
            tl.add_late(t);
        }
        tl
    }

    fn bucket(&self, t_us: f64) -> Option<usize> {
        if !t_us.is_finite() || t_us < 0.0 {
            return None;
        }
        Some(((t_us / self.bucket_us) as usize).min(self.door.len() - 1))
    }

    pub fn add_door(&mut self, t_us: f64) {
        if let Some(i) = self.bucket(t_us) {
            self.door[i] += 1;
        }
    }

    pub fn add_late(&mut self, t_us: f64) {
        if let Some(i) = self.bucket(t_us) {
            self.late[i] += 1;
        }
    }

    pub fn total_door(&self) -> u64 {
        self.door.iter().sum()
    }

    pub fn total_late(&self) -> u64 {
        self.late.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_door() == 0 && self.total_late() == 0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bucket_us", Json::num(self.bucket_us)),
            ("door", Json::Arr(self.door.iter().map(|&c| Json::num(c as f64)).collect())),
            ("late", Json::Arr(self.late.iter().map(|&c| Json::num(c as f64)).collect())),
        ])
    }

    /// Human-readable timeline, one line per non-empty bucket:
    /// `  [  12.0ms ..   24.0ms)  door 17  late 2`.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (i, (&d, &l)) in self.door.iter().zip(self.late.iter()).enumerate() {
            if d == 0 && l == 0 {
                continue;
            }
            let lo = self.bucket_us * i as f64 / 1e3;
            let hi = self.bucket_us * (i + 1) as f64 / 1e3;
            s.push_str(&format!("  [{lo:8.1}ms .. {hi:8.1}ms)  door {d:<6} late {l}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn telemetry_ring_keeps_last_snapshots_in_order() {
        let t = Telemetry::new(4);
        for i in 0..10 {
            t.push(Snapshot { t_us: i as f64, ..Snapshot::default() });
        }
        assert_eq!(t.taken(), 10);
        let snaps = t.snapshots();
        let ts: Vec<f64> = snaps.iter().map(|s| s.t_us).collect();
        assert_eq!(ts, vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(t.last().unwrap().t_us, 9.0);
    }

    #[test]
    fn snapshot_json_has_schema_version() {
        let j = Snapshot::default().to_json();
        assert_eq!(
            j.get("schema_version").unwrap().as_u64().unwrap(),
            REPORT_SCHEMA_VERSION
        );
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn snapshot_json_carries_control_plane_fields() {
        let s = Snapshot {
            interval_tokens: 42,
            interval_us_p50: 100.0,
            interval_us_p95: 250.0,
            dvfs_repoints: 3,
            slo_shedding: true,
            slo_door_sheds: 7,
            ..Snapshot::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("interval_tokens").unwrap().as_u64().unwrap(), 42);
        assert_eq!(j.get("interval_us_p95").unwrap().as_f64().unwrap(), 250.0);
        assert_eq!(j.get("dvfs_repoints").unwrap().as_u64().unwrap(), 3);
        assert_eq!(j.get("slo_shedding").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("slo_door_sheds").unwrap().as_u64().unwrap(), 7);
        // Off/absent control plane: the defaults serialize as zeros — pure
        // additions, schema version unchanged.
        let d = Snapshot::default().to_json();
        assert_eq!(d.get("dvfs_repoints").unwrap().as_u64().unwrap(), 0);
        assert_eq!(d.get("slo_shedding").unwrap().as_u64().unwrap(), 0);
    }

    #[test]
    fn shed_timeline_buckets_both_series() {
        let door = [0.0, 10.0, 95.0, 99.0];
        let late = [50.0];
        let tl = ShedTimeline::from_instants(&door, &late, 10);
        assert_eq!(tl.total_door(), 4);
        assert_eq!(tl.total_late(), 1);
        assert!((tl.bucket_us - 9.9).abs() < 1e-9);
        assert_eq!(tl.door[0], 2, "0 and 10µs land in the first bucket");
        assert_eq!(tl.door[9], 2, "the closing edge lands in the last bucket");
        assert_eq!(tl.late[5], 1);
        let rendered = tl.render();
        assert!(rendered.contains("door 2"), "render shows counts: {rendered}");
        // Empty timelines render to nothing and know they're empty.
        assert!(ShedTimeline::new(100.0, 4).is_empty());
        assert_eq!(ShedTimeline::new(100.0, 4).render(), "");
    }
}
