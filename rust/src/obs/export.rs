//! Span exporters: Chrome `trace_event` JSON (loadable in Perfetto /
//! `chrome://tracing`) and a line-oriented JSONL stream, plus the anomaly
//! dump format the fuzzer and the ledger audit write on failure.
//!
//! The Chrome export lays the same spans out on two process tracks:
//!
//! * **pid 1 "pool workers"** — one thread track per recorder lane
//!   (worker 0..N, then the admission and KV service lanes): the
//!   execution view, where interleaving and idle gaps are visible.
//! * **pid 2 "streams"** — one thread track per request id, carrying only
//!   the lifecycle spans (queue → prefill → decode steps → terminal
//!   marker): the per-request view, where each stream's spans tile its
//!   e2e latency end to end.
//!
//! Durations use the complete-event form (`"ph": "X"`), timestamps are µs
//! from the recorder epoch (the unit Perfetto expects), and per-span
//! attribution (sim-clock µs, µJ, EMA byte split) rides in `args`.

use super::span::{FlightRecorder, SpanEvent, SpanKind};
use crate::coordinator::REPORT_SCHEMA_VERSION;
use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

fn args_json(ev: &SpanEvent) -> Json {
    Json::obj(vec![
        ("id", Json::num(ev.id as f64)),
        ("chip_us", Json::num(ev.chip_us)),
        ("chip_uj", Json::num(ev.chip_uj)),
        ("ema_bytes", Json::num(ev.ema_bytes as f64)),
        ("ema_kv_bytes", Json::num(ev.ema_kv_bytes as f64)),
        ("past_len", Json::num(ev.past_len as f64)),
        ("group", Json::num(ev.group as f64)),
    ])
}

fn complete_event(ev: &SpanEvent, pid: u64, tid: u64) -> Json {
    Json::obj(vec![
        ("name", Json::str(ev.kind.name())),
        ("cat", Json::str("serving")),
        ("ph", Json::str("X")),
        ("ts", Json::num(ev.t_start_us)),
        ("dur", Json::num(ev.dur_us())),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", args_json(ev)),
    ])
}

fn thread_name(pid: u64, tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("thread_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn process_name(pid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// Render `events` as a Chrome `trace_event` JSON document. `n_workers`
/// names the worker lanes; lanes beyond it get the service-lane names from
/// the [`FlightRecorder::for_pool`] convention.
pub fn chrome_trace(events: &[SpanEvent], n_workers: usize) -> Json {
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + 16);
    out.push(process_name(1, "pool workers"));
    out.push(process_name(2, "streams"));
    let mut named_lanes: Vec<u32> = Vec::new();
    let mut named_streams: Vec<u64> = Vec::new();
    for ev in events {
        // Execution view: everything lands on its writer's lane.
        if !named_lanes.contains(&ev.lane) {
            named_lanes.push(ev.lane);
            let name = match (ev.lane as usize) < n_workers {
                true => format!("worker-{}", ev.lane),
                false if ev.lane as usize == n_workers => "admit".to_string(),
                false => "kv-arena".to_string(),
            };
            out.push(thread_name(1, ev.lane as u64, &name));
        }
        out.push(complete_event(ev, 1, ev.lane as u64));
        // Stream view: lifecycle spans only, one track per request.
        if ev.id != 0 && (ev.kind.is_lifecycle() || ev.kind == SpanKind::Shed) {
            if !named_streams.contains(&ev.id) {
                named_streams.push(ev.id);
                out.push(thread_name(2, ev.id, &format!("req-{}", ev.id)));
            }
            out.push(complete_event(ev, 2, ev.id));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("schema_version", Json::num(REPORT_SCHEMA_VERSION as f64)),
                ("producer", Json::str("trex")),
            ]),
        ),
    ])
}

/// Fleet variant of [`chrome_trace`]: worker lane *i* is chip *i*'s
/// execution track, rendered as its **own process group** (`pid 10+i`,
/// named after the chip) so Perfetto shows one group per chip. The
/// admission and KV service lanes stay under `pid 1` ("pool shared") and
/// the per-stream lifecycle view stays `pid 2`, exactly as in the
/// single-chip export.
pub fn chrome_trace_fleet(events: &[SpanEvent], chip_ids: &[String]) -> Json {
    let n_chips = chip_ids.len();
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + 16);
    out.push(process_name(1, "pool shared"));
    out.push(process_name(2, "streams"));
    for (i, id) in chip_ids.iter().enumerate() {
        out.push(process_name(10 + i as u64, &format!("chip:{id}")));
    }
    let mut named_lanes: Vec<u32> = Vec::new();
    let mut named_streams: Vec<u64> = Vec::new();
    for ev in events {
        // Execution view: chip lanes get their own pid, service lanes
        // share pid 1.
        let lane = ev.lane as usize;
        let (pid, name) = if lane < n_chips {
            (10 + lane as u64, format!("worker-{lane}"))
        } else if lane == n_chips {
            (1, "admit".to_string())
        } else {
            (1, "kv-arena".to_string())
        };
        if !named_lanes.contains(&ev.lane) {
            named_lanes.push(ev.lane);
            out.push(thread_name(pid, ev.lane as u64, &name));
        }
        out.push(complete_event(ev, pid, ev.lane as u64));
        // Stream view: identical to the single-chip export.
        if ev.id != 0 && (ev.kind.is_lifecycle() || ev.kind == SpanKind::Shed) {
            if !named_streams.contains(&ev.id) {
                named_streams.push(ev.id);
                out.push(thread_name(2, ev.id, &format!("req-{}", ev.id)));
            }
            out.push(complete_event(ev, 2, ev.id));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("schema_version", Json::num(REPORT_SCHEMA_VERSION as f64)),
                ("producer", Json::str("trex")),
            ]),
        ),
    ])
}

/// Render `events` as JSONL: one span object per line, in input order.
pub fn spans_jsonl(events: &[SpanEvent]) -> String {
    let mut s = String::new();
    for ev in events {
        s.push_str(&ev.to_json().to_string());
        s.push('\n');
    }
    s
}

/// Drain the recorder's retained events to `path` as an anomaly dump:
/// the spans as JSONL, then one `{"kind": "violation", ...}` line per
/// entry of `violations` — the dump's **final lines restate the violation
/// it was taken for**, so a dump file is self-describing. Returns the
/// number of span events written.
pub fn dump_anomaly(
    rec: &FlightRecorder,
    path: &Path,
    violations: &[String],
) -> std::io::Result<usize> {
    let events = rec.snapshot();
    let mut f = std::fs::File::create(path)?;
    f.write_all(spans_jsonl(&events).as_bytes())?;
    for v in violations {
        let line = Json::obj(vec![
            ("kind", Json::str("violation")),
            ("detail", Json::str(v)),
            ("ts_us", Json::num(rec.now_us())),
        ]);
        f.write_all(line.to_string().as_bytes())?;
        f.write_all(b"\n")?;
    }
    f.flush()?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::SpanKind;

    fn span(id: u64, kind: SpanKind, lane: u32, t0: f64, t1: f64) -> SpanEvent {
        let mut ev = SpanEvent::marker(kind, id, t0);
        ev.t_end_us = t1;
        ev.lane = lane;
        ev
    }

    #[test]
    fn chrome_trace_is_valid_json_with_both_views() {
        let events = vec![
            span(5, SpanKind::Queue, 0, 0.0, 10.0),
            span(5, SpanKind::Prefill, 0, 10.0, 30.0),
            span(5, SpanKind::DecodeStep, 1, 30.0, 45.0),
            span(5, SpanKind::Complete, 1, 45.0, 45.0),
            span(0, SpanKind::KvEvict, 3, 20.0, 20.0),
        ];
        let doc = chrome_trace(&events, 2);
        // Round-trips through the parser: structurally valid JSON.
        let parsed = Json::parse(&doc.to_string()).expect("valid JSON");
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        // Lifecycle spans appear twice (worker view + stream view), the
        // arena marker once; metadata events name both processes.
        let complete: Vec<&Json> = evs
            .iter()
            .filter(|e| e.opt("ph").and_then(|p| p.as_str().ok()) == Some("X"))
            .collect();
        assert_eq!(complete.len(), 4 * 2 + 1);
        let stream_view: Vec<&Json> = complete
            .iter()
            .copied()
            .filter(|e| e.opt("pid").and_then(|p| p.as_f64().ok()) == Some(2.0))
            .collect();
        assert_eq!(stream_view.len(), 4, "all four lifecycle spans on the stream track");
        assert!(stream_view.iter().all(|e| e.opt("tid").and_then(|t| t.as_f64().ok()) == Some(5.0)));
        // Durations tile 0 → 45.
        let total: f64 = stream_view
            .iter()
            .map(|e| e.opt("dur").unwrap().as_f64().unwrap())
            .sum();
        assert!((total - 45.0).abs() < 1e-9);
    }

    #[test]
    fn fleet_trace_groups_one_process_per_chip() {
        let events = vec![
            span(5, SpanKind::Prefill, 0, 0.0, 10.0),   // chip p0's worker lane
            span(5, SpanKind::DecodeStep, 1, 12.0, 20.0), // chip d0's worker lane
            span(5, SpanKind::Admit, 2, 0.0, 0.0),      // admit service lane
            span(5, SpanKind::KvMigrate, 3, 11.0, 11.0), // kv service lane
        ];
        let chips = vec!["p0".to_string(), "d0".to_string()];
        let doc = chrome_trace_fleet(&events, &chips);
        let parsed = Json::parse(&doc.to_string()).expect("valid JSON");
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let pid_of = |e: &Json| e.opt("pid").and_then(|p| p.as_f64().ok()).unwrap_or(-1.0);
        // One process-name metadata record per chip, pids 10 and 11.
        let procs: Vec<String> = evs
            .iter()
            .filter(|e| e.opt("name").and_then(|n| n.as_str().ok()) == Some("process_name"))
            .filter(|e| pid_of(e) >= 10.0)
            .map(|e| {
                e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string()
            })
            .collect();
        assert_eq!(procs, vec!["chip:p0".to_string(), "chip:d0".to_string()]);
        // Chip-lane spans land in their chip's process; service lanes stay
        // under the shared pool process (pid 1).
        let complete: Vec<&Json> = evs
            .iter()
            .filter(|e| e.opt("ph").and_then(|p| p.as_str().ok()) == Some("X"))
            .collect();
        let exec_pids: Vec<f64> =
            complete.iter().filter(|e| pid_of(e) != 2.0).map(|e| pid_of(e)).collect();
        assert_eq!(exec_pids, vec![10.0, 11.0, 1.0, 1.0]);
    }

    #[test]
    fn jsonl_one_line_per_event_each_parseable() {
        let events =
            vec![span(1, SpanKind::Admit, 2, 1.0, 1.0), span(1, SpanKind::Queue, 0, 1.0, 8.0)];
        let text = spans_jsonl(&events);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).expect("each line parses");
            assert!(j.opt("kind").is_some());
            assert!(j.opt("dur_us").is_some());
        }
    }

    #[test]
    fn anomaly_dump_ends_with_the_violations() {
        let rec = FlightRecorder::new(1, 64);
        for i in 0..5u64 {
            rec.record(0, SpanEvent::marker(SpanKind::DecodeStep, i, i as f64));
        }
        let dir = std::env::temp_dir();
        let path = dir.join(format!("trex-test-anomaly-{}.jsonl", std::process::id()));
        let n = dump_anomaly(&rec, &path, &["req 3: completed twice".to_string()]).unwrap();
        assert_eq!(n, 5);
        let text = std::fs::read_to_string(&path).unwrap();
        let last = text.lines().last().unwrap();
        let j = Json::parse(last).unwrap();
        assert_eq!(j.opt("kind").and_then(|k| k.as_str().ok()), Some("violation"));
        assert_eq!(j.opt("detail").and_then(|d| d.as_str().ok()), Some("req 3: completed twice"));
        std::fs::remove_file(&path).ok();
    }
}
