//! `trex inspect` — summarize an exported trace offline: per-phase µs/µJ
//! breakdown, top-k slowest requests, and the shed timeline.
//!
//! Accepts either exporter format ([`crate::obs::export`]): a Chrome
//! `trace_event` JSON document (spans are read from the worker-view
//! track, so nothing is double-counted) or a JSONL span stream.

use super::span::{SpanEvent, SpanKind};
use super::timeseries::ShedTimeline;
use crate::util::json::Json;
use std::collections::BTreeMap;

fn span_from_chrome(ev: &Json) -> Option<SpanEvent> {
    if ev.opt("ph").and_then(|p| p.as_str().ok()) != Some("X") {
        return None;
    }
    // Worker view only — every span appears there exactly once. Single-chip
    // traces put all worker lanes under pid 1; fleet traces
    // ([`crate::obs::export::chrome_trace_fleet`]) group each chip's lanes
    // under pid 10+chip while the shared admit/kv lanes stay on pid 1. The
    // stream view (pid 2) duplicates lifecycle spans and is always skipped.
    let pid = ev.opt("pid").and_then(|p| p.as_f64().ok())?;
    if pid != 1.0 && pid < 10.0 {
        return None;
    }
    let kind = SpanKind::from_name(ev.opt("name")?.as_str().ok()?)?;
    let ts = ev.opt("ts")?.as_f64().ok()?;
    let dur = ev.opt("dur").and_then(|d| d.as_f64().ok()).unwrap_or(0.0);
    let args = ev.opt("args");
    let f = |key: &str| args.and_then(|a| a.opt(key)).and_then(|v| v.as_f64().ok());
    Some(SpanEvent {
        id: f("id").unwrap_or(0.0) as u64,
        kind,
        lane: ev.opt("tid").and_then(|t| t.as_f64().ok()).unwrap_or(0.0) as u32,
        t_start_us: ts,
        t_end_us: ts + dur,
        chip_us: f("chip_us").unwrap_or(0.0),
        chip_uj: f("chip_uj").unwrap_or(0.0),
        ema_bytes: f("ema_bytes").unwrap_or(0.0) as u64,
        ema_kv_bytes: f("ema_kv_bytes").unwrap_or(0.0) as u64,
        past_len: f("past_len").unwrap_or(0.0) as u32,
        group: f("group").unwrap_or(0.0) as u32,
    })
}

fn span_from_jsonl(line: &Json) -> Option<SpanEvent> {
    let kind = SpanKind::from_name(line.opt("kind")?.as_str().ok()?)?;
    let ts = line.opt("ts_us")?.as_f64().ok()?;
    let f = |key: &str| line.opt(key).and_then(|v| v.as_f64().ok());
    Some(SpanEvent {
        id: f("id").unwrap_or(0.0) as u64,
        kind,
        lane: f("lane").unwrap_or(0.0) as u32,
        t_start_us: ts,
        t_end_us: ts + f("dur_us").unwrap_or(0.0),
        chip_us: f("chip_us").unwrap_or(0.0),
        chip_uj: f("chip_uj").unwrap_or(0.0),
        ema_bytes: f("ema_bytes").unwrap_or(0.0) as u64,
        ema_kv_bytes: f("ema_kv_bytes").unwrap_or(0.0) as u64,
        past_len: f("past_len").unwrap_or(0.0) as u32,
        group: f("group").unwrap_or(0.0) as u32,
    })
}

/// Parse spans out of either exporter format. Chrome documents are
/// detected by their `traceEvents` key; anything else is treated as JSONL
/// (lines that aren't spans — violation markers, telemetry — are skipped).
pub fn parse_trace(text: &str) -> Result<Vec<SpanEvent>, String> {
    if let Ok(doc) = Json::parse(text) {
        if let Some(evs) = doc.opt("traceEvents") {
            let evs = evs.as_arr().map_err(|e| e.to_string())?;
            return Ok(evs.iter().filter_map(span_from_chrome).collect());
        }
    }
    let mut out = Vec::new();
    let mut parsed_any = false;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("bad JSONL line: {e}"))?;
        parsed_any = true;
        if let Some(ev) = span_from_jsonl(&j) {
            out.push(ev);
        }
    }
    if !parsed_any {
        return Err("empty trace".to_string());
    }
    Ok(out)
}

/// Per-phase aggregate.
#[derive(Debug, Clone, Copy, Default)]
struct PhaseAgg {
    count: u64,
    wall_us: f64,
    chip_us: f64,
    chip_uj: f64,
    ema_bytes: u64,
    ema_kv_bytes: u64,
}

/// Summarize a trace: per-phase breakdown, `topk` slowest requests (by
/// summed lifecycle-span wall time, i.e. e2e latency), shed timeline.
pub fn summarize(events: &[SpanEvent], topk: usize) -> Json {
    let mut phases: BTreeMap<&'static str, PhaseAgg> = BTreeMap::new();
    let mut per_req: BTreeMap<u64, (f64, u64, f64, f64)> = BTreeMap::new(); // e2e, steps, chip_us, chip_uj
    let mut per_lane: BTreeMap<u32, PhaseAgg> = BTreeMap::new(); // lane == chip in fleet traces
    let mut door_sheds: Vec<f64> = Vec::new();
    let mut late_sheds: Vec<f64> = Vec::new();
    // DVFS re-points per chip: (t_us, from_vdd, to_vdd) — the markers
    // carry the voltages in chip_us/chip_uj (see [`SpanKind::DvfsRepoint`]).
    let mut dvfs: BTreeMap<u32, Vec<(f64, f64, f64)>> = BTreeMap::new();
    for ev in events {
        if ev.kind == SpanKind::DvfsRepoint {
            // Not chip time: the payload is a voltage transition. Count it
            // in the phase table but keep it out of every µs/µJ aggregate.
            phases.entry(ev.kind.name()).or_default().count += 1;
            dvfs.entry(ev.group).or_default().push((ev.t_start_us, ev.chip_us, ev.chip_uj));
            continue;
        }
        let agg = phases.entry(ev.kind.name()).or_default();
        agg.count += 1;
        agg.wall_us += ev.dur_us();
        agg.chip_us += ev.chip_us;
        agg.chip_uj += ev.chip_uj;
        agg.ema_bytes += ev.ema_bytes;
        agg.ema_kv_bytes += ev.ema_kv_bytes;
        if ev.chip_us > 0.0 || ev.chip_uj > 0.0 {
            let l = per_lane.entry(ev.lane).or_default();
            l.count += 1;
            l.wall_us += ev.dur_us();
            l.chip_us += ev.chip_us;
            l.chip_uj += ev.chip_uj;
            l.ema_bytes += ev.ema_bytes;
            l.ema_kv_bytes += ev.ema_kv_bytes;
        }
        match ev.kind {
            SpanKind::DoorShed => door_sheds.push(ev.t_start_us),
            SpanKind::Shed => late_sheds.push(ev.t_start_us),
            _ => {}
        }
        if ev.id != 0 && ev.kind.is_lifecycle() {
            let r = per_req.entry(ev.id).or_insert((0.0, 0, 0.0, 0.0));
            r.0 += ev.dur_us();
            if ev.kind == SpanKind::DecodeStep {
                r.1 += 1;
                // chip_us/chip_uj are per token on decode steps.
                r.2 += ev.chip_us;
                r.3 += ev.chip_uj;
            } else {
                r.2 += ev.chip_us;
                r.3 += ev.chip_uj;
            }
        }
    }

    let phase_json = Json::Obj(
        phases
            .iter()
            .map(|(name, a)| {
                (
                    name.to_string(),
                    Json::obj(vec![
                        ("count", Json::num(a.count as f64)),
                        ("wall_us", Json::num(a.wall_us)),
                        ("chip_us", Json::num(a.chip_us)),
                        ("chip_uj", Json::num(a.chip_uj)),
                        ("ema_bytes", Json::num(a.ema_bytes as f64)),
                        ("ema_kv_bytes", Json::num(a.ema_kv_bytes as f64)),
                    ]),
                )
            })
            .collect(),
    );

    let mut slowest: Vec<(u64, (f64, u64, f64, f64))> = per_req.into_iter().collect();
    slowest.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0).then(a.0.cmp(&b.0)));
    slowest.truncate(topk.max(1));
    let slowest_json = Json::Arr(
        slowest
            .iter()
            .map(|(id, (e2e, steps, chip_us, chip_uj))| {
                Json::obj(vec![
                    ("id", Json::num(*id as f64)),
                    ("e2e_us", Json::num(*e2e)),
                    ("decode_steps", Json::num(*steps as f64)),
                    ("chip_us", Json::num(*chip_us)),
                    ("chip_uj", Json::num(*chip_uj)),
                ])
            })
            .collect(),
    );

    // Per-lane chip-time attribution. Workers are bound 1:1 to chips in
    // fleet pools, so in a fleet trace each lane *is* a chip and this is
    // the per-chip µs/µJ split; in single-chip traces it is the per-worker
    // split of one modeled chip.
    let lanes_json = Json::Obj(
        per_lane
            .iter()
            .map(|(lane, a)| {
                (
                    format!("lane{lane}"),
                    Json::obj(vec![
                        ("count", Json::num(a.count as f64)),
                        ("chip_us", Json::num(a.chip_us)),
                        ("chip_uj", Json::num(a.chip_uj)),
                    ]),
                )
            })
            .collect(),
    );

    // Governor-decision summary: total re-points plus each chip's VDD
    // timeline in trace order.
    let repoint_total: u64 = dvfs.values().map(|v| v.len() as u64).sum();
    let dvfs_json = Json::obj(vec![
        ("repoints", Json::num(repoint_total as f64)),
        (
            "chips",
            Json::Obj(
                dvfs.iter()
                    .map(|(chip, moves)| {
                        (
                            format!("chip{chip}"),
                            Json::Arr(
                                moves
                                    .iter()
                                    .map(|(t, from, to)| {
                                        Json::obj(vec![
                                            ("t_us", Json::num(*t)),
                                            ("from_vdd", Json::num(*from)),
                                            ("to_vdd", Json::num(*to)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        )
                    })
                    .collect(),
            ),
        ),
    ]);

    let timeline = ShedTimeline::from_instants(&door_sheds, &late_sheds, 20);
    Json::obj(vec![
        ("events", Json::num(events.len() as f64)),
        ("phases", phase_json),
        ("lanes", lanes_json),
        ("dvfs", dvfs_json),
        ("slowest", slowest_json),
        ("shed_timeline", timeline.to_json()),
    ])
}

/// Human-readable rendering of a [`summarize`] document.
pub fn render_summary(summary: &Json) -> String {
    let mut s = String::new();
    let n = summary.opt("events").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    s.push_str(&format!("trace: {n:.0} span events\n\nper-phase breakdown:\n"));
    s.push_str(&format!(
        "  {:<14} {:>8} {:>14} {:>12} {:>12} {:>14}\n",
        "phase", "count", "wall_us", "chip_us", "chip_uj", "ema_bytes"
    ));
    if let Some(Ok(phases)) = summary.opt("phases").map(|p| p.as_obj()) {
        for (name, a) in phases {
            let f = |key: &str| a.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            s.push_str(&format!(
                "  {:<14} {:>8.0} {:>14.1} {:>12.2} {:>12.3} {:>14.0}\n",
                name,
                f("count"),
                f("wall_us"),
                f("chip_us"),
                f("chip_uj"),
                f("ema_bytes"),
            ));
        }
    }
    if let Some(Ok(lanes)) = summary.opt("lanes").map(|l| l.as_obj()) {
        if !lanes.is_empty() {
            s.push_str("\nper-lane chip time (lane == chip in fleet traces):\n");
            for (name, a) in lanes {
                let f = |key: &str| a.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
                s.push_str(&format!(
                    "  {:<14} {:>8.0} {:>26} {:>12.2} {:>12.3}\n",
                    name,
                    f("count"),
                    "",
                    f("chip_us"),
                    f("chip_uj"),
                ));
            }
        }
    }
    let repoints = summary
        .opt("dvfs")
        .and_then(|d| d.opt("repoints"))
        .and_then(|v| v.as_f64().ok())
        .unwrap_or(0.0);
    if repoints > 0.0 {
        s.push_str(&format!("\ndvfs re-points: {repoints:.0}\n"));
        let chips = summary.opt("dvfs").and_then(|d| d.opt("chips")).map(|c| c.as_obj());
        if let Some(Ok(chips)) = chips {
            for (name, moves) in chips {
                if let Ok(moves) = moves.as_arr() {
                    let path: Vec<String> = moves
                        .iter()
                        .map(|m| {
                            let f =
                                |k: &str| m.opt(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
                            format!(
                                "{:.2}V→{:.2}V @{:.0}us",
                                f("from_vdd"),
                                f("to_vdd"),
                                f("t_us")
                            )
                        })
                        .collect();
                    s.push_str(&format!("  {:<8} {}\n", name, path.join("; ")));
                }
            }
        }
    }
    s.push_str("\nslowest requests (by e2e):\n");
    if let Some(Ok(slow)) = summary.opt("slowest").map(|v| v.as_arr()) {
        for r in slow {
            let f = |key: &str| r.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            s.push_str(&format!(
                "  req {:<6.0} e2e {:>12.1}us  decode_steps {:<5.0} chip {:>10.2}us {:>8.3}uJ\n",
                f("id"),
                f("e2e_us"),
                f("decode_steps"),
                f("chip_us"),
                f("chip_uj"),
            ));
        }
    }
    let tl = summary.opt("shed_timeline");
    let door: f64 = tl
        .and_then(|t| t.opt("door"))
        .and_then(|d| d.as_arr().ok())
        .map(|a| a.iter().filter_map(|v| v.as_f64().ok()).sum())
        .unwrap_or(0.0);
    let late: f64 = tl
        .and_then(|t| t.opt("late"))
        .and_then(|d| d.as_arr().ok())
        .map(|a| a.iter().filter_map(|v| v.as_f64().ok()).sum())
        .unwrap_or(0.0);
    if door + late > 0.0 {
        s.push_str(&format!("\nshed timeline (door {door:.0}, late {late:.0}):\n"));
        if let (Some(t), Some(Ok(d)), Some(Ok(l))) = (
            tl,
            tl.and_then(|t| t.opt("door")).map(|d| d.as_arr()),
            tl.and_then(|t| t.opt("late")).map(|l| l.as_arr()),
        ) {
            let bucket = t.opt("bucket_us").and_then(|b| b.as_f64().ok()).unwrap_or(1.0);
            let mut timeline = ShedTimeline::new(bucket * d.len() as f64, d.len());
            timeline.door = d.iter().filter_map(|v| v.as_f64().ok()).map(|v| v as u64).collect();
            timeline.late = l.iter().filter_map(|v| v.as_f64().ok()).map(|v| v as u64).collect();
            timeline.bucket_us = bucket;
            s.push_str(&timeline.render());
        }
    } else {
        s.push_str("\nno sheds recorded\n");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::{chrome_trace, spans_jsonl};

    fn span(id: u64, kind: SpanKind, t0: f64, t1: f64, chip_us: f64) -> SpanEvent {
        let mut ev = SpanEvent::marker(kind, id, t0);
        ev.t_end_us = t1;
        ev.chip_us = chip_us;
        ev
    }

    fn sample_events() -> Vec<SpanEvent> {
        vec![
            span(1, SpanKind::Queue, 0.0, 10.0, 0.0),
            span(1, SpanKind::Prefill, 10.0, 40.0, 25.0),
            span(1, SpanKind::DecodeStep, 40.0, 55.0, 11.0),
            span(1, SpanKind::DecodeStep, 55.0, 70.0, 12.0),
            span(1, SpanKind::Complete, 70.0, 70.0, 0.0),
            span(2, SpanKind::DoorShed, 30.0, 30.0, 0.0),
            span(3, SpanKind::Queue, 5.0, 20.0, 0.0),
            span(3, SpanKind::Shed, 20.0, 20.0, 0.0),
        ]
    }

    #[test]
    fn both_exporter_formats_parse_back_identically() {
        let events = sample_events();
        let from_chrome = parse_trace(&chrome_trace(&events, 1).to_string()).unwrap();
        let from_jsonl = parse_trace(&spans_jsonl(&events)).unwrap();
        assert_eq!(from_chrome.len(), events.len());
        assert_eq!(from_jsonl.len(), events.len());
        for (a, b) in from_chrome.iter().zip(from_jsonl.iter()) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.id, b.id);
            assert!((a.t_start_us - b.t_start_us).abs() < 1e-9);
            assert!((a.dur_us() - b.dur_us()).abs() < 1e-9);
        }
        assert!(parse_trace("").is_err());
        assert!(parse_trace("not json").is_err());
    }

    #[test]
    fn fleet_traces_parse_and_attribute_lanes() {
        use crate::obs::export::chrome_trace_fleet;
        let mut events = sample_events();
        // Move request 1's decode steps to chip lane 1 so the summary has
        // chip time on two lanes.
        for ev in events.iter_mut() {
            if ev.kind == SpanKind::DecodeStep {
                ev.lane = 1;
            }
        }
        let chips = vec!["p0".to_string(), "d0".to_string()];
        let doc = chrome_trace_fleet(&events, &chips).to_string();
        // Fleet traces group chip lanes under pid 10+chip; parsing must
        // still see every span exactly once (stream view skipped).
        let parsed = parse_trace(&doc).unwrap();
        assert_eq!(parsed.len(), events.len());
        let s = summarize(&parsed, 3);
        let lanes = s.get("lanes").unwrap();
        let lane0 = lanes.get("lane0").unwrap();
        let lane1 = lanes.get("lane1").unwrap();
        assert_eq!(lane0.get("chip_us").unwrap().as_f64().unwrap(), 25.0);
        assert_eq!(lane1.get("chip_us").unwrap().as_f64().unwrap(), 23.0);
        assert!(render_summary(&s).contains("per-lane chip time"));
    }

    #[test]
    fn dvfs_repoints_summarize_as_per_chip_vdd_timelines() {
        let mut events = sample_events();
        // Two re-points on chip 1, one on chip 0 (voltages ride in
        // chip_us/chip_uj; group = chip).
        for (chip, t, from, to) in
            [(1u32, 100.0, 0.85, 0.75), (0u32, 150.0, 0.85, 0.65), (1u32, 200.0, 0.75, 0.65)]
        {
            let mut ev = SpanEvent::marker(SpanKind::DvfsRepoint, chip as u64, t);
            ev.group = chip;
            ev.chip_us = from;
            ev.chip_uj = to;
            events.push(ev);
        }
        let s = summarize(&events, 3);
        let dvfs = s.get("dvfs").unwrap();
        assert_eq!(dvfs.get("repoints").unwrap().as_f64().unwrap(), 3.0);
        let chips = dvfs.get("chips").unwrap();
        let c1 = chips.get("chip1").unwrap().as_arr().unwrap();
        assert_eq!(c1.len(), 2);
        assert_eq!(c1[0].get("from_vdd").unwrap().as_f64().unwrap(), 0.85);
        assert_eq!(c1[1].get("to_vdd").unwrap().as_f64().unwrap(), 0.65);
        // The markers stay out of the per-lane chip-time attribution (their
        // payload is volts, not µs/µJ): lane 0 sums exactly the prefill
        // (25) + decode (23) chip time, no 0.85-volt crumbs added.
        let lane0 = s.get("lanes").unwrap().get("lane0").unwrap();
        assert_eq!(lane0.get("chip_us").unwrap().as_f64().unwrap(), 48.0);
        assert_eq!(
            s.get("phases").unwrap().get("dvfs_repoint").unwrap().get("count").unwrap()
                .as_f64()
                .unwrap(),
            3.0
        );
        let text = render_summary(&s);
        assert!(text.contains("dvfs re-points: 3"));
        assert!(text.contains("chip1"));
        assert!(text.contains("0.85V→0.75V"));
        // Round-trips through the JSONL exporter like every other kind.
        let parsed = parse_trace(&spans_jsonl(&events)).unwrap();
        assert_eq!(parsed.len(), events.len());
    }

    #[test]
    fn summary_breaks_down_phases_and_ranks_requests() {
        let events = sample_events();
        let s = summarize(&events, 5);
        let decode = s.get("phases").unwrap().get("decode_step").unwrap();
        assert_eq!(decode.get("count").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(decode.get("wall_us").unwrap().as_f64().unwrap(), 30.0);
        assert_eq!(decode.get("chip_us").unwrap().as_f64().unwrap(), 23.0);
        let slow = s.get("slowest").unwrap().as_arr().unwrap();
        assert_eq!(slow[0].get("id").unwrap().as_u64().unwrap(), 1);
        assert_eq!(slow[0].get("e2e_us").unwrap().as_f64().unwrap(), 70.0);
        assert_eq!(slow[1].get("id").unwrap().as_u64().unwrap(), 3);
        // Sheds land in the timeline.
        let tl = s.get("shed_timeline").unwrap();
        let door: f64 =
            tl.get("door").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).sum();
        let late: f64 =
            tl.get("late").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).sum();
        assert_eq!(door, 1.0);
        assert_eq!(late, 1.0);
        // Renders without panicking and names the phases.
        let text = render_summary(&s);
        assert!(text.contains("decode_step"));
        assert!(text.contains("shed timeline"));
    }
}
