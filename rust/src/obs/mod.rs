//! Observability: flight-recorder span tracing + time-series telemetry.
//!
//! The serving pool's end-of-run aggregates say *what* happened; this
//! module records *where the microseconds and bytes went*. Three pieces:
//!
//! * [`span`] — the flight recorder: per-request lifecycle spans
//!   (admit → queue → prefill chunks → decode steps → KV events →
//!   complete/shed) in fixed-capacity per-worker ring buffers. Off by
//!   default; the disabled hot path is a branch on `None`.
//! * [`export`] — Chrome `trace_event` JSON (Perfetto-loadable, one track
//!   per worker + one per stream) and JSONL, plus the anomaly-dump format
//!   written on ledger violations, fuzz failures, and shed storms.
//! * [`timeseries`] — the sampler's periodic pool snapshots and the
//!   bucketed shed timeline; [`inspect`] summarizes exported traces for
//!   `trex inspect`.
//!
//! Span durations are defined to **tile**: each lifecycle span starts
//! where the request's previous one ended, so one request's spans sum to
//! its reported e2e latency exactly (the `integration_obs` test pins
//! this against `Response::e2e_us`).

pub mod export;
pub mod inspect;
pub mod span;
pub mod timeseries;

pub use export::{chrome_trace, chrome_trace_fleet, dump_anomaly, spans_jsonl};
pub use inspect::{parse_trace, render_summary, summarize};
pub use span::{DumpOnce, FlightRecorder, SpanEvent, SpanKind, SpanWriter, DEFAULT_LANE_CAPACITY};
pub use timeseries::{ShedTimeline, Snapshot, Telemetry, TelemetryConfig};
