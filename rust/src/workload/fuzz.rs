//! Seeded scenario fuzzer: random pool configurations × random request
//! schedules, property-checked against the scheduler invariants that every
//! other test asserts only for its one hand-picked interleaving.
//!
//! Each iteration derives one [`Scenario`] from one seed — pool knobs
//! (workers, backpressure bounds, chunking, coalescing, decode policy,
//! KV quantization and arena size) plus a request schedule (arrival gaps,
//! lengths, decode budgets, deliberately malformed payloads, oversized
//! lengths, an optional mid-schedule shutdown) — runs it against a real
//! pool over the deterministic reference backend, and checks:
//!
//! 1. **Conservation** — every admitted request reaches exactly one
//!    terminal state (completed or shed), via the lifecycle ledger
//!    ([`crate::coordinator::ServerMetrics::ledger_audit`]).
//! 2. **Zero KV residual** — after the drain, the arena holds no live
//!    streams, resident pages, reservations, pins, shared prefix pages,
//!    or dangling prefix refcounts ([`crate::kv::KvManager::residual`]).
//!    Schedules mix `prefix_group` tags into their requests, so the
//!    refcount-conservation of the radix prefix chains is checked under
//!    every interleaving — sheds racing prefix-mates' releases included.
//! 3. **Token ordering** — no token event is emitted after its stream
//!    sheds, and none belongs to a request that was never admitted.
//! 4. **Fault attribution** — the pool only reports worker errors when the
//!    schedule injected faults, and never reports a thread panic.
//!
//! Everything is deterministic in the seed *except thread interleaving* —
//! which is the point: the same seed replays the same schedule against the
//! same config, and the invariants must hold under every interleaving.
//! A failure minimizes its schedule greedily (bounded re-runs) and renders
//! the seed + a trace-format snippet, so one CI line reproduces locally:
//! `cargo run --release -- fuzz --seed <seed> --iters 1`.

use crate::config::{HwConfig, ModelConfig};
use crate::control::{GovernorConfig, SloTarget};
use crate::coordinator::{
    BatcherConfig, DecodePolicy, Engine, EngineConfig, Lifecycle, PoolConfig, Request, Server,
};
use crate::fleet::{ChipRole, ChipSpec, Fleet};
use crate::kv::{KvArenaConfig, KvManager, KvQuant};
use crate::obs::{dump_anomaly, FlightRecorder};
use crate::runtime::{artifacts, ArtifactSet};
use crate::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// One request in a scenario's schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ReqSpec {
    pub id: u64,
    /// Gap slept before submitting this request, µs.
    pub gap_us: u64,
    pub len: usize,
    pub generate: usize,
    /// Payload one row short — the engine fails the batch at plane
    /// assembly, exercising the shed path (and shedding batch mates).
    pub malformed: bool,
    /// Shared-prompt tag index (`g0`, `g1`, …): requests sharing it attach
    /// to one refcounted KV prefix — the refcount-conservation invariant
    /// (zero shared pages / refs after drain) only bites when schedules
    /// actually share.
    pub prefix_group: Option<u8>,
}

/// One fuzz iteration: pool knobs + request schedule, derived from a seed.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub seed: u64,
    workers: usize,
    queue_depth: usize,
    max_inflight: usize,
    prefill_chunk: usize,
    decode_max_wait_us: u64,
    decode_priority: bool,
    decode: DecodePolicy,
    batcher_wait_us: u64,
    kv_quant: KvQuant,
    kv_pages: usize,
    admit_oversub: f64,
    /// Shut the pool down after half the schedule, then verify the closed
    /// gate rejects the rest (drain-on-shutdown must still conserve).
    early_shutdown: bool,
    /// Drop the token receiver instead of auditing it (dropping must be
    /// harmless; skips the token-ordering check).
    drop_tokens: bool,
    /// Heterogeneous fleet shape: one `(role, vdd)` per chip. Empty runs
    /// the classic single-arena pool; non-empty binds one worker per chip
    /// with its own tiny KV arena, so placement, chain migration, and
    /// sheds racing mid-migration streams all get fuzzed. The residual
    /// invariant then applies to EVERY chip's arena.
    fleet: Vec<(ChipRole, f64)>,
    /// Runtime DVFS governor (fleet scenarios only — inert without chips
    /// to re-point): re-points race decode steps, and the stale-plan
    /// invariant (every re-point's epoch bump re-costs the plan scope
    /// before the next priced step) gets checked under fuzz interleaving.
    dvfs: bool,
    /// Governor dwell, µs (small values on purpose: more re-points race
    /// more steps).
    dvfs_dwell_us: u64,
    /// Decode-p95 SLO target: gates generate admission and qualifies
    /// governor drops.
    slo_p95_us: Option<f64>,
    pub reqs: Vec<ReqSpec>,
}

impl Scenario {
    /// Deterministic scenario from a seed.
    pub fn from_seed(seed: u64) -> Scenario {
        let max_seq = artifacts::TINY_MAX_SEQ;
        let mut rng = Rng::new(seed);
        let workers = 1 + rng.below(2);
        let queue_depth = if rng.f64() < 0.3 { 0 } else { 2 + rng.below(5) };
        let max_inflight = if rng.f64() < 0.3 { 0 } else { 3 + rng.below(14) };
        let prefill_chunk = rng.below(4);
        let decode_max_wait_us = [0, 0, 100, 500][rng.below(4)];
        let decode_priority = rng.f64() < 0.5;
        let decode = if rng.f64() < 0.5 {
            DecodePolicy::Greedy
        } else {
            DecodePolicy::DepthBucketed { bucket: 4 << rng.below(2) }
        };
        let batcher_wait_us = [0, 200, 1000][rng.below(3)];
        let kv_quant = [KvQuant::Fp16, KvQuant::Int8, KvQuant::Int4][rng.below(3)];
        // Small arenas on purpose: eviction, swap-in, and overcommit fire.
        let kv_pages = 2 + rng.below(15);
        let admit_oversub = [1.0, 4.0, 8.0][rng.below(3)];
        let early_shutdown = rng.f64() < 0.2;
        let drop_tokens = rng.f64() < 0.3;
        // 0 disables sharing for this scenario; otherwise requests draw
        // from a small tag pool so prefix-mates actually collide (sheds
        // racing a mate's release is the refcount path worth fuzzing).
        let prefix_groups = rng.below(4) as u8;
        let n = 4 + rng.below(21);
        let reqs = (0..n as u64)
            .map(|id| {
                let len = if rng.f64() < 0.05 {
                    // Oversized: must reject synchronously at the door.
                    max_seq + 1 + rng.below(max_seq)
                } else {
                    1 + rng.below(max_seq)
                };
                let prefix_group = if prefix_groups > 0 && rng.f64() < 0.5 {
                    Some(rng.below(prefix_groups as usize) as u8)
                } else {
                    None
                };
                ReqSpec {
                    id,
                    gap_us: rng.below(400) as u64,
                    len,
                    generate: if rng.f64() < 0.5 { 0 } else { 1 + rng.below(6) },
                    malformed: rng.f64() < 0.10,
                    prefix_group,
                }
            })
            .collect();
        // Fleet draws come LAST on purpose: appending them after every
        // pre-existing draw keeps each seed's pool knobs and schedule
        // bit-identical to what that seed produced before fleets existed
        // (old failing seeds still replay their old scenarios).
        let fleet = if rng.f64() < 0.5 {
            Vec::new()
        } else {
            let n_chips = 1 + rng.below(4);
            let roles = [ChipRole::General, ChipRole::Prefill, ChipRole::Decode];
            let vdds = [0.45, 0.60, 0.85];
            (0..n_chips).map(|_| (roles[rng.below(3)], vdds[rng.below(3)])).collect()
        };
        // Governor/SLO draws append after the fleet draws — the same
        // append-LAST contract: every pre-existing draw keeps its position
        // in the seed's stream, so old seeds still replay their old
        // scenarios bit-identically. (Draw unconditionally, gate on the
        // fleet afterwards, so the stream shape never depends on content.)
        let dvfs_roll = rng.f64() < 0.4;
        let dvfs_dwell_us = [1_000, 10_000, 50_000][rng.below(3)];
        let slo_p95_us = if rng.f64() < 0.3 {
            Some([500.0, 5_000.0, 50_000.0][rng.below(3)])
        } else {
            None
        };
        let dvfs = dvfs_roll && !fleet.is_empty();
        Scenario {
            seed,
            workers,
            queue_depth,
            max_inflight,
            prefill_chunk,
            decode_max_wait_us,
            decode_priority,
            decode,
            batcher_wait_us,
            kv_quant,
            kv_pages,
            admit_oversub,
            early_shutdown,
            drop_tokens,
            fleet,
            dvfs,
            dvfs_dwell_us,
            slo_p95_us,
            reqs,
        }
    }

    /// One-line pool-knob description for failure reports.
    pub fn describe(&self) -> String {
        let fleet = if self.fleet.is_empty() {
            "none".to_string()
        } else {
            self.fleet
                .iter()
                .map(|(r, v)| format!("{}@{v:.2}V", r.name()))
                .collect::<Vec<_>>()
                .join(",")
        };
        let governor = if self.dvfs {
            format!("dwell_us={}", self.dvfs_dwell_us)
        } else {
            "off".to_string()
        };
        let slo = match self.slo_p95_us {
            Some(t) => format!("{t}us"),
            None => "none".to_string(),
        };
        format!(
            "workers={} queue_depth={} max_inflight={} prefill_chunk={} \
             decode={:?} wait_us={} priority={} batcher_wait_us={} \
             kv={}x{}pages oversub={} early_shutdown={} drop_tokens={} fleet=[{fleet}] \
             dvfs=[{governor}] slo_p95=[{slo}]",
            self.workers,
            self.queue_depth,
            self.max_inflight,
            self.prefill_chunk,
            self.decode,
            self.decode_max_wait_us,
            self.decode_priority,
            self.batcher_wait_us,
            self.kv_quant.name(),
            self.kv_pages,
            self.admit_oversub,
            self.early_shutdown,
            self.drop_tokens,
        )
    }

    /// Render a schedule as trace-format lines (malformed/oversized
    /// entries annotated as comments — the format itself has no fault
    /// fields).
    pub fn snippet(reqs: &[ReqSpec]) -> String {
        let mut out = String::from("# id arrival_us class prompt_len gen_len [prefix_group]\n");
        let mut t = 0u64;
        for r in reqs {
            t += r.gap_us;
            if r.malformed {
                out.push_str("# next request submits a malformed payload (one row short)\n");
            }
            let class = if r.generate > 0 { "chat" } else { "embed" };
            out.push_str(&format!("{} {} {} {} {}", r.id, t, class, r.len, r.generate));
            if let Some(g) = r.prefix_group {
                out.push_str(&format!(" g{g}"));
            }
            out.push('\n');
        }
        out
    }
}

/// Fuzzer knobs.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Base seed: iteration 0 runs the base seed itself as its scenario
    /// seed (so `--seed <failing> --iters 1` replays a failure exactly);
    /// later iterations draw scenario seeds from a stream seeded by it.
    pub seed: u64,
    pub iters: u64,
    /// Heartbeat to stderr every N iterations (0 = silent).
    pub progress_every: u64,
    /// Where a failing scenario's flight-recorder anomaly dump goes
    /// (`None` = the OS temp dir). The dump holds the recorder's final
    /// events from the FIRST failing run — before minimization re-runs
    /// perturb the interleaving.
    pub dump_dir: Option<PathBuf>,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        FuzzConfig { seed: 0, iters: 1, progress_every: 0, dump_dir: None }
    }
}

/// One invariant failure, minimized and rendered for reproduction.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    /// The *scenario* seed — replays with `fuzz --seed <seed> --iters 1`.
    pub seed: u64,
    pub iteration: u64,
    pub violations: Vec<String>,
    pub scenario: String,
    /// Minimized schedule in trace format.
    pub snippet: String,
    /// Flight-recorder anomaly dump from the failing run (JSONL; final
    /// lines restate the violations), when the dump could be written.
    pub dump_path: Option<String>,
}

impl FuzzFailure {
    pub fn render(&self) -> String {
        let mut s = format!(
            "fuzz failure at iteration {} (scenario seed {}):\n",
            self.iteration, self.seed
        );
        for v in &self.violations {
            s.push_str(&format!("  violation: {v}\n"));
        }
        s.push_str(&format!("  scenario: {}\n", self.scenario));
        s.push_str("  minimized schedule:\n");
        for line in self.snippet.lines() {
            s.push_str(&format!("    {line}\n"));
        }
        match &self.dump_path {
            Some(p) => s.push_str(&format!(
                "  reproduce: cargo run --release -- fuzz --seed {} --iters 1  \
                 (flight-recorder dump: {p})\n",
                self.seed
            )),
            None => s.push_str(&format!(
                "  reproduce: cargo run --release -- fuzz --seed {} --iters 1\n",
                self.seed
            )),
        }
        s
    }
}

/// Outcome of a fuzz run: how far it got, and the first failure (if any).
#[derive(Debug, Clone)]
pub struct FuzzSummary {
    pub iters_run: u64,
    pub failure: Option<FuzzFailure>,
}

impl FuzzSummary {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

/// Run `cfg.iters` seeded scenarios, stopping (after minimizing) at the
/// first invariant violation.
pub fn run_fuzz(cfg: &FuzzConfig) -> FuzzSummary {
    let mut seed_stream = Rng::new(cfg.seed);
    for i in 0..cfg.iters {
        let scenario_seed = if i == 0 { cfg.seed } else { seed_stream.next_u64() };
        let sc = Scenario::from_seed(scenario_seed);
        let dump_to = cfg
            .dump_dir
            .clone()
            .unwrap_or_else(std::env::temp_dir)
            .join(format!("trex-fuzz-dump-{scenario_seed}.jsonl"));
        let (violations, dump_path) = exec(&sc, &sc.reqs, Some(&dump_to));
        if !violations.is_empty() {
            let minimized = minimize(&sc);
            return FuzzSummary {
                iters_run: i + 1,
                failure: Some(FuzzFailure {
                    seed: scenario_seed,
                    iteration: i,
                    violations,
                    scenario: sc.describe(),
                    snippet: Scenario::snippet(&minimized),
                    dump_path,
                }),
            };
        }
        if cfg.progress_every > 0 && (i + 1) % cfg.progress_every == 0 {
            eprintln!("fuzz: {}/{} scenarios ok", i + 1, cfg.iters);
        }
    }
    FuzzSummary { iters_run: cfg.iters, failure: None }
}

/// Greedy schedule minimization: try dropping chunks (halves, then smaller)
/// while the violation persists. Bounded re-runs — minimization is a
/// convenience, not a search.
fn minimize(sc: &Scenario) -> Vec<ReqSpec> {
    let mut reqs = sc.reqs.clone();
    let mut budget = 8u32;
    let mut chunk = reqs.len().div_ceil(2);
    while chunk >= 1 && budget > 0 && reqs.len() > 1 {
        let mut i = 0;
        let mut shrunk = false;
        while i < reqs.len() && budget > 0 {
            let mut candidate = reqs.clone();
            candidate.drain(i..(i + chunk).min(candidate.len()));
            if candidate.is_empty() {
                break;
            }
            budget -= 1;
            if exec(sc, &candidate, None).0.is_empty() {
                i += chunk;
            } else {
                reqs = candidate;
                shrunk = true;
            }
        }
        if chunk == 1 && !shrunk {
            break;
        }
        chunk = (chunk / 2).max(1);
    }
    reqs
}

/// Run one schedule against the scenario's pool and return every invariant
/// violation observed (empty = the scenario passed) plus the path of the
/// flight-recorder anomaly dump written when there were violations and
/// `dump_to` was given. The pool always runs with a recorder attached —
/// fuzz scenarios are tiny, and a failing interleaving's span history is
/// exactly what a reproduction needs.
fn exec(sc: &Scenario, reqs: &[ReqSpec], dump_to: Option<&Path>) -> (Vec<String>, Option<String>) {
    let d = artifacts::TINY_D_MODEL;
    let max_seq = artifacts::TINY_MAX_SEQ;
    let hw = HwConfig::default();
    let pm = ModelConfig::tiny();
    let mut arena = KvArenaConfig::for_pool(&hw, &pm, sc.kv_quant, Some(sc.kv_pages));
    arena.admit_oversub = sc.admit_oversub;
    let kv = Arc::new(KvManager::new(&hw, &pm, arena));
    // Heterogeneous-fleet scenarios: one worker per chip, each with its own
    // tiny arena (the scenario's page budget) so eviction, chain migration
    // and sheds racing mid-migration streams fire under fuzz pressure.
    let fleet = if sc.fleet.is_empty() {
        None
    } else {
        let specs: Vec<ChipSpec> = sc
            .fleet
            .iter()
            .enumerate()
            .map(|(i, (role, vdd))| {
                let mut s = ChipSpec::with_role(format!("c{i}"), *role, *vdd);
                s.kv_pages = Some(sc.kv_pages);
                s
            })
            .collect();
        match Fleet::build(specs, &hw, &pm, sc.kv_quant) {
            Ok(f) => Some(Arc::new(f)),
            Err(e) => return (vec![format!("fleet build failed: {e}")], None),
        }
    };
    let n_workers = fleet.as_ref().map(|f| f.n_chips()).unwrap_or(sc.workers);
    let recorder = Arc::new(FlightRecorder::for_pool(n_workers, 4096));
    let pool = PoolConfig {
        workers: n_workers,
        queue_depth: sc.queue_depth,
        max_inflight: sc.max_inflight,
        affinity: true,
        decode: sc.decode,
        decode_max_wait: Duration::from_micros(sc.decode_max_wait_us),
        decode_priority: sc.decode_priority,
        prefill_chunk: sc.prefill_chunk,
        kv: if fleet.is_some() { None } else { Some(Arc::clone(&kv)) },
        fleet: fleet.clone(),
        lifecycle_ledger: true,
        recorder: Some(Arc::clone(&recorder)),
        // `None` is synthesized into a default telemetry config by the pool
        // whenever the control plane is on (the governor rides the sampler).
        telemetry: None,
        slo: sc.slo_p95_us.map(SloTarget::decode),
        governor: sc.dvfs.then(|| GovernorConfig {
            dwell_us: sc.dvfs_dwell_us as f64,
            ..GovernorConfig::default()
        }),
        batcher: BatcherConfig {
            max_seq,
            max_wait: Duration::from_micros(sc.batcher_wait_us),
        },
    };
    let (quant, pages) = (sc.kv_quant, sc.kv_pages);
    let (hw2, pm2) = (hw.clone(), pm.clone());
    let mut handle = Server::start_pool(
        move |ctx| {
            let set = ArtifactSet::reference(artifacts::TINY_MODEL, d, max_seq)?;
            Engine::for_worker(
                set,
                EngineConfig {
                    hw: hw2.clone(),
                    perf_model: pm2.clone(),
                    self_test: false,
                    kv_quant: quant,
                    kv_pages: Some(pages),
                },
                ctx,
            )
        },
        pool,
    );
    let metrics = Arc::clone(&handle.metrics);
    let (resp_rx, tok_rx) = handle.detach_streams();
    let tok_rx = if sc.drop_tokens {
        drop(tok_rx);
        None
    } else {
        Some(tok_rx)
    };
    let submitter = handle.submitter();

    let mut violations: Vec<String> = Vec::new();
    let mut injected_faults = false;
    let cutoff = if sc.early_shutdown { reqs.len() / 2 } else { reqs.len() };
    let submit_one = |spec: &ReqSpec| {
        if spec.gap_us > 0 {
            std::thread::sleep(Duration::from_micros(spec.gap_us));
        }
        let rows = if spec.malformed { spec.len.saturating_sub(1) } else { spec.len };
        let mut req = Request::new(spec.id, spec.len, vec![0.1; rows * d]);
        if spec.generate > 0 {
            req = req.with_generate(spec.generate);
        }
        if let Some(g) = spec.prefix_group {
            req = req.with_prefix_group(crate::kv::prefix_id(&format!("g{g}")));
        }
        submitter.try_submit(req).is_ok()
    };
    for spec in &reqs[..cutoff] {
        if submit_one(spec) && spec.malformed {
            injected_faults = true;
        }
    }

    // Shutdown drains everything admitted, then joins every thread.
    match handle.shutdown() {
        Ok(_report) => {}
        Err(e) => {
            let msg = e.to_string();
            if msg.contains("panicked") {
                violations.push(format!("pool thread panicked: {msg}"));
            } else if !injected_faults {
                violations.push(format!(
                    "pool latched a worker error with no injected faults: {msg}"
                ));
            }
        }
    }

    // Closed-gate property: late submits must reject, never admit.
    for spec in &reqs[cutoff..] {
        if submit_one(spec) {
            violations.push(format!(
                "request {} admitted after shutdown (gate must be closed)",
                spec.id
            ));
        }
    }

    // Invariant 1 — conservation via the ledger.
    match metrics.ledger_audit() {
        Some(audit) => {
            if !audit.conserved() {
                violations.push(format!(
                    "conservation violated: admitted={} completed={} shed={} open={:?} \
                     ledger_violations={:?}",
                    audit.admitted, audit.completed, audit.shed, audit.open, audit.violations
                ));
            }
            // The responses actually delivered must match the ledger.
            let delivered = resp_rx.try_iter().count() as u64;
            if delivered != audit.completed {
                violations.push(format!(
                    "response channel delivered {delivered} responses but the ledger \
                     completed {}",
                    audit.completed
                ));
            }
        }
        None => violations.push("lifecycle ledger unexpectedly disabled".to_string()),
    }

    // Invariant 2 — zero KV residual after drain, on EVERY chip: a stream
    // shed mid-migration holds state on both its source and target arenas,
    // and both must end clean.
    match &fleet {
        Some(f) => {
            for (i, chip) in f.chips.iter().enumerate() {
                let residual = chip.kv.residual();
                if !residual.is_clean() {
                    violations.push(format!(
                        "kv residual on chip {i} ('{}') after drain: {residual:?}",
                        chip.spec.id
                    ));
                }
                // Invariant 5 — no stale-plan pricing: every governor
                // re-point bumps the chip's op epoch, and the engine must
                // re-cost its plan scope before the next priced step. A
                // nonzero counter means some step was priced against a plan
                // compiled for a previous operating point.
                let stale = chip.stale_plan_hits();
                if stale != 0 {
                    violations.push(format!(
                        "chip {i} ('{}') priced {stale} step(s) against a stale \
                         plan after a re-point",
                        chip.spec.id
                    ));
                }
                // And with the governor off, nothing may re-point at all:
                // static configs must stay bit-identical to governorless runs.
                if !sc.dvfs && chip.op_epoch() != 0 {
                    violations.push(format!(
                        "chip {i} ('{}') re-pointed {} time(s) with the governor off",
                        chip.spec.id,
                        chip.op_epoch()
                    ));
                }
            }
        }
        None => {
            let residual = kv.residual();
            if !residual.is_clean() {
                violations.push(format!("kv arena residual after drain: {residual:?}"));
            }
        }
    }

    // Invariant 3 — no token event after its stream shed (and none for a
    // request the ledger never saw).
    if let Some(tok_rx) = tok_rx {
        for ev in tok_rx.try_iter() {
            match metrics.ledger_state(ev.id) {
                None => violations.push(format!(
                    "token event for request {} the ledger never admitted",
                    ev.id
                )),
                Some((Lifecycle::Shed, shed_at)) => {
                    if ev.emitted > shed_at {
                        violations.push(format!(
                            "token event for request {} emitted {:?} after its shed",
                            ev.id,
                            ev.emitted.duration_since(shed_at)
                        ));
                    }
                }
                Some(_) => {}
            }
        }
    }

    let mut dump_path = None;
    if !violations.is_empty() {
        if let Some(path) = dump_to {
            if dump_anomaly(&recorder, path, &violations).is_ok() {
                dump_path = Some(path.display().to_string());
            }
        }
    }
    (violations, dump_path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_are_deterministic_in_the_seed() {
        let a = Scenario::from_seed(42);
        let b = Scenario::from_seed(42);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(a.describe(), b.describe());
        assert!(!a.reqs.is_empty());
    }

    #[test]
    fn snippet_renders_trace_format_lines() {
        let reqs = vec![
            ReqSpec {
                id: 0,
                gap_us: 10,
                len: 4,
                generate: 2,
                malformed: false,
                prefix_group: Some(1),
            },
            ReqSpec { id: 1, gap_us: 5, len: 8, generate: 0, malformed: true, prefix_group: None },
        ];
        let s = Scenario::snippet(&reqs);
        assert!(s.contains("0 10 chat 4 2 g1"), "{s}");
        assert!(s.contains("1 15 embed 8 0\n"), "{s}");
        assert!(s.contains("# next request submits a malformed payload"), "{s}");
    }

    #[test]
    fn schedules_actually_mix_prefix_groups() {
        // The refcount invariant is vacuous if no scenario ever shares a
        // prefix; make sure the generator produces collisions somewhere in
        // a small seed range.
        let mut shared = 0usize;
        for seed in 0..32u64 {
            let sc = Scenario::from_seed(seed);
            let mut tags: Vec<u8> = sc.reqs.iter().filter_map(|r| r.prefix_group).collect();
            tags.sort_unstable();
            let before = tags.len();
            tags.dedup();
            if before > tags.len() {
                shared += 1;
            }
        }
        assert!(shared > 0, "no seed in 0..32 produced prefix-mates");
    }

    #[test]
    fn fleets_actually_mix_shapes() {
        // The per-chip residual and migration invariants are vacuous if no
        // scenario ever draws a multi-chip or role-split fleet.
        let mut multi = 0usize;
        let mut mixed_roles = 0usize;
        for seed in 0..64u64 {
            let sc = Scenario::from_seed(seed);
            if sc.fleet.len() > 1 {
                multi += 1;
            }
            let mut roles: Vec<&str> = sc.fleet.iter().map(|(r, _)| r.name()).collect();
            roles.sort_unstable();
            roles.dedup();
            if roles.len() > 1 {
                mixed_roles += 1;
            }
        }
        assert!(multi > 0, "no seed in 0..64 drew a multi-chip fleet");
        assert!(mixed_roles > 0, "no seed in 0..64 drew a role-split fleet");
    }

    #[test]
    fn governor_draws_actually_mix() {
        // The stale-plan invariant is vacuous if no scenario ever turns the
        // governor on; same for the SLO door gate.
        let mut governed = 0usize;
        let mut slo = 0usize;
        for seed in 0..64u64 {
            let sc = Scenario::from_seed(seed);
            if sc.dvfs {
                governed += 1;
            }
            if sc.slo_p95_us.is_some() {
                slo += 1;
            }
        }
        assert!(governed > 0, "no seed in 0..64 drew a governed fleet");
        assert!(slo > 0, "no seed in 0..64 drew an SLO target");
    }

    #[test]
    fn forced_governor_scenario_holds_invariants() {
        // A deterministic governed fleet with a tiny dwell: re-points race
        // decode steps, so the stale-plan invariant (epoch bump re-costs the
        // plan scope before the next priced step) is exercised rather than
        // vacuously true.
        let mut sc = Scenario::from_seed(0xD7F5);
        sc.fleet = vec![(ChipRole::General, 0.85), (ChipRole::General, 0.85)];
        sc.dvfs = true;
        sc.dvfs_dwell_us = 500;
        sc.slo_p95_us = Some(5_000.0);
        let (violations, _) = exec(&sc, &sc.reqs, None);
        assert!(violations.is_empty(), "{violations:?}");
    }

    #[test]
    fn forced_fleet_scenario_holds_invariants() {
        // A deterministic fleet shape with an early shutdown: streams shed
        // mid-migration must release pages on BOTH the source and target
        // chips, which the per-chip residual check below would catch.
        let mut sc = Scenario::from_seed(0xF1EE7);
        sc.early_shutdown = true;
        sc.fleet = vec![
            (ChipRole::Prefill, 0.85),
            (ChipRole::Decode, 0.45),
            (ChipRole::Decode, 0.45),
        ];
        let (violations, _) = exec(&sc, &sc.reqs, None);
        assert!(violations.is_empty(), "{violations:?}");
    }

    #[test]
    fn fuzz_smoke_holds_invariants_for_a_few_seeds() {
        // A bounded in-tree smoke: the CI job runs hundreds of iterations;
        // this keeps `cargo test` honest without the wall-clock bill.
        let summary =
            run_fuzz(&FuzzConfig { seed: 0xF077, iters: 3, ..FuzzConfig::default() });
        if let Some(f) = &summary.failure {
            panic!("{}", f.render());
        }
        assert_eq!(summary.iters_run, 3);
    }
}
