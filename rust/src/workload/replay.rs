//! Open-loop trace replay: submit on the trace clock, measure what the
//! pool does under the load it was *offered*, not the load it accepted.
//!
//! The closed-loop client in `main.rs`/the benches retries a rejected
//! submit after draining a response — offered load converges to pool
//! capacity and overload never happens. The open-loop driver is the
//! opposite contract: each trace record is submitted at its arrival time
//! (scaled by [`ReplayConfig::speed`]) exactly once, whether or not
//! anything has completed. A saturated pool must then actually exercise
//! its overload machinery — shed at the door, bound its queues — and the
//! driver measures the outcome: goodput, shed split (door vs
//! post-admission), and client-observed tail latency for the work that was
//! admitted. Graceful degradation means the door does the shedding while
//! admitted work keeps a bounded tail; a pool that admits everything and
//! lets queues grow shows up here as an unbounded p95.

use crate::coordinator::{Lifecycle, RequestId, ServerHandle, REPORT_SCHEMA_VERSION};
use crate::coordinator::request::Request;
use crate::kv::prefix_id;
use crate::obs::ShedTimeline;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::workload::trace_file::Trace;
use std::collections::{HashMap, HashSet};
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

/// Replay knobs.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Trace-clock speedup: 2.0 replays a trace in half its span (the
    /// standard way to turn a calibrated at-capacity trace into a 2×
    /// overload without regenerating it).
    pub speed: f64,
    /// Model width of the payload rows submitted with each request.
    pub d_model: usize,
    /// How long to keep draining after the last submission before
    /// declaring leftover in-flight work stalled.
    pub drain_timeout: Duration,
}

impl ReplayConfig {
    pub fn new(d_model: usize) -> Self {
        ReplayConfig { speed: 1.0, d_model, drain_timeout: Duration::from_secs(30) }
    }

    pub fn at_speed(mut self, speed: f64) -> Self {
        self.speed = speed.max(1e-6);
        self
    }
}

/// What one open-loop replay observed.
#[derive(Debug, Clone, Default)]
pub struct ReplayStats {
    /// Records in the trace (every one was offered exactly once).
    pub offered: usize,
    /// Submits the pool accepted.
    pub admitted: usize,
    /// Submits rejected at the door (backpressure / kv bound / bad length).
    pub shed_at_door: usize,
    /// Admitted requests that answered.
    pub completed: usize,
    /// Admitted requests that never answered within the drain window
    /// (shed post-admission, or stalled — [`ReplayStats::drained`] tells
    /// which).
    pub shed_after_admit: usize,
    /// Token events streamed during the replay.
    pub tokens_streamed: usize,
    /// False when the drain window expired with work still in flight.
    pub drained: bool,
    /// Wall time from first submission to end of drain, seconds.
    pub wall_seconds: f64,
    /// Completed requests per wall second.
    pub goodput_rps: f64,
    /// Client-observed submit→response latency of completed work, µs.
    pub latency_us_p50: f64,
    pub latency_us_p95: f64,
    pub latency_us_p99: f64,
    /// When each door shed happened, µs from replay start — the raw
    /// series behind [`ReplayStats::shed_timeline`].
    pub shed_door_us: Vec<f64>,
    /// When each post-admission shed happened, µs from replay start
    /// (recovered from the lifecycle ledger after the drain; empty when
    /// the pool ran without the ledger).
    pub shed_late_us: Vec<f64>,
}

impl ReplayStats {
    /// Shed fraction of offered load (door + post-admission).
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        (self.shed_at_door + self.shed_after_admit) as f64 / self.offered as f64
    }

    /// Door/late sheds bucketed over the run (the shape `serve --trace`
    /// prints and `to_json` embeds).
    pub fn shed_timeline(&self, buckets: usize) -> ShedTimeline {
        ShedTimeline::from_instants(&self.shed_door_us, &self.shed_late_us, buckets)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(REPORT_SCHEMA_VERSION as f64)),
            ("offered", Json::num(self.offered as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("shed_at_door", Json::num(self.shed_at_door as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("shed_after_admit", Json::num(self.shed_after_admit as f64)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("tokens_streamed", Json::num(self.tokens_streamed as f64)),
            ("drained", Json::num(if self.drained { 1.0 } else { 0.0 })),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("goodput_rps", Json::num(self.goodput_rps)),
            ("latency_us_p50", Json::num(self.latency_us_p50)),
            ("latency_us_p95", Json::num(self.latency_us_p95)),
            ("latency_us_p99", Json::num(self.latency_us_p99)),
            ("shed_timeline", self.shed_timeline(20).to_json()),
        ])
    }
}

/// Replay `trace` open-loop against a running pool. The caller keeps the
/// handle (and shuts it down afterwards — a post-replay
/// [`crate::coordinator::ServerMetrics::ledger_audit`] then checks
/// conservation). The driver owns the handle's response/token receivers
/// for the duration of the call; completions are drained concurrently
/// with submission so channel buffers never become the bottleneck.
pub fn replay(handle: &ServerHandle, trace: &Trace, cfg: &ReplayConfig) -> ReplayStats {
    let mut stats = ReplayStats { offered: trace.len(), ..ReplayStats::default() };
    let mut submitted_at: HashMap<RequestId, Instant> = HashMap::new();
    let mut completed_ids: HashSet<RequestId> = HashSet::new();
    let mut latencies: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut disconnected = false;

    let mut note = |resp: crate::coordinator::Response,
                    submitted_at: &HashMap<RequestId, Instant>,
                    completed_ids: &mut HashSet<RequestId>,
                    latencies: &mut Vec<f64>| {
        completed_ids.insert(resp.id);
        if let Some(t0) = submitted_at.get(&resp.id) {
            latencies.push(t0.elapsed().as_secs_f64() * 1e6);
        }
    };

    for rec in &trace.records {
        let target =
            start + Duration::from_micros((rec.arrival_us as f64 / cfg.speed) as u64);
        // Open-loop discipline: until the trace clock reaches this record,
        // do useful work — drain completions.
        loop {
            let now = Instant::now();
            if now >= target || disconnected {
                break;
            }
            match handle.responses.recv_timeout(target - now) {
                Ok(resp) => {
                    stats.completed += 1;
                    note(resp, &submitted_at, &mut completed_ids, &mut latencies);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
        // Exactly one submit per record — a rejection is the pool shedding
        // at the door, not a cue to retry.
        let mut req =
            Request::new(rec.id, rec.prompt_len, vec![0.1; rec.prompt_len * cfg.d_model]);
        if rec.gen_len > 0 {
            req = req.with_generate(rec.gen_len);
        }
        if let Some(tag) = &rec.prefix_group {
            // Records sharing a tag share one physical KV prefix in the
            // arena — trace replays exercise the radix index for real.
            req = req.with_prefix_group(prefix_id(tag));
        }
        match handle.try_submit(req) {
            Ok(()) => {
                stats.admitted += 1;
                submitted_at.insert(rec.id, Instant::now());
            }
            Err(_) => {
                stats.shed_at_door += 1;
                stats.shed_door_us.push(start.elapsed().as_secs_f64() * 1e6);
            }
        }
    }

    // Drain: completions keep arriving until the pool has nothing in
    // flight (sheds also free the in-flight slot, so inflight()==0 is the
    // settle condition) or the drain window expires.
    let deadline = Instant::now() + cfg.drain_timeout;
    while !disconnected && stats.completed < stats.admitted {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let wait = (deadline - now).min(Duration::from_millis(50));
        match handle.responses.recv_timeout(wait) {
            Ok(resp) => {
                stats.completed += 1;
                note(resp, &submitted_at, &mut completed_ids, &mut latencies);
            }
            Err(RecvTimeoutError::Timeout) => {
                if handle.inflight() == 0 {
                    // Settled: anything still unanswered was shed.
                    while let Ok(resp) = handle.responses.try_recv() {
                        stats.completed += 1;
                        note(resp, &submitted_at, &mut completed_ids, &mut latencies);
                    }
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => disconnected = true,
        }
    }

    stats.shed_after_admit = stats.admitted.saturating_sub(stats.completed);
    // Recover WHEN each post-admission shed happened from the lifecycle
    // ledger (the shed executed on a worker thread; the ledger stamped
    // it). Without the ledger the timeline just lacks the late series.
    for id in submitted_at.keys() {
        if completed_ids.contains(id) {
            continue;
        }
        if let Some((Lifecycle::Shed, at)) = handle.metrics.ledger_state(*id) {
            stats.shed_late_us.push(at.saturating_duration_since(start).as_secs_f64() * 1e6);
        }
    }
    stats.drained = disconnected || handle.inflight() == 0;
    stats.tokens_streamed = handle.tokens.try_iter().count();
    stats.wall_seconds = start.elapsed().as_secs_f64();
    stats.goodput_rps = if stats.wall_seconds > 0.0 {
        stats.completed as f64 / stats.wall_seconds
    } else {
        0.0
    };
    stats.latency_us_p50 = percentile(&latencies, 50.0);
    stats.latency_us_p95 = percentile(&latencies, 95.0);
    stats.latency_us_p99 = percentile(&latencies, 99.0);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_rate_counts_both_shed_kinds() {
        let s = ReplayStats {
            offered: 10,
            admitted: 8,
            shed_at_door: 2,
            completed: 7,
            shed_after_admit: 1,
            ..ReplayStats::default()
        };
        assert!((s.shed_rate() - 0.3).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("offered").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(j.get("shed_rate").unwrap().as_f64().unwrap(), 0.3);
    }

    #[test]
    fn empty_stats_are_finite() {
        let s = ReplayStats::default();
        assert_eq!(s.shed_rate(), 0.0);
        assert_eq!(s.to_json().get("latency_us_p95").unwrap().as_f64().unwrap(), 0.0);
    }
}
