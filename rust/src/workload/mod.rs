//! Trace-driven workloads: request-trace files, seeded synthetic arrival
//! generators, an open-loop replay driver, and a scenario fuzzer.
//!
//! The serving benches drive the pool **closed-loop** (via
//! [`crate::coordinator::TraceGenerator`]): a rejected submit retries after
//! draining a response, so offered load self-throttles to pool capacity and
//! overload behavior — admission, shedding, eviction — never actually
//! fires. This module is the other half of the story, the half T-REX's
//! utilization claims live or die on:
//!
//! * [`trace_file`] — a line-oriented request-trace format (`id arrival_us
//!   class prompt_len gen_len [prefix_group]`) with a hand-rolled parser
//!   that reports line-numbered errors. Traces are text so failures embed
//!   them, CI artifacts diff them, and `trex serve --trace FILE` replays
//!   them.
//! * [`synth`] — seeded generators for steady / bursty / diurnal Poisson
//!   arrivals over the benches' class mix; deterministic in the seed.
//! * [`replay`] — the **open-loop** replay driver: submits on the trace
//!   clock regardless of completions, so a 2× overload trace really
//!   overloads the pool and goodput / shed rate / tail latency under
//!   pressure become measurable (surfaced by the `fig11_replay` bench).
//! * [`fuzz`] — the seeded scenario fuzzer: random pool configs × random
//!   request schedules (including shared `prefix_group` tags, so the radix
//!   prefix index's refcounts are exercised under every interleaving),
//!   property-checked against scheduler invariants (request conservation
//!   via the lifecycle ledger, zero KV residual after drain, no token
//!   events after a stream sheds). Failures print the scenario seed + a
//!   minimized trace snippet.

pub mod fuzz;
pub mod replay;
pub mod synth;
pub mod trace_file;

pub use fuzz::{run_fuzz, FuzzConfig, FuzzFailure, FuzzSummary};
pub use replay::{replay, ReplayConfig, ReplayStats};
pub use synth::{synth_trace, ArrivalShape, SynthSpec};
pub use trace_file::{Trace, TraceError, TraceErrorKind, TraceRecord};
