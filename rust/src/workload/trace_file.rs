//! Line-oriented request-trace format + hand-rolled parser.
//!
//! A trace is the serving pool's replacement for closed-loop synthetic
//! load: a list of requests with **trace-clock arrival times**, replayed
//! open-loop (submission follows the clock regardless of completions) so
//! overload actually overloads. The format is deliberately tiny — one
//! record per line, whitespace-separated fields, `#` comments:
//!
//! ```text
//! # id arrival_us class prompt_len gen_len [prefix_group]
//! 0 0    chat  6 24 sys-a
//! 1 150  embed 30 0
//! 2 150  chat  7 24 sys-a
//! ```
//!
//! Grammar (one record per non-blank, non-comment line):
//!
//! ```text
//! record       := id ws arrival_us ws class ws prompt_len ws gen_len (ws prefix_group)?
//! id           := uint            ; unique across the trace
//! arrival_us   := uint            ; non-decreasing down the file
//! class        := ident           ; workload tag, reporting key ("chat", "embed", …)
//! prompt_len   := uint > 0        ; input tokens
//! gen_len      := uint            ; decode budget (0 = encode-only)
//! prefix_group := ident           ; optional shared-prompt-prefix tag
//! ident        := [A-Za-z][A-Za-z0-9_-]*
//! uint         := [0-9]+          ; 64-bit, overflow is an error
//! ```
//!
//! The parser is hand-rolled (zero deps, the offline-crate rule) and
//! rejects with **line-numbered, field-named errors** — a malformed trace
//! must tell the operator exactly which line and field to fix, never
//! panic, and never silently skip records. `prefix_group` names the
//! shared-prompt identity the replay driver hashes
//! ([`crate::kv::prefix_id`]) and submits with each request, so records
//! sharing a tag attach to ONE physical KV prefix in the arena's radix
//! index instead of each paying a copy.

use std::collections::HashSet;
use std::fmt;

/// One request in a trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRecord {
    pub id: u64,
    /// Arrival on the trace clock, µs from trace start (non-decreasing).
    pub arrival_us: u64,
    /// Workload tag ("chat", "embed", …) — a reporting key, not a batch
    /// class: batch classes derive from `prompt_len` at admission.
    pub class: String,
    /// Input length in tokens (≥ 1).
    pub prompt_len: usize,
    /// Decode budget (0 = encode-only).
    pub gen_len: usize,
    /// Optional shared-prompt-prefix tag: records sharing it share one
    /// refcounted KV prefix (hashed into the submitted request's
    /// `prefix_group` by the replay driver).
    pub prefix_group: Option<String>,
}

impl fmt::Display for TraceRecord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {} {} {} {}",
            self.id, self.arrival_us, self.class, self.prompt_len, self.gen_len
        )?;
        if let Some(g) = &self.prefix_group {
            write!(f, " {g}")?;
        }
        Ok(())
    }
}

/// What went wrong, with enough structure for tests to pin each path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceErrorKind {
    /// A record line ended before this field.
    MissingField { field: &'static str },
    /// Trailing token(s) after the last accepted field.
    ExtraField { got: String },
    /// A field failed its own grammar (`want` names the expected shape).
    Malformed { field: &'static str, got: String, want: &'static str },
    /// `arrival_us` went backwards relative to the previous record.
    NonMonotoneArrival { prev: u64, got: u64 },
    /// The same request id appeared twice.
    DuplicateId { id: u64 },
    /// `prompt_len` was zero — an empty prompt is unservable.
    ZeroPromptLen,
}

/// A parse failure: 1-based line number + what was wrong on it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceError {
    pub line: usize,
    pub kind: TraceErrorKind,
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "trace line {}: ", self.line)?;
        match &self.kind {
            TraceErrorKind::MissingField { field } => {
                write!(f, "missing field `{field}`")
            }
            TraceErrorKind::ExtraField { got } => {
                write!(f, "unexpected trailing field `{got}`")
            }
            TraceErrorKind::Malformed { field, got, want } => {
                write!(f, "field `{field}`: expected {want}, got `{got}`")
            }
            TraceErrorKind::NonMonotoneArrival { prev, got } => {
                write!(f, "arrival_us went backwards: {got} after {prev} (traces are time-sorted)")
            }
            TraceErrorKind::DuplicateId { id } => {
                write!(f, "duplicate request id {id}")
            }
            TraceErrorKind::ZeroPromptLen => {
                write!(f, "prompt_len must be >= 1 (an empty prompt is unservable)")
            }
        }
    }
}

impl std::error::Error for TraceError {}

/// A parsed trace: records in arrival order, ids unique.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    pub records: Vec<TraceRecord>,
}

impl Trace {
    /// Parse trace text. Blank lines and `#` comments (whole-line or
    /// trailing) are skipped; every record line must parse or the whole
    /// trace is rejected with a line-numbered error.
    pub fn parse(src: &str) -> Result<Trace, TraceError> {
        let mut records = Vec::new();
        let mut seen_ids: HashSet<u64> = HashSet::new();
        let mut prev_arrival: u64 = 0;
        for (idx, raw) in src.lines().enumerate() {
            let line_no = idx + 1;
            // Strip a trailing comment, then leading/trailing whitespace.
            let line = match raw.find('#') {
                Some(p) => &raw[..p],
                None => raw,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let rec = parse_record(line, line_no)?;
            if !records.is_empty() && rec.arrival_us < prev_arrival {
                return Err(TraceError {
                    line: line_no,
                    kind: TraceErrorKind::NonMonotoneArrival {
                        prev: prev_arrival,
                        got: rec.arrival_us,
                    },
                });
            }
            if !seen_ids.insert(rec.id) {
                return Err(TraceError {
                    line: line_no,
                    kind: TraceErrorKind::DuplicateId { id: rec.id },
                });
            }
            prev_arrival = rec.arrival_us;
            records.push(rec);
        }
        Ok(Trace { records })
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Trace-clock span: arrival of the last record, µs.
    pub fn span_us(&self) -> u64 {
        self.records.last().map(|r| r.arrival_us).unwrap_or(0)
    }

    /// Unique class tags, in first-seen order.
    pub fn classes(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for r in &self.records {
            if !out.iter().any(|c| c == &r.class) {
                out.push(r.class.clone());
            }
        }
        out
    }

    /// Serialize back to the line format (round-trips through [`parse`]).
    ///
    /// [`parse`]: Trace::parse
    pub fn to_text(&self) -> String {
        let mut s = String::from("# id arrival_us class prompt_len gen_len [prefix_group]\n");
        for r in &self.records {
            s.push_str(&r.to_string());
            s.push('\n');
        }
        s
    }
}

/// Field-by-field record parser. Hand-rolled scanners per field so every
/// rejection names the field and what it expected.
fn parse_record(line: &str, line_no: usize) -> Result<TraceRecord, TraceError> {
    let mut fields = line.split_ascii_whitespace();
    let mut next = |field: &'static str| -> Result<&str, TraceError> {
        fields.next().ok_or(TraceError {
            line: line_no,
            kind: TraceErrorKind::MissingField { field },
        })
    };
    let id = parse_uint("id", next("id")?, line_no)?;
    let arrival_us = parse_uint("arrival_us", next("arrival_us")?, line_no)?;
    let class = parse_ident("class", next("class")?, line_no)?;
    let prompt_len = parse_uint("prompt_len", next("prompt_len")?, line_no)? as usize;
    if prompt_len == 0 {
        return Err(TraceError { line: line_no, kind: TraceErrorKind::ZeroPromptLen });
    }
    let gen_len = parse_uint("gen_len", next("gen_len")?, line_no)? as usize;
    let prefix_group = match fields.next() {
        Some(tok) => Some(parse_ident("prefix_group", tok, line_no)?),
        None => None,
    };
    if let Some(extra) = fields.next() {
        return Err(TraceError {
            line: line_no,
            kind: TraceErrorKind::ExtraField { got: extra.to_string() },
        });
    }
    Ok(TraceRecord { id, arrival_us, class, prompt_len, gen_len, prefix_group })
}

/// `[0-9]+` with 64-bit overflow checking — a digit-wise accumulator, not
/// `str::parse`, so the error text is ours and exact.
fn parse_uint(field: &'static str, tok: &str, line: usize) -> Result<u64, TraceError> {
    let malformed = |want: &'static str| TraceError {
        line,
        kind: TraceErrorKind::Malformed { field, got: tok.to_string(), want },
    };
    if tok.is_empty() {
        return Err(malformed("an unsigned integer"));
    }
    let mut v: u64 = 0;
    for b in tok.bytes() {
        if !b.is_ascii_digit() {
            return Err(malformed("an unsigned integer"));
        }
        v = v
            .checked_mul(10)
            .and_then(|v| v.checked_add((b - b'0') as u64))
            .ok_or_else(|| malformed("an unsigned integer that fits in 64 bits"))?;
    }
    Ok(v)
}

/// `[A-Za-z][A-Za-z0-9_-]*` — class / prefix-group tags.
fn parse_ident(field: &'static str, tok: &str, line: usize) -> Result<String, TraceError> {
    let malformed = || TraceError {
        line,
        kind: TraceErrorKind::Malformed {
            field,
            got: tok.to_string(),
            want: "an identifier ([A-Za-z][A-Za-z0-9_-]*)",
        },
    };
    let mut bytes = tok.bytes();
    match bytes.next() {
        Some(b) if b.is_ascii_alphabetic() => {}
        _ => return Err(malformed()),
    }
    for b in bytes {
        if !(b.is_ascii_alphanumeric() || b == b'_' || b == b'-') {
            return Err(malformed());
        }
    }
    Ok(tok.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(line: &str) -> TraceRecord {
        Trace::parse(line).expect("valid record").records.remove(0)
    }

    fn err(src: &str) -> TraceError {
        Trace::parse(src).expect_err("must reject")
    }

    #[test]
    fn parses_minimal_and_full_records() {
        let r = rec("3 120 chat 7 24");
        assert_eq!(r.id, 3);
        assert_eq!(r.arrival_us, 120);
        assert_eq!(r.class, "chat");
        assert_eq!(r.prompt_len, 7);
        assert_eq!(r.gen_len, 24);
        assert_eq!(r.prefix_group, None);
        let r = rec("0 0 embed 30 0 sys-a");
        assert_eq!(r.prefix_group.as_deref(), Some("sys-a"));
    }

    #[test]
    fn comments_and_blank_lines_are_skipped() {
        let t = Trace::parse(
            "# a header comment\n\
             \n\
             0 0 chat 6 8   # trailing comment\n\
             \t  \n\
             1 10 chat 6 8\n",
        )
        .unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.span_us(), 10);
        assert_eq!(t.classes(), vec!["chat".to_string()]);
    }

    #[test]
    fn missing_fields_name_the_field_and_line() {
        let e = err("0 0 chat 6");
        assert_eq!(e.line, 1);
        assert_eq!(e.kind, TraceErrorKind::MissingField { field: "gen_len" });
        let e = err("7");
        assert_eq!(e.kind, TraceErrorKind::MissingField { field: "arrival_us" });
        // The error carries the right line number past valid records.
        let e = err("0 0 chat 6 0\n1 5 chat\n");
        assert_eq!(e.line, 2);
        assert_eq!(e.kind, TraceErrorKind::MissingField { field: "prompt_len" });
    }

    #[test]
    fn malformed_fields_are_errors_not_panics() {
        let e = err("x 0 chat 6 0");
        assert!(matches!(e.kind, TraceErrorKind::Malformed { field: "id", .. }), "{e}");
        let e = err("0 12x chat 6 0");
        assert!(matches!(e.kind, TraceErrorKind::Malformed { field: "arrival_us", .. }), "{e}");
        let e = err("0 0 9bad 6 0");
        assert!(matches!(e.kind, TraceErrorKind::Malformed { field: "class", .. }), "{e}");
        let e = err("0 0 chat -6 0");
        assert!(matches!(e.kind, TraceErrorKind::Malformed { field: "prompt_len", .. }), "{e}");
        let e = err("0 0 chat 6 0 !grp");
        assert!(matches!(e.kind, TraceErrorKind::Malformed { field: "prefix_group", .. }), "{e}");
        // 2^64 overflows: rejected, not wrapped.
        let e = err("18446744073709551616 0 chat 6 0");
        assert!(matches!(e.kind, TraceErrorKind::Malformed { field: "id", .. }), "{e}");
    }

    #[test]
    fn trailing_garbage_is_an_error() {
        let e = err("0 0 chat 6 0 grp extra");
        assert_eq!(e.kind, TraceErrorKind::ExtraField { got: "extra".to_string() });
    }

    #[test]
    fn non_monotone_arrivals_rejected_with_line() {
        let e = err("0 100 chat 6 0\n1 99 chat 6 0\n");
        assert_eq!(e.line, 2);
        assert_eq!(e.kind, TraceErrorKind::NonMonotoneArrival { prev: 100, got: 99 });
        // Equal arrivals are fine (a burst lands together).
        assert!(Trace::parse("0 100 chat 6 0\n1 100 chat 6 0\n").is_ok());
    }

    #[test]
    fn duplicate_ids_rejected_with_line() {
        let e = err("0 0 chat 6 0\n1 5 chat 6 0\n0 9 chat 6 0\n");
        assert_eq!(e.line, 3);
        assert_eq!(e.kind, TraceErrorKind::DuplicateId { id: 0 });
    }

    #[test]
    fn zero_prompt_len_rejected() {
        let e = err("0 0 chat 0 4");
        assert_eq!(e.kind, TraceErrorKind::ZeroPromptLen);
        assert_eq!(
            e.to_string(),
            "trace line 1: prompt_len must be >= 1 (an empty prompt is unservable)"
        );
    }

    #[test]
    fn round_trips_through_text() {
        let src = "0 0 chat 6 24 sys-a\n1 150 embed 30 0\n2 150 chat 7 24 sys-a\n";
        let t = Trace::parse(src).unwrap();
        let t2 = Trace::parse(&t.to_text()).unwrap();
        assert_eq!(t, t2);
        assert_eq!(t2.classes(), vec!["chat".to_string(), "embed".to_string()]);
    }

    #[test]
    fn error_display_is_line_numbered_and_field_named() {
        let e = err("0 0 chat 6 0\n1 5 chat 6 zz\n");
        assert_eq!(
            e.to_string(),
            "trace line 2: field `gen_len`: expected an unsigned integer, got `zz`"
        );
    }
}
