//! Seeded synthetic trace generators: steady, bursty, and diurnal arrival
//! processes over the mixed-class length distribution the pool benches
//! drive.
//!
//! Arrivals are an inhomogeneous Poisson process: exponential gaps drawn
//! at the instantaneous rate `rate(t)`, where the shape modulates the mean
//! rate (constant, periodic multiplicative bursts, or a sinusoidal
//! "diurnal" cycle compressed into `period_us`). Everything is
//! deterministic in the seed — a failing replay names its seed and spec,
//! and regenerating the exact trace is one call.

use crate::util::rng::Rng;
use crate::workload::trace_file::{Trace, TraceRecord};

/// How the arrival rate varies over the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalShape {
    /// Constant-rate Poisson arrivals.
    Steady,
    /// Background rate with periodic bursts: for the first `burst_us` of
    /// every `period_us`, the rate multiplies by `mult`. The background
    /// rate is scaled down so the *mean* stays `mean_rps`.
    Burst { mult: f64, period_us: u64, burst_us: u64 },
    /// Sinusoidal rate: `mean × (1 + swing·sin(2πt/period))` — a diurnal
    /// cycle compressed into `period_us`. `swing` ∈ [0, 1).
    Diurnal { swing: f64, period_us: u64 },
}

/// Spec for one synthetic trace.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub seed: u64,
    /// Mean offered rate on the trace clock, requests/s.
    pub mean_rps: f64,
    /// Trace-clock length, µs (arrivals stop past this).
    pub duration_us: u64,
    pub shape: ArrivalShape,
    /// Prompt lengths are class-mixed uniform in `[1, max_seq]` (equal
    /// B1/B2/B4 traffic, like `TraceGenerator::mixed`).
    pub max_seq: usize,
    /// Fraction of requests that decode (`0.0` = all encode-only).
    pub generate_share: f64,
    /// Decode budget of a generate request.
    pub gen_tokens: usize,
    /// Distinct prefix-group tags sprinkled over generate requests
    /// (0 = no prefix groups emitted).
    pub prefix_groups: usize,
}

impl SynthSpec {
    /// A steady trace at `mean_rps` for `duration_us` — the base spec the
    /// benches then reshape.
    pub fn steady(seed: u64, mean_rps: f64, duration_us: u64, max_seq: usize) -> SynthSpec {
        SynthSpec {
            seed,
            mean_rps,
            duration_us,
            shape: ArrivalShape::Steady,
            max_seq,
            generate_share: 0.5,
            gen_tokens: 4,
            prefix_groups: 0,
        }
    }
}

/// Instantaneous rate (requests/s) at trace-clock `t_us`.
fn rate_at(spec: &SynthSpec, t_us: u64) -> f64 {
    match spec.shape {
        ArrivalShape::Steady => spec.mean_rps,
        ArrivalShape::Burst { mult, period_us, burst_us } => {
            let period = period_us.max(1);
            let duty = burst_us.min(period) as f64 / period as f64;
            // Scale the background so the time-average equals mean_rps.
            let base = spec.mean_rps / (1.0 + (mult - 1.0) * duty);
            if t_us % period < burst_us {
                base * mult
            } else {
                base
            }
        }
        ArrivalShape::Diurnal { swing, period_us } => {
            let phase = (t_us % period_us.max(1)) as f64 / period_us.max(1) as f64;
            spec.mean_rps * (1.0 + swing * (2.0 * std::f64::consts::PI * phase).sin())
        }
    }
}

/// Generate a trace from a spec. Deterministic in `spec.seed`.
pub fn synth_trace(spec: &SynthSpec) -> Trace {
    let mut rng = Rng::new(spec.seed);
    let mut records = Vec::new();
    let mut t_us: f64 = 0.0;
    let mut id: u64 = 0;
    loop {
        // Exponential gap at the instantaneous rate (floor the rate so a
        // deep diurnal trough can't stall the clock forever).
        let rps = rate_at(spec, t_us as u64).max(spec.mean_rps * 1e-3).max(1e-6);
        let per_us = rps / 1e6;
        let gap = -(1.0 - rng.f64()).max(1e-12).ln() / per_us;
        t_us += gap;
        if t_us as u64 > spec.duration_us {
            break;
        }
        let prompt_len = class_mixed_len(&mut rng, spec.max_seq);
        let generates = rng.f64() < spec.generate_share;
        let gen_len = if generates { spec.gen_tokens } else { 0 };
        let class = if generates { "chat" } else { "embed" };
        let prefix_group = if generates && spec.prefix_groups > 0 {
            Some(format!("g{}", rng.below(spec.prefix_groups)))
        } else {
            None
        };
        records.push(TraceRecord {
            id,
            arrival_us: t_us as u64,
            class: class.to_string(),
            prompt_len,
            gen_len,
            prefix_group,
        });
        id += 1;
    }
    Trace { records }
}

/// Equal-probability batch-class mix: pick B1/B2/B4 uniformly, then a
/// length uniform within the class band (mirrors `TraceGenerator::mixed`).
fn class_mixed_len(rng: &mut Rng, max_seq: usize) -> usize {
    let quarter = (max_seq / 4).max(1);
    match rng.below(3) {
        0 => rng.range(1, quarter),
        1 => rng.range(quarter + 1, (max_seq / 2).max(quarter + 1)),
        _ => rng.range(max_seq / 2 + 1, max_seq.max(max_seq / 2 + 1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: ArrivalShape) -> SynthSpec {
        SynthSpec { shape, ..SynthSpec::steady(0xBEEF, 2000.0, 500_000, 32) }
    }

    #[test]
    fn deterministic_in_seed_and_parseable() {
        let a = synth_trace(&spec(ArrivalShape::Steady));
        let b = synth_trace(&spec(ArrivalShape::Steady));
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // Round-trips through the trace-file format.
        let parsed = Trace::parse(&a.to_text()).unwrap();
        assert_eq!(parsed, a);
    }

    #[test]
    fn steady_rate_close_to_mean() {
        let t = synth_trace(&spec(ArrivalShape::Steady));
        // 2000 rps × 0.5 s ⇒ ~1000 arrivals; Poisson σ ≈ 32.
        let n = t.len() as f64;
        assert!((850.0..1150.0).contains(&n), "n={n}");
        assert!(t.span_us() <= 500_000);
        // Arrivals are sorted and ids unique by construction.
        assert!(t.records.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
    }

    #[test]
    fn burst_concentrates_arrivals_but_keeps_the_mean() {
        let s = spec(ArrivalShape::Burst { mult: 8.0, period_us: 100_000, burst_us: 10_000 });
        let t = synth_trace(&s);
        let n = t.len() as f64;
        assert!((800.0..1200.0).contains(&n), "mean preserved, n={n}");
        // The burst decile of each period holds well above its 10% share.
        let in_burst =
            t.records.iter().filter(|r| r.arrival_us % 100_000 < 10_000).count() as f64;
        assert!(in_burst / n > 0.3, "burst share {}", in_burst / n);
    }

    #[test]
    fn diurnal_peak_beats_trough() {
        let s = spec(ArrivalShape::Diurnal { swing: 0.9, period_us: 500_000 });
        let t = synth_trace(&s);
        // sin > 0 over the first half-period (peak), < 0 over the second.
        let first_half = t.records.iter().filter(|r| r.arrival_us < 250_000).count();
        let second_half = t.len() - first_half;
        assert!(
            first_half > second_half * 2,
            "peak {first_half} vs trough {second_half}"
        );
    }

    #[test]
    fn lengths_and_budgets_in_spec_bounds() {
        let mut s = spec(ArrivalShape::Steady);
        s.generate_share = 1.0;
        s.gen_tokens = 7;
        s.prefix_groups = 3;
        let t = synth_trace(&s);
        assert!(t.records.iter().all(|r| (1..=32).contains(&r.prompt_len)));
        assert!(t.records.iter().all(|r| r.gen_len == 7 && r.class == "chat"));
        assert!(t.records.iter().all(|r| r.prefix_group.is_some()));
    }
}
